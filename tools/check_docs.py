#!/usr/bin/env python
"""Docs health check: markdown link integrity + runnable snippets.

Run from the repo root (CI's fast docs job does):

    PYTHONPATH=src python tools/check_docs.py

Two passes over ``README.md`` and every ``docs/*.md``:

1. **Link check** — every relative markdown link ``[text](target)`` must
   resolve to an existing file (anchors are stripped; same-file ``#anchor``
   links must match a heading). External ``http(s)://`` links are not
   fetched — CI must not flake on the network.
2. **Snippet check** — every fenced ```` ```python ```` block in the
   snippet-checked files (``docs/API.md`` and the README) is executed.
   Blocks run top to bottom in ONE namespace per file, so a later block may
   use objects an earlier one defined — write docs accordingly. A failing
   snippet fails CI: the docs may not drift from the code.
"""
from __future__ import annotations

import re
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_CHECKED = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
SNIPPET_CHECKED = [ROOT / "README.md", ROOT / "docs" / "API.md"]

# [text](target) — but not images ![..](..) nor in-code backticked text
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def _headings(md: str) -> set[str]:
    """GitHub-style anchor slugs for every heading in ``md``."""
    out = set()
    for line in md.splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            slug = m.group(1).strip().lower()
            slug = re.sub(r"[`*_]", "", slug)
            slug = re.sub(r"[^\w\- ]", "", slug).replace(" ", "-")
            out.add(slug)
    return out


def _strip_fences(md: str) -> str:
    """Drop fenced code blocks so code-sample brackets aren't 'links'."""
    out, fenced = [], False
    for line in md.splitlines():
        if _FENCE_RE.match(line):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def check_links() -> list[str]:
    errors = []
    for path in LINK_CHECKED:
        md = path.read_text()
        for target in _LINK_RE.findall(_strip_fences(md)):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, …
                continue
            base, _, anchor = target.partition("#")
            where = f"{path.relative_to(ROOT)} -> {target}"
            if not base:                                    # same-file anchor
                if anchor not in _headings(md):
                    errors.append(f"{where}: no such heading")
                continue
            dest = (path.parent / base).resolve()
            if not dest.exists():
                errors.append(f"{where}: file not found")
            elif anchor and dest.suffix == ".md":
                if anchor not in _headings(dest.read_text()):
                    errors.append(f"{where}: no such heading in {base}")
    return errors


def _python_blocks(md: str) -> list[tuple[int, str]]:
    blocks, buf, lang, start = [], [], None, 0
    for i, line in enumerate(md.splitlines(), 1):
        m = _FENCE_RE.match(line)
        if m and lang is None:
            lang, buf, start = m.group(1), [], i
        elif m:
            if lang == "python":
                blocks.append((start, "\n".join(buf)))
            lang = None
        elif lang is not None:
            buf.append(line)
    return blocks


def check_snippets() -> list[str]:
    errors = []
    for path in SNIPPET_CHECKED:
        ns: dict = {"__name__": "__docs__"}   # one namespace per file
        for lineno, code in _python_blocks(path.read_text()):
            t0 = time.monotonic()
            try:
                exec(compile(code, f"{path.name}:{lineno}", "exec"), ns)
            except Exception as e:  # noqa: BLE001 — reported, fails the job
                errors.append(
                    f"{path.relative_to(ROOT)} snippet at line {lineno}: "
                    f"{type(e).__name__}: {e}")
                break   # later blocks in this file may depend on this one
            print(f"  ok {path.name}:{lineno} "
                  f"({time.monotonic() - t0:.1f}s)")
    return errors


def main() -> int:
    print(f"link check: {', '.join(p.name for p in LINK_CHECKED)}")
    errors = check_links()
    print(f"snippet check: {', '.join(p.name for p in SNIPPET_CHECKED)}")
    errors += check_snippets()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"docs check: {'FAIL' if errors else 'OK'} "
          f"({len(LINK_CHECKED)} files linked-checked, "
          f"{len(SNIPPET_CHECKED)} snippet-checked)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
