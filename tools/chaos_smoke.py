#!/usr/bin/env python
"""Chaos smoke: a seeded fault-injection soak over reduced VGG16.

    PYTHONPATH=src python tools/chaos_smoke.py [--seeds 0 1 2] [--requests 24]

Builds the reduced VGG16 accelerator once, then for each seed drives a
:func:`repro.serving.chaos_soak` — a fixed request stream served under a
:meth:`FaultPlan.seeded` schedule of injected errors, delays, payload
corruption and thread kills — and asserts the liveness invariant the
fault-injection test suite proves per-mechanism:

* every submitted request's future RESOLVES (result or typed error);
* the session ledger balances EXACTLY:
  ``submitted == completed + errors + shed``.

One seed always includes a ``kill`` spec so the watchdog-restart path is
exercised on every CI run, not only when a seed happens to draw one. The
plans are deterministic (all randomness at construction), so a failure
here reproduces locally with the same command. CI's fast tier runs this
on every PR.
"""
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1])
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    from repro import api
    from repro.core import perf_model as pm
    from repro.models import vgg
    from repro.serving import FaultPlan, FaultSpec, chaos_soak

    specs = vgg.network_specs(img=64, scale=8, n_classes=10)
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=4, seed=0)

    failures = 0
    for seed in args.seeds:
        plan = FaultPlan.seeded(seed, n_faults=6, horizon=12,
                                n_requests=args.requests)
        report = chaos_soak(acc, plan=plan, n_requests=args.requests,
                            timeout_s=120.0)
        print(f"seed {seed}: survived={report['survived']} "
              f"submitted={report['submitted']} "
              f"completed={report['stats_completed']} "
              f"errors={report['stats_errors']} shed={report['shed']} "
              f"retries={report['retries']} isolated={report['isolated']} "
              f"faults fired={report['fault_events']}")
        if not report["survived"]:
            print(f"FAIL: seed {seed} violated liveness/accounting: "
                  f"{report}", file=sys.stderr)
            failures += 1

    # the guaranteed-kill soak: the watchdog must restart the pipeline and
    # still account for every request
    plan = FaultPlan([FaultSpec(site="dispatch", kind="kill", at=(2,)),
                      FaultSpec(site="drain", kind="kill", at=(5,))])
    report = chaos_soak(acc, plan=plan, n_requests=args.requests,
                        timeout_s=120.0, max_batch=2, buckets=(2,))
    print(f"kill soak: survived={report['survived']} "
          f"watchdog_restarts={report['watchdog_restarts']} "
          f"errors={report['stats_errors']}")
    if not report["survived"] or report["watchdog_restarts"] < 1:
        print(f"FAIL: kill soak did not survive/restart: {report}",
              file=sys.stderr)
        failures += 1

    if failures:
        return 1
    print("chaos smoke OK: every request resolved, every ledger balanced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
