#!/usr/bin/env python
"""AOT round-trip smoke: save -> FRESH process -> load -> serve.

    PYTHONPATH=src python tools/aot_smoke.py

The parent builds a small model, serves one warmed session (recording the
fresh ``compile_ms`` and the per-request outputs), and writes an AOT bundle
(``Accelerator.save_program(..., aot=True)``). A child interpreter — a
genuinely cold process, the autoscaling-event case the artifact layer
exists for — loads the bundle, serves the same requests, and reports its
``SessionStats``. The smoke fails if the warm process compiled anything
(``compile_ms`` must be exactly 0), if any output differs BITWISE from the
parent's, or if the warm start is not faster than the fresh compile. CI's
fast tier runs this on every PR.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

_CHILD = r"""
import json, sys
import numpy as np
from repro import api

bundle, out_path = sys.argv[1], sys.argv[2]
rng = np.random.default_rng(1)
reqs = [rng.standard_normal((16, 16, 3)).astype(np.float32)
        for _ in range(8)]
# same stand-in weights the parent's build(seed=0) generated
with open(bundle + "/program.json") as f:
    doc = json.load(f)
specs = [api._spec_from_dict(d) for d in doc["specs"]]
acc = api.Accelerator.from_program(bundle,
                                   params=api.random_params(specs, seed=0))
with acc.serve(max_batch=4, buckets=(1, 2, 4), warmup=True) as s:
    outs = [np.asarray(y).tolist() for y in s.run_many(reqs)]
    st = s.stats
json.dump({"compile_ms": st.compile_ms, "warm_load_ms": st.warm_load_ms,
           "outs": outs}, open(out_path, "w"))
"""


def main() -> int:
    import numpy as np

    from repro import api
    from repro.core import perf_model as pm
    from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec

    specs = [ConvSpec("c1", 16, 16, 3, 8), PoolSpec("p1", 16, 16, 8),
             FCSpec("fc", 8 * 8 * 8, 10, relu=False)]
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=4, seed=0)
    rng = np.random.default_rng(1)
    reqs = [rng.standard_normal((16, 16, 3)).astype(np.float32)
            for _ in range(8)]
    with acc.serve(max_batch=4, buckets=(1, 2, 4), warmup=True) as s:
        fresh = [np.asarray(y) for y in s.run_many(reqs)]
        fresh_compile_ms = s.stats.compile_ms

    with tempfile.TemporaryDirectory() as tmp:
        bundle = os.path.join(tmp, "bundle")
        acc.save_program(bundle, aot=True, buckets=(1, 2, 4))
        out_path = os.path.join(tmp, "warm.json")
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(repo, "src"), env.get("PYTHONPATH", "")])
        r = subprocess.run([sys.executable, "-c", _CHILD, bundle, out_path],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        if r.returncode != 0:
            print(f"FAIL: warm child process died\nstdout:\n{r.stdout}\n"
                  f"stderr:\n{r.stderr}", file=sys.stderr)
            return 1
        warm = json.load(open(out_path))

    ok = True
    if warm["compile_ms"] != 0.0:
        print(f"FAIL: warm process compiled "
              f"({warm['compile_ms']:.1f}ms != 0)", file=sys.stderr)
        ok = False
    if not warm["warm_load_ms"] > 0.0:
        print("FAIL: warm process reported no warm-load time — the bundle "
              "was not used", file=sys.stderr)
        ok = False
    for i, (a, b) in enumerate(zip(fresh, warm["outs"])):
        if not np.array_equal(a, np.asarray(b, a.dtype)):
            print(f"FAIL: request {i} differs between fresh and warm-loaded "
                  f"executors (bitwise)", file=sys.stderr)
            ok = False
            break
    ratio = warm["warm_load_ms"] / max(fresh_compile_ms, 1e-9)
    print(f"aot smoke: fresh compile {fresh_compile_ms:.0f}ms, warm load "
          f"{warm['warm_load_ms']:.0f}ms ({ratio:.2f}x), outputs bitwise "
          f"{'OK' if ok else 'MISMATCH'}")
    if ratio >= 1.0:
        print("FAIL: warm load is not faster than the fresh compile",
              file=sys.stderr)
        ok = False
    print(f"aot smoke: {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
