#!/usr/bin/env python
"""Benchmark-artifact guard: schema-check ``BENCH_*.json`` and diff fresh
rows against the committed file — the nightly regression tripwire.

    PYTHONPATH=src python tools/bench_compare.py BENCH_table4_vgg16.json \
        --against git:HEAD --tol 0.5

Two passes:

1. **Schema check** — the file must be a JSON list of row dicts, every row
   carries string ``bench``/``name`` keys and JSON-scalar values, and rows
   with a known ``name`` carry that row's required metric keys (so a bench
   refactor cannot silently drop the metric CI archives). Always runs;
   failures exit non-zero.
2. **Regression diff** (with ``--against``) — rows are matched by ``name``
   against the baseline file (a path, or ``git:<ref>`` to read the version
   committed at ``<ref>``). Every shared numeric metric is reported. For
   the *ratio* metrics (speedups, rps ratios — machine-load-independent by
   construction), a drop of more than ``--tol`` fraction below the baseline
   fails the run (growth, for lower-is-better ratios); raw wall-clock/rps
   values are reported but never gated — CI runners are too noisy for
   absolute thresholds. Ratio gates that are only meaningful on specific
   hardware are skipped with a printed reason (the Pallas interpret-mode
   fallback ratio off-TPU; sharded-fleet ``rps_scaling`` on hosts with
   fewer cores than mesh devices). ``max_abs_diff`` (and the sharded
   ``pallas_sharded_max_abs_diff``) is gated absolutely: a row whose
   numerical-parity evidence worsens past ``--max-abs-diff`` (default
   1e-3) fails regardless of the baseline.

A baseline that does not exist (file missing at the ref — e.g. a brand-new
bench) skips the diff for that file with a note; the schema check still
applies. A fresh row the baseline file lacks (a newly added bench row, the
usual way a PR lands a new metric) is a WARN-and-record, never a failure:
its metrics are printed so the CI log archives the first measurement, and
it starts gating once the baseline catches up with it.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

# required metric keys per known row name — the contract between the bench
# writers and the CI artifact consumers
ROW_SCHEMAS: dict[str, set[str]] = {
    "runtime/jit_vs_interpreter": {"interp_ms", "jit_ms", "speedup",
                                   "max_abs_diff"},
    "runtime/single_vs_segmented": {"single_program_ms", "segmented_ms",
                                    "speedup", "max_abs_diff"},
    "runtime/fused_vs_blocked": {"fused_ms", "blocked_ms", "speedup",
                                 "fused_trace_compile_ms",
                                 "blocked_trace_compile_ms",
                                 "fused_jaxpr_ops", "blocked_jaxpr_ops",
                                 "jaxpr_op_reduction", "max_abs_diff"},
    "serving/batched_queue": {"session_rps", "direct_b1_rps",
                              "session_vs_direct_batched",
                              "session_vs_direct_single", "compile_ms",
                              "latency_p50_ms", "latency_p95_ms",
                              "max_abs_diff"},
    "serving/fleet_sharded": {"n_devices", "host_cores",
                              "session_rps_1dev", "session_rps_4dev",
                              "rps_scaling", "continuous_rps",
                              "bucketed_rps", "continuous_vs_bucketed",
                              "pallas_sharded_max_abs_diff",
                              "max_abs_diff"},
    "runtime/pallas_vs_xla": {"xla_ms", "pallas_ms", "pallas_over_xla",
                              "max_abs_diff"},
    "runtime/resnet18_single_program": {"n_instructions", "n_eltwise",
                                        "exec_ms", "gops", "strict_bitwise",
                                        "max_abs_diff_ref"},
    # parity key is dequant_max_abs_err, NOT max_abs_diff: int8 quantization
    # error is ~1e-1 in the dequantized logits by design, and the absolute
    # max_abs_diff gate (1e-3, fp32 bitwise-parity evidence) must not apply
    "runtime/int8_vs_fp32": {"fp32_ms", "int8_ms", "int8_speedup",
                             "top1_agreement_vgg16",
                             "top1_agreement_resnet18",
                             "executor_interp_bitwise",
                             "dequant_max_abs_err", "backend_mode"},
    # warm_over_cold_compile_ratio = warm-process warm_load_ms over
    # cold-process compile_ms: both sides are fresh-interpreter wall
    # clocks for the SAME program on the same host, so the ratio is
    # machine-load-independent and gates as a lower-is-better key
    "serving/aot_cold_start": {"cold_compile_ms", "warm_load_ms",
                               "warm_over_cold_compile_ratio",
                               "max_abs_diff"},
    # survived/accounting_balanced/offenders_isolated are hard booleans
    # (liveness invariant), innocent_max_abs_diff must be exactly 0.0
    # (bisection re-runs the same executor at the same offsets), and
    # isolation_overhead_ratio gates as lower-is-better: both passes run
    # back-to-back in one process, so the ratio is load-independent
    "serving/fault_injection": {"fault_rate", "survived",
                                "accounting_balanced", "offenders_isolated",
                                "retries", "isolated",
                                "isolation_overhead_ratio",
                                "p95_clean_ms", "p95_faulty_ms",
                                "innocent_max_abs_diff"},
}

# higher-is-better ratio metrics: stable across machines, so they gate
RATIO_KEYS = ("speedup", "jaxpr_op_reduction", "session_vs_direct_batched",
              "session_vs_direct_single", "hybrid_speedup",
              "rps_scaling", "continuous_vs_bucketed", "int8_speedup",
              "top1_agreement_vgg16", "top1_agreement_resnet18")

# lower-is-better ratio metrics: gate on growth past tol instead of a drop
LOWER_RATIO_KEYS = ("pallas_over_xla", "warm_over_cold_compile_ratio",
                    "isolation_overhead_ratio")


def _ratio_gate_skipped(name, key, row) -> str | None:
    """Reason to skip ratio-gating this metric, or None to gate normally.

    * ``runtime/pallas_vs_xla`` in ``cpu_interpret`` mode measures the
      Pallas *interpreter* fallback, not kernel performance — its ratio is
      pure interpreter overhead and regresses with any added checking, so
      only the ``tpu`` mode gates.
    * ``rps_scaling`` (serving/fleet_sharded) needs one host core per mesh
      device to show real parallel speedup — on a smaller host the shards
      time-slice and the ratio measures scheduler overhead, so only hosts
      with enough cores gate it.
    """
    if (name == "runtime/pallas_vs_xla"
            and row.get("backend_mode") == "cpu_interpret"):
        return "cpu_interpret mode: ratio measures the interpreter fallback"
    if key == "rps_scaling":
        cores, ndev = row.get("host_cores", 0), row.get("n_devices", 0)
        if not (isinstance(cores, (int, float)) and isinstance(ndev, (int, float))) \
                or cores < ndev:
            return (f"host_cores={cores} < n_devices={ndev}: shards "
                    f"time-slice, scaling is not measurable")
    if (name == "runtime/int8_vs_fp32" and key == "int8_speedup"
            and str(row.get("backend_mode", "")).startswith("cpu")):
        return ("cpu host: XLA emulates int8 MACs in wider arithmetic, "
                "so the ratio measures emulation, not packed-MAC speedup")
    return None


def check_schema(path: Path) -> list[str]:
    errors = []
    try:
        rows = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or not JSON: {e}"]
    if not isinstance(rows, list) or not rows:
        return [f"{path}: expected a non-empty JSON list of row dicts"]
    for i, row in enumerate(rows):
        where = f"{path}[{i}]"
        if not isinstance(row, dict):
            errors.append(f"{where}: not a dict")
            continue
        for key in ("bench", "name"):
            if not isinstance(row.get(key), str):
                errors.append(f"{where}: missing/non-string {key!r}")
        for k, v in row.items():
            if not isinstance(v, (str, int, float, bool)):
                errors.append(f"{where}: key {k!r} has non-scalar "
                              f"value {type(v).__name__}")
        name = row.get("name")
        if not isinstance(name, str):
            continue        # already reported; an unhashable name (e.g. a
                            # list) would crash the ROW_SCHEMAS lookup
        missing = ROW_SCHEMAS.get(name, set()) - set(row)
        if missing:
            errors.append(f"{where} ({row.get('name')}): missing required "
                          f"metric keys {sorted(missing)}")
    return errors


def _load_baseline(path: Path, against: str):
    if against.startswith("git:"):
        ref = against[4:] or "HEAD"
        proc = subprocess.run(
            ["git", "show", f"{ref}:{path.as_posix()}"],
            capture_output=True, text=True, cwd=path.parent or ".")
        if proc.returncode != 0:
            # only a genuinely-absent path is a benign skip (new bench);
            # any other git failure (not a repo, bad ref, absolute path,
            # shallow clone) means the tripwire is misconfigured and must
            # FAIL rather than silently gate nothing
            stderr = proc.stderr.strip()
            if ("does not exist" in stderr
                    or "exists on disk, but not in" in stderr):
                return None, None, f"{path} not present at {ref} (new bench?)"
            return None, f"git show {ref}:{path} failed: {stderr}", None
        try:
            return json.loads(proc.stdout), None, None
        except json.JSONDecodeError as e:
            return None, f"{path} at {ref} is not JSON: {e}", None
    base = Path(against)
    if not base.exists():
        return None, f"baseline {base} does not exist", None
    return json.loads(base.read_text()), None, None


def diff_rows(path: Path, against: str, tol: float,
              max_abs_diff: float) -> list[str]:
    baseline, error, skip_note = _load_baseline(path, against)
    if error is not None:
        return [error]
    if baseline is None:
        print(f"  diff skipped: {skip_note}")
        return []
    base_by_name = {r.get("name"): r for r in baseline}
    errors = []
    fresh_rows = json.loads(path.read_text())
    # a baseline row that disappears entirely is itself a regression — a
    # refactor must not silently drop a metric CI archives
    dropped = set(base_by_name) - {r.get("name") for r in fresh_rows}
    for name in sorted(dropped):
        errors.append(f"{path}: baseline row {name!r} is missing from the "
                      f"fresh artifact (bench dropped?)")
    new_rows = []
    for row in fresh_rows:
        name = row.get("name")
        base = base_by_name.get(name)
        if base is None:
            # warn-and-record, never fail: a new row is how a PR lands a
            # new metric — print its first measurements so the CI log
            # archives them; it gates once the committed baseline has it
            new_rows.append(name)
            metrics = ", ".join(
                f"{k}={v}" for k, v in sorted(row.items())
                if isinstance(v, (int, float)) and not isinstance(v, bool))
            print(f"  WARNING: {name}: new row, no baseline at {against} — "
                  f"recorded, not gated ({metrics})")
            continue
        for k, v in sorted(row.items()):
            bv = base.get(k)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or not isinstance(bv, (int, float)):
                continue
            delta = v - bv
            print(f"  {name}.{k}: {bv} -> {v} ({delta:+.3g})")
            if k in RATIO_KEYS or k in LOWER_RATIO_KEYS:
                skip = _ratio_gate_skipped(name, k, row)
                if skip is not None:
                    print(f"  {name}.{k}: ratio gate skipped ({skip})")
                    continue
            if k in RATIO_KEYS and bv > 0 and v < bv * (1.0 - tol):
                errors.append(
                    f"{path}: {name}.{k} regressed {bv} -> {v} "
                    f"(> {tol:.0%} below baseline)")
            if k in LOWER_RATIO_KEYS and bv > 0 and v > bv * (1.0 + tol):
                errors.append(
                    f"{path}: {name}.{k} regressed {bv} -> {v} "
                    f"(> {tol:.0%} above baseline)")
            if k in ("max_abs_diff", "pallas_sharded_max_abs_diff") \
                    and v > max(bv, max_abs_diff):
                errors.append(
                    f"{path}: {name}.{k} worsened {bv} -> {v} "
                    f"(numerical-parity evidence)")
    if new_rows:
        print(f"  {len(new_rows)} new row(s) recorded without baseline "
              f"({', '.join(sorted(new_rows))}) — they gate once the "
              f"committed artifact includes them")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=None,
                    help="artifact files (default: BENCH_*.json in cwd)")
    ap.add_argument("--against", default=None,
                    help="baseline: a path, or git:<ref> for the committed "
                         "version (e.g. git:HEAD)")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="allowed fractional drop in ratio metrics before "
                         "the diff fails (default 0.5 — CI runners are "
                         "noisy; ratios are load-independent but not "
                         "noise-free)")
    ap.add_argument("--max-abs-diff", type=float, default=1e-3,
                    help="absolute ceiling for max_abs_diff growth")
    args = ap.parse_args()
    files = [Path(f) for f in args.files] or sorted(
        Path(".").glob("BENCH_*.json"))
    if not files:
        print("ERROR: no BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    errors = []
    for path in files:
        print(f"schema check: {path}")
        file_errors = check_schema(path)
        # gate the diff on THIS file's schema only — a malformed sibling
        # artifact must not suppress another file's regression check
        if args.against and not file_errors:
            print(f"diff vs {args.against}:")
            file_errors += diff_rows(path, args.against, args.tol,
                                     args.max_abs_diff)
        errors += file_errors
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"bench check: {'FAIL' if errors else 'OK'} "
          f"({len(files)} artifact file(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
