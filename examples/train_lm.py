"""End-to-end LM training driver (deliverable (b)): trains a ~100M-class
model for a few hundred steps with checkpointing + the deterministic data
pipeline.

On this CPU container the default is a width-reduced config so a few hundred
steps finish in minutes; pass --full-width to train the real mamba2-130m
(slow on CPU, the same code on TPU uses the production mesh).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    losses = train(args.arch, reduced=not args.full_width, steps=args.steps,
                   batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                   ckpt_every=50, lr=3e-3, log_every=10)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
