"""Batched serving with a KV/SSM cache (deliverable (b)): prefill a batch of
prompts, then decode tokens step by step — the same ``serve_step`` the
decode_32k/long_500k dry-run cells lower.

  PYTHONPATH=src python examples/serve_batch.py --arch qwen3-32b --gen 24
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    toks = serve(args.arch, reduced=True, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen)
    assert toks.shape == (args.batch, args.gen)
    print(f"generated {toks.shape} tokens")


if __name__ == "__main__":
    main()
