"""Quickstart: the HybridDNN pipeline end-to-end on a small CNN.

1. Describe CONV layers (ConvSpec) — here a reduced VGG16.
2. Run the DSE (paper Sec. 5) to pick per-layer mode (Spatial/Winograd) and
   dataflow (IS/WS) for both the paper's FPGA targets and the TPU target.
3. Compile the network to the 128-bit instruction stream (Sec. 4.1).
4. Execute the stream on the functional runtime and check it against direct
   execution through the hybrid PE.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as pm
from repro.core.compiler import compile_network
from repro.core.dse import run_fpga_dse, run_tpu_dse
from repro.core.hybrid_conv import hybrid_conv2d
from repro.core.isa import encode_stream
from repro.core.runtime import run_program
from repro.models import vgg


def main():
    img, scale = 32, 16
    specs = vgg.conv_specs(img=img, scale=scale)

    print("== DSE (paper Sec. 5) ==")
    for target, name in ((pm.VU9P, "VU9P"), (pm.PYNQ_Z1, "PYNQ-Z1")):
        r = run_fpga_dse(target, specs)
        print(f"{name}: PI={r.hw.pi} PO={r.hw.po} PT={r.hw.pt} NI={r.hw.ni} "
              f"| {sum(p.mode == 'wino' for p in r.plans)}/13 layers Winograd")
    tr = run_tpu_dse(specs, batch=4)
    print(f"v5e:  blocks=({tr.hw.bm},{tr.hw.bk},{tr.hw.bn}) m={tr.hw.m} "
          f"| {sum(p.mode == 'wino' for p in tr.plans)}/13 layers Winograd")

    # the instruction stream executes one CONV *stage* (the chain between
    # pools — the paper's runtime drives pooling from the host side)
    from repro.core.hybrid_conv import ConvSpec
    from repro.core.compiler import LayerPlan
    specs = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
             ConvSpec("c3", 16, 16, 16, 8)]
    plans = [LayerPlan("wino", "is", m=4, g_h=2, g_k=2),
             LayerPlan("spat", "ws", m=4, g_h=2, g_k=2),
             LayerPlan("wino", "is", m=2)]

    print("\n== compile to the 128-bit ISA (Sec. 4.1) ==")
    prog = compile_network(specs, plans)
    image = encode_stream(prog.instructions)
    print(f"{len(prog.instructions)} instructions "
          f"({image.nbytes} bytes of instruction memory), "
          f"DRAM plan: {prog.dram_size_words} words")

    print("\n== execute the stream vs direct hybrid-PE execution ==")
    key = jax.random.PRNGKey(0)
    conv_params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i))
        conv_params.append(
            (jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
             jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
    x = jax.random.normal(key, (2, 16, 16, 3), jnp.float32)
    y_stream = run_program(prog, conv_params, x)

    y_direct = x
    for spec, (w, b), plan in zip(specs, conv_params, plans):
        y_direct = hybrid_conv2d(y_direct, w, b, mode=plan.mode, m=plan.m,
                                 relu=spec.relu, use_pallas=False)
    err = float(jnp.max(jnp.abs(y_stream - y_direct)))
    print(f"instruction-stream output == direct output: max |err| = {err:.2e}")
    assert err < 5e-3
    print("OK")


if __name__ == "__main__":
    main()
