"""Quickstart: the HybridDNN framework API end-to-end on a reduced VGG16.

The paper's whole design flow is one call — DSE (Sec. 5) -> compile to the
128-bit ISA (Sec. 4.1) -> validate the hazard schedule once -> the cached
jitted executor:

    acc = api.Accelerator.build(specs, target=pm.V5E, batch=4)
    logits = acc(x)

Any DSE backend goes through the same ``Target`` protocol, so the paper's
FPGA devices and the TPU target are interchangeable here. The script also
exercises the save/load path (reuse a compiled Program without re-running
DSE) and the batching ``ServingSession``.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import numpy as np

from repro import api
from repro.core import perf_model as pm
from repro.models import vgg


def main():
    img, scale = 32, 16
    specs = vgg.network_specs(img=img, scale=scale, n_classes=10)
    x = np.random.default_rng(0).standard_normal(
        (2, img, img, 3)).astype(np.float32)

    # -- the 5-line flow: DSE -> compile -> validate -> execute -------------
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=2)
    logits = acc(x)
    print(acc.summary())
    print(f"logits: {logits.shape}\n")

    # -- lowering optimizer: opt_level=1 (default) fuses each layer's
    # per-block loop into one PE dispatch; opt_level=0 is the literal
    # per-block reference lowering it is tested against. Reuse acc's plans:
    # same schedule by construction, and no redundant second DSE search.
    acc_ref = api.Accelerator.build(specs, plans=acc.plans, batch=2,
                                    params=acc.params, opt_level=0)
    err = float(np.max(np.abs(np.asarray(acc_ref(x)) - np.asarray(logits))))
    print(f"opt_level=1 (fused) vs opt_level=0 (blocked): "
          f"max |diff| = {err:.2e}")
    assert err < 1e-5

    # -- one Target protocol, three DSE backends ----------------------------
    for target in (pm.VU9P, pm.PYNQ_Z1):
        r = target.run_dse(specs)
        n_wino = sum(p.mode == "wino" for s, p in zip(specs, r.plans)
                     if isinstance(s, vgg.ConvSpec))
        print(f"{target.name}: PI={r.hw.pi} PO={r.hw.po} PT={r.hw.pt} "
              f"NI={r.hw.ni} | {n_wino}/13 CONVs Winograd "
              f"({r.candidates_searched} candidates)")
    acc_fpga = api.Accelerator.build(specs, target=pm.PYNQ_Z1, batch=2,
                                     params=acc.params)
    err = float(np.max(np.abs(np.asarray(acc_fpga(x)) - np.asarray(logits))))
    print(f"FPGA-planned vs TPU-planned logits: max |diff| = {err:.2e}\n")
    assert err < 5e-3

    # -- save the compiled Program; reload without re-running the DSE -------
    with tempfile.TemporaryDirectory() as d:
        path = acc.save_program(os.path.join(d, "vgg16_reduced.json"))
        acc2 = api.Accelerator.from_program(path, params=acc.params)
        same = np.array_equal(np.asarray(acc2(x)), np.asarray(logits))
        print(f"saved + reloaded Program ({acc2.n_instructions} "
              f"instructions): bitwise-equal logits = {same}")
        assert same

    # -- batched serving: single-image requests coalesce on the queue, and
    # the pipelined dispatch overlaps batch i+1's staging with batch i's
    # device compute -------------------------------------------------------
    with acc.serve(max_batch=4, warmup=True) as session:
        outs = session.run_many([x[i % 2] for i in range(8)])
        jax.block_until_ready(outs[-1])
        # coalesced device batches may differ in shape from the batch-2
        # reference call -> float-associativity tolerance, not bitwise
        ok = all(np.allclose(np.asarray(o), np.asarray(logits[i % 2]),
                             atol=1e-5, rtol=1e-5)
                 for i, o in enumerate(outs))
        print(f"ServingSession: {session.stats.requests} requests in "
              f"{session.stats.batches} device batches "
              f"({session.stats.padded_rows} padded rows, latency "
              f"p50 {session.stats.p50_ms():.2f}ms "
              f"p95 {session.stats.p95_ms():.2f}ms); "
              f"rows match = {ok}")
        assert ok
    print("OK")


if __name__ == "__main__":
    main()
