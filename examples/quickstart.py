"""Quickstart: the HybridDNN pipeline end-to-end on a small CNN.

1. Describe CONV layers (ConvSpec) — here a reduced VGG16.
2. Run the DSE (paper Sec. 5) to pick per-layer mode (Spatial/Winograd) and
   dataflow (IS/WS) for both the paper's FPGA targets and the TPU target.
3. Compile the network to the 128-bit instruction stream (Sec. 4.1).
4. Execute the stream on the functional runtime and check it against direct
   execution through the hybrid PE.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as pm
from repro.core.compiler import compile_network
from repro.core.dse import run_fpga_dse, run_tpu_dse
from repro.core.hybrid_conv import hybrid_conv2d
from repro.core.isa import encode_stream
from repro.core.runtime import run_program
from repro.models import vgg


def main():
    img, scale = 32, 16
    specs = vgg.conv_specs(img=img, scale=scale)

    print("== DSE (paper Sec. 5) ==")
    for target, name in ((pm.VU9P, "VU9P"), (pm.PYNQ_Z1, "PYNQ-Z1")):
        r = run_fpga_dse(target, specs)
        print(f"{name}: PI={r.hw.pi} PO={r.hw.po} PT={r.hw.pt} NI={r.hw.ni} "
              f"| {sum(p.mode == 'wino' for p in r.plans)}/13 layers Winograd")
    tr = run_tpu_dse(specs, batch=4)
    print(f"v5e:  blocks=({tr.hw.bm},{tr.hw.bk},{tr.hw.bn}) m={tr.hw.m} "
          f"| {sum(p.mode == 'wino' for p in tr.plans)}/13 layers Winograd")

    # the instruction stream executes the WHOLE model — CONVs, the 2x2
    # maxpool, and the FC tail compile into one Program (POOL/FC opcodes)
    from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
    from repro.core.compiler import LayerPlan
    specs = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
             ConvSpec("c3", 16, 16, 16, 8),
             PoolSpec("p1", 16, 16, 8),
             FCSpec("fc", 8 * 8 * 8, 10, relu=False)]
    plans = [LayerPlan("wino", "is", m=4, g_h=2, g_k=2),
             LayerPlan("spat", "ws", m=4, g_h=2, g_k=2),
             LayerPlan("wino", "is", m=2), None, None]

    print("\n== compile to the 128-bit ISA (Sec. 4.1) ==")
    prog = compile_network(specs, plans)
    image = encode_stream(prog.instructions)
    print(f"{len(prog.instructions)} instructions "
          f"({image.nbytes} bytes of instruction memory), "
          f"DRAM plan: {prog.dram_size_words} words")

    print("\n== execute the stream vs direct hybrid-PE execution ==")
    from repro.core.hybrid_conv import dense, max_pool2d
    key = jax.random.PRNGKey(0)
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i))
        if isinstance(s, ConvSpec):
            params.append(
                (jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
                 jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
        elif isinstance(s, FCSpec):
            params.append(
                (jax.random.normal(kw, (s.d_in, s.d_out), jnp.float32) * 0.1,
                 jnp.zeros((s.d_out,), jnp.float32)))
    x = jax.random.normal(key, (2, 16, 16, 3), jnp.float32)
    y_stream = run_program(prog, params, x)

    y_direct, pi = x, 0
    for spec, plan in zip(specs, plans):
        if isinstance(spec, PoolSpec):
            y_direct = max_pool2d(y_direct, spec.window, spec.stride)
        elif isinstance(spec, FCSpec):
            w, b = params[pi]; pi += 1
            y_direct = dense(y_direct.reshape(y_direct.shape[0], -1), w, b,
                             relu=spec.relu)
        else:
            w, b = params[pi]; pi += 1
            y_direct = hybrid_conv2d(y_direct, w, b, mode=plan.mode, m=plan.m,
                                     relu=spec.relu, use_pallas=False)
    err = float(jnp.max(jnp.abs(y_stream - y_direct)))
    print(f"instruction-stream logits == direct logits: max |err| = {err:.2e}")
    assert err < 5e-3
    print("OK")


if __name__ == "__main__":
    main()
