"""Fault-tolerance demo (deliverable (b) + Sec. 5 'large-scale runnability'):

* trains with async checkpointing,
* a simulated node failure mid-run triggers restart-from-latest,
* the deterministic data pipeline makes recovery bit-exact,
* finally the checkpoint is restored onto a DIFFERENT mesh shape
  (elastic re-meshing) and training continues.

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.checkpoint.fault_tolerance import (
    HeartbeatMonitor, run_with_recovery,
)
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.optim import adamw
from repro.train import steps as steps_lib


def main():
    cfg = get_config("minitron-8b").reduced()
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    params = steps_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt_cfg))
    data_cfg = DataConfig(cfg.vocab_size, 32, 4)
    monitor = HeartbeatMonitor(n_workers=1)

    crashed = {"done": False}

    def train_one(state, step):
        params, opt_state = state
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated preemption of worker 0")
        batch = batch_for_step(data_cfg, step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        monitor.report(0, 0.1)
        if step % 5 == 0:
            print(f"  step {step}: loss {float(metrics['loss']):.4f}")
        return (params, opt_state)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        print("== training with a simulated failure at step 12 ==")
        (params, opt_state), log = run_with_recovery(
            train_one, (params, opt_state), n_steps=20,
            ckpt_dir=ckpt_dir, ckpt_every=5)
        print(f"restarts: {log['restarts']} (recovered and finished 20 steps)")

        print("\n== elastic re-mesh: restore onto a different mesh ==")
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("model",))
        # a different (here trivial) mesh: every leaf re-placed by device_put
        restored, step = ckpt_lib.restore(ckpt_dir, (params, opt_state))
        print(f"restored step {step}; continuing 5 more steps on new mesh")
        params, opt_state = restored
        for s in range(step, step + 5):
            batch = batch_for_step(data_cfg, s)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        print(f"final loss {float(metrics['loss']):.4f}")
    print("OK")


if __name__ == "__main__":
    main()
