"""Table 4 reproduction: end-to-end VGG16 throughput (GOPS).

* paper-faithful: the FPGA DSE re-derives the paper's configurations and the
  Eq. 6-15 latency model reproduces the published GOPS (VU9P 3375.7 /
  PYNQ-Z1 83.3).
* hybrid-vs-spatial-only: the paper's headline 1.8x-class gain, measured by
  forcing all-Spatial plans through the same model.
* TPU analog: the hardware-adapted model's GOPS for the v5e target.
"""
from __future__ import annotations

import dataclasses

from repro.core import perf_model as pm
from repro.core.dse import DSEResult, run_fpga_dse, run_tpu_dse
from repro.models.vgg import conv_specs

PAPER_GOPS = {"VU9P": 3375.7, "PYNQ-Z1": 83.3}


def _gops(specs, total_latency):
    return sum(2 * s.macs for s in specs) / 1e9 / total_latency


def _spatial_only_latency(target, specs, hw) -> float:
    t_inst = dataclasses.replace(target, bw=target.bw / hw.ni)
    total = 0.0
    for spec in specs:
        best = min(
            pm.fpga_layer_latency(t_inst, spec, hw.pi, hw.po, hw.pt, hw.m,
                                  "spat", df)
            for df in ("is", "ws"))
        total += best / hw.ni
    return total


def run() -> list[dict]:
    specs = conv_specs()
    rows = []
    for target, name in ((pm.VU9P, "VU9P"), (pm.PYNQ_Z1, "PYNQ-Z1")):
        r: DSEResult = run_fpga_dse(target, specs)
        gops = _gops(specs, r.total_latency)
        err = abs(gops - PAPER_GOPS[name]) / PAPER_GOPS[name] * 100
        rows.append({
            "bench": "table4_vgg16", "name": f"{name}/hybrid",
            "config": f"PI{r.hw.pi}_PO{r.hw.po}_PT{r.hw.pt}_NI{r.hw.ni}",
            "gops": round(gops, 1), "paper": PAPER_GOPS[name],
            "err_pct": round(err, 2),
            "wino_layers": sum(p.mode == "wino" for p in r.plans),
        })
        spat_lat = _spatial_only_latency(target, specs, r.hw)
        gops_spat = _gops(specs, spat_lat)
        rows.append({
            "bench": "table4_vgg16", "name": f"{name}/spatial_only",
            "gops": round(gops_spat, 1),
            "hybrid_speedup": round(gops / gops_spat, 2),
        })
    rt = run_tpu_dse(specs, batch=8)
    rows.append({
        "bench": "table4_vgg16", "name": "v5e/tpu_dse",
        "config": f"bm{rt.hw.bm}_bk{rt.hw.bk}_bn{rt.hw.bn}_m{rt.hw.m}",
        "gops": round(8 * _gops(specs, rt.total_latency), 1),
        "wino_layers": sum(p.mode == "wino" for p in rt.plans),
    })
    return rows
