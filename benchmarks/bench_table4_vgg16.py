"""Table 4 reproduction: end-to-end VGG16 throughput (GOPS).

* paper-faithful: the FPGA DSE re-derives the paper's configurations and the
  Eq. 6-15 latency model reproduces the published GOPS (VU9P 3375.7 /
  PYNQ-Z1 83.3).
* hybrid-vs-spatial-only: the paper's headline 1.8x-class gain, measured by
  forcing all-Spatial plans through the same model.
* TPU analog: the hardware-adapted model's GOPS for the v5e target.
* runtime rows: interpreter vs cached-jitted executor, the full-network
  single-Program path vs the legacy segmented path, the lowering optimizer
  (``opt_level=1`` fused whole-layer dispatches) vs the literal per-block
  lowering, the batching pipelined ``ServingSession`` queue vs direct
  ``rt.run`` loops, the sharded-fleet serving row (shard_map'd executors
  over forced host devices + continuous-vs-bucketed scheduling), the
  Pallas PE backend vs the XLA lowering, and the quantized int8 accelerator
  vs fp32 (throughput ratio + top-1 agreement on reduced VGG16 and
  ResNet-18) — the runtime + serving rows are written to a
  ``BENCH_table4_vgg16.json`` artifact for CI; ``tools/bench_compare.py``
  schema-checks it and diffs against the committed file as a regression
  tripwire.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro.core import perf_model as pm
from repro.core.dse import DSEResult, run_fpga_dse, run_tpu_dse
from repro.models.vgg import conv_specs, conv_segments, network_specs

PAPER_GOPS = {"VU9P": 3375.7, "PYNQ-Z1": 83.3}


def _gops(specs, total_latency):
    return sum(2 * s.macs for s in specs) / 1e9 / total_latency


def _spatial_only_latency(target, specs, hw) -> float:
    t_inst = dataclasses.replace(target, bw=target.bw / hw.ni)
    total = 0.0
    for spec in specs:
        best = min(
            pm.fpga_layer_latency(t_inst, spec, hw.pi, hw.po, hw.pt, hw.m,
                                  "spat", df)
            for df in ("is", "ws"))
        total += best / hw.ni
    return total


def run() -> list[dict]:
    specs = conv_specs()
    rows = []
    for target, name in ((pm.VU9P, "VU9P"), (pm.PYNQ_Z1, "PYNQ-Z1")):
        r: DSEResult = run_fpga_dse(target, specs)
        gops = _gops(specs, r.total_latency)
        err = abs(gops - PAPER_GOPS[name]) / PAPER_GOPS[name] * 100
        rows.append({
            "bench": "table4_vgg16", "name": f"{name}/hybrid",
            "config": f"PI{r.hw.pi}_PO{r.hw.po}_PT{r.hw.pt}_NI{r.hw.ni}",
            "gops": round(gops, 1), "paper": PAPER_GOPS[name],
            "err_pct": round(err, 2),
            "wino_layers": sum(p.mode == "wino" for p in r.plans),
        })
        spat_lat = _spatial_only_latency(target, specs, r.hw)
        gops_spat = _gops(specs, spat_lat)
        rows.append({
            "bench": "table4_vgg16", "name": f"{name}/spatial_only",
            "gops": round(gops_spat, 1),
            "hybrid_speedup": round(gops / gops_spat, 2),
        })
    rt = run_tpu_dse(specs, batch=8)
    rows.append({
        "bench": "table4_vgg16", "name": "v5e/tpu_dse",
        "config": f"bm{rt.hw.bm}_bk{rt.hw.bk}_bn{rt.hw.bn}_m{rt.hw.m}",
        "gops": round(8 * _gops(specs, rt.total_latency), 1),
        "wino_layers": sum(p.mode == "wino" for p in rt.plans),
    })
    runtime_rows = run_runtime_comparison()
    runtime_rows += run_single_vs_segmented()
    runtime_rows += run_fused_vs_blocked()
    runtime_rows += run_serving_queue()
    runtime_rows += run_fleet_sharded()
    runtime_rows += run_pallas_vs_xla()
    runtime_rows += run_resnet18_single_program()
    runtime_rows += run_int8_vs_fp32()
    runtime_rows += run_aot_cold_start()
    runtime_rows += run_fault_injection()
    _write_artifact(runtime_rows)
    return rows + runtime_rows


def _write_artifact(rows: list[dict],
                    artifact: str = "BENCH_table4_vgg16.json"):
    with open(artifact, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {os.path.abspath(artifact)}")


def run_runtime_comparison(*, img: int = 32, scale: int = 16, batch: int = 2,
                           iters: int = 10) -> list[dict]:
    """Interpreter vs cached-jitted-executor wall clock on the reduced VGG16
    stack — the validate-once/trace-many payoff measured end-to-end.

    Plans alternate Winograd/Spatial so the comparison exercises both CONV
    modes, the U-space weight path, and the WINO<->SPAT layout reorders.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.compiler import LayerPlan, compile_network
    from repro.core.hybrid_conv import max_pool2d
    from repro.core.runtime import HybridRuntime

    specs = conv_specs(img=img, scale=scale)
    plans = [LayerPlan("wino" if i % 2 == 0 else "spat", "is" if i % 2 else "ws",
                       m=2, g_k=2, g_h=2) for i, _ in enumerate(specs)]
    rng = np.random.default_rng(0)
    params = [(jnp.asarray(rng.standard_normal((s.r, s.s, s.c, s.k)),
                           jnp.float32) * (s.r * s.s * s.c) ** -0.5,
               jnp.zeros((s.k,), jnp.float32)) for s in specs]
    x = jnp.asarray(rng.standard_normal((batch, img, img, specs[0].c)),
                    jnp.float32)

    jit_rts, strict_rts, idx = [], [], 0
    for n in conv_segments():
        program = compile_network(specs[idx:idx + n], plans[idx:idx + n])
        for strict, dst in ((False, jit_rts), (True, strict_rts)):
            r = HybridRuntime(program, strict=strict)
            r.load_params(params[idx:idx + n])
            dst.append(r)
        idx += n

    def request(rts, x):
        for r in rts:
            x = max_pool2d(r.run(x))
        return x

    # warm BOTH paths before timing so neither side pays first-use XLA op
    # compilation inside the measured region
    y_jit = jax.block_until_ready(request(jit_rts, x))   # validate + compile
    jax.block_until_ready(request(strict_rts, x))
    t0 = time.monotonic()
    for _ in range(iters):
        y_jit = jax.block_until_ready(request(jit_rts, x))
    t_jit = (time.monotonic() - t0) / iters

    t0 = time.monotonic()
    y_int = jax.block_until_ready(request(strict_rts, x))
    t_int = time.monotonic() - t0
    err = float(jnp.max(jnp.abs(y_jit - y_int)))

    return [{
        "bench": "table4_vgg16", "name": "runtime/jit_vs_interpreter",
        "config": f"img{img}_scale{scale}_batch{batch}",
        "interp_ms": round(t_int * 1e3, 1),
        "jit_ms": round(t_jit * 1e3, 2),
        "speedup": round(t_int / t_jit, 1),
        "max_abs_diff": err,
    }]


def _alternating_plans(specs):
    """Fixed wino/spat-alternating CONV plans — pins the schedule so the
    runtime rows measure execution, not DSE variance."""
    from repro.core.compiler import LayerPlan
    from repro.core.hybrid_conv import ConvSpec

    ci, plans = 0, []
    for s in specs:
        if isinstance(s, ConvSpec):
            plans.append(LayerPlan("wino" if ci % 2 == 0 else "spat",
                                   "is" if ci % 2 else "ws", m=2,
                                   g_k=2, g_h=2))
            ci += 1
        else:
            plans.append(None)
    return plans


def run_single_vs_segmented(*, img: int = 32, scale: int = 16, batch: int = 2,
                            iters: int = 10) -> list[dict]:
    """Full-network ISA payoff: the whole reduced VGG16 (13 CONV + 5 POOL +
    3 FC) as ONE Program vs the legacy per-segment Programs with host-side
    maxpool/FC glue — end-to-end wall clock on the cached jitted executors.

    ``run()`` writes this row (plus the serving row) to
    ``BENCH_table4_vgg16.json`` so CI can archive it as a run artifact.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api

    specs = network_specs(img=img, scale=scale, n_classes=10)
    plans = _alternating_plans(specs)
    acc = api.Accelerator.build(specs, plans=plans, seed=0, batch=batch)
    acc_seg = api.Accelerator.build(specs, plans=plans, params=acc.params,
                                    batch=batch, segmented=True)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, img, img, 3)), jnp.float32)

    y_single = jax.block_until_ready(acc(x))        # validate + jit both
    y_seg = jax.block_until_ready(acc_seg(x))
    t0 = time.monotonic()
    for _ in range(iters):
        y_single = jax.block_until_ready(acc(x))
    t_single = (time.monotonic() - t0) / iters
    t0 = time.monotonic()
    for _ in range(iters):
        y_seg = jax.block_until_ready(acc_seg(x))
    t_seg = (time.monotonic() - t0) / iters

    return [{
        "bench": "table4_vgg16", "name": "runtime/single_vs_segmented",
        "config": f"img{img}_scale{scale}_batch{batch}",
        "n_instructions": acc.n_instructions,
        "single_program_ms": round(t_single * 1e3, 2),
        "segmented_ms": round(t_seg * 1e3, 2),
        "speedup": round(t_seg / t_single, 2),
        "max_abs_diff": float(jnp.max(jnp.abs(y_single - y_seg))),
    }]


def _jaxpr_ops(jaxpr) -> int:
    """Primitive-equation count, recursing into nested (pjit/scan) bodies —
    the graph-size metric the lowering optimizer is judged on."""
    n = 0
    for eq in jaxpr.eqns:
        n += 1
        for v in eq.params.values():
            for vv in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(vv, "jaxpr"):
                    n += _jaxpr_ops(vv.jaxpr)
    return n


def run_fused_vs_blocked(*, img: int = 32, scale: int = 16, batch: int = 2,
                         iters: int = 20) -> list[dict]:
    """Lowering-optimizer payoff on the full reduced VGG16 (13 CONV +
    5 POOL + 3 FC, ONE Program): ``opt_level=1`` (whole-layer fused
    dispatches) vs ``opt_level=0`` (the literal per-block lowering) —
    steady-state wall clock, trace+compile time, and traced-graph op count
    (``jax.make_jaxpr`` equation count), plus max |diff| between the two.

    Plans alternate Winograd/Spatial with g_h=2/g_k=2 so every layer has a
    real block structure to fuse (4 COMP blocks per CONV layer).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.core.compiler import compile_network
    from repro.core.executor import (
        compile_executor,
        lower_program,
        to_dram_params,
        validate_schedule,
    )

    specs = network_specs(img=img, scale=scale, n_classes=10)
    plans = _alternating_plans(specs)
    program = compile_network(specs, plans)
    stats = validate_schedule(program)
    params = api.random_params(specs, seed=0)
    dram = to_dram_params(program, params)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, img, img, 3)), jnp.float32)

    out: dict = {"bench": "table4_vgg16", "name": "runtime/fused_vs_blocked",
                 "config": f"img{img}_scale{scale}_batch{batch}"}
    execs, ys = {}, {}
    for lvl, tag in ((1, "fused"), (0, "blocked")):
        ex = compile_executor(program, stats=stats, opt_level=lvl)
        t0 = time.monotonic()                 # first call: trace + compile
        ys[tag] = jax.block_until_ready(ex(dram, x))
        out[f"{tag}_trace_compile_ms"] = round(
            (time.monotonic() - t0) * 1e3, 1)
        out[f"{tag}_jaxpr_ops"] = _jaxpr_ops(jax.make_jaxpr(
            lower_program(program, opt_level=lvl))(dram, x).jaxpr)
        execs[tag] = ex
    # interleaved best-of-rounds: a single long loop per level charges
    # whichever runs first for machine warm-up — alternating short rounds
    # and keeping each level's best is robust to drift either way
    wall = {"fused": float("inf"), "blocked": float("inf")}
    for _ in range(3):
        for tag, ex in execs.items():
            t0 = time.monotonic()
            for _ in range(iters):
                jax.block_until_ready(ex(dram, x))
            wall[tag] = min(wall[tag], (time.monotonic() - t0) / iters)
    out["fused_ms"] = round(wall["fused"] * 1e3, 2)
    out["blocked_ms"] = round(wall["blocked"] * 1e3, 2)
    out["speedup"] = round(wall["blocked"] / wall["fused"], 2)
    out["jaxpr_op_reduction"] = round(
        out["blocked_jaxpr_ops"] / out["fused_jaxpr_ops"], 2)
    out["max_abs_diff"] = float(jnp.max(jnp.abs(ys["fused"]
                                                - ys["blocked"])))
    return [out]


def run_pallas_vs_xla(*, img: int = 32, scale: int = 16, batch: int = 2,
                      iters: int = 5) -> list[dict]:
    """PE-backend comparison on the cached jitted executor: the same reduced
    VGG16 Program lowered through the XLA ops vs the Pallas PE kernels
    (``Accelerator.build(..., backend="pallas")``), with max |diff|.

    On CPU/CI the Pallas path runs in interpret mode, so ``pallas_ms`` there
    measures the fallback, not kernel performance — the row's job off-TPU is
    the numerical-parity evidence and keeping the path exercised; on real
    TPU it becomes the kernel-vs-XLA speed row. ``backend_mode`` records
    which of the two was measured.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api

    specs = network_specs(img=img, scale=scale, n_classes=10)
    plans = _alternating_plans(specs)
    acc_xla = api.Accelerator.build(specs, plans=plans, seed=0, batch=batch)
    acc_pal = api.Accelerator.build(specs, plans=plans, params=acc_xla.params,
                                    batch=batch, backend="pallas")
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, img, img, 3)), jnp.float32)

    y_xla = jax.block_until_ready(acc_xla(x))       # trace + compile both
    y_pal = jax.block_until_ready(acc_pal(x))
    t0 = time.monotonic()
    for _ in range(iters):
        y_xla = jax.block_until_ready(acc_xla(x))
    t_xla = (time.monotonic() - t0) / iters
    t0 = time.monotonic()
    for _ in range(iters):
        y_pal = jax.block_until_ready(acc_pal(x))
    t_pal = (time.monotonic() - t0) / iters

    on_tpu = jax.default_backend() == "tpu"
    return [{
        "bench": "table4_vgg16", "name": "runtime/pallas_vs_xla",
        "config": f"img{img}_scale{scale}_batch{batch}",
        "backend_mode": "tpu" if on_tpu else "cpu_interpret",
        "xla_ms": round(t_xla * 1e3, 2),
        "pallas_ms": round(t_pal * 1e3, 2),
        "pallas_over_xla": round(t_pal / t_xla, 2),
        "max_abs_diff": float(jnp.max(jnp.abs(y_xla - y_pal))),
    }]


def run_resnet18_single_program(*, img: int = 64, scale: int = 8,
                                batch: int = 2, iters: int = 10
                                ) -> list[dict]:
    """Residual-workload row: the reduced ResNet-18 (20 CONV + 8 ELTWISE_ADD
    + 1 POOL + 1 FC, skip tensors held live across each block by the DRAM
    planner) as ONE Program on the cached jitted executor — steady-state
    wall clock and GOPS, with the strict per-instruction interpreter and the
    spec-chain reference oracle as the numerical cross-checks.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.runtime import HybridRuntime
    from repro.models import resnet

    specs = resnet.resnet18_specs(img, scale, n_classes=10)
    t0 = time.monotonic()
    acc = resnet.accelerator(img=img, scale=scale, n_classes=10, batch=batch)
    t_build = time.monotonic() - t0
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (batch, img, img, 3)), jnp.float32)

    y = jax.block_until_ready(acc(x))
    t0 = time.monotonic()
    for _ in range(iters):
        y = jax.block_until_ready(acc(x))
    t_exec = (time.monotonic() - t0) / iters

    strict = HybridRuntime(acc.program, strict=True)
    strict.load_params(acc.params)
    y_strict = strict.run(x)
    y_ref = resnet.reference_forward(acc.params, x, specs)
    macs = sum(s.macs for s in specs)
    return [{
        "bench": "table4_vgg16", "name": "runtime/resnet18_single_program",
        "config": f"img{img}_scale{scale}_batch{batch}",
        "n_instructions": acc.n_instructions,
        "n_eltwise": sum(strict.stats[k] for k in ("eltwise",)),
        "build_ms": round(t_build * 1e3, 1),
        "exec_ms": round(t_exec * 1e3, 2),
        "gops": round(2 * macs * batch / 1e9 / t_exec, 1),
        "strict_bitwise": bool(jnp.array_equal(y, y_strict)),
        "max_abs_diff_ref": float(jnp.max(jnp.abs(y - y_ref))),
    }]


def run_int8_vs_fp32(*, img: int = 32, scale: int = 16, batch: int = 2,
                     n_eval: int = 256, n_calib: int = 256,
                     iters: int = 10) -> list[dict]:
    """Quantized-inference row: the int8 accelerator (calibrated sidecar,
    int8 PEs with the fused requantize+ReLU epilogue, int8-aware DSE) vs
    the fp32 build of the same reduced VGG16 — steady-state wall clock,
    plus top-1 agreement on ``n_eval`` images for BOTH reduced VGG16 and
    reduced ResNet-18, the executor-vs-strict-interpreter bitwise check on
    the int8 path, and the dequantized-logit error vs fp32.

    The agreement models are ``scale=4`` VGG16 and ``scale=8`` ResNet-18
    (minmax observer, ``n_calib`` calibration images): per-tensor int8
    activation grids need enough channels for rounding noise to
    self-average, and at ``scale=16`` the narrowest VGG layers are FOUR
    channels wide — a breakdown regime no calibration fixes (measured
    ~0.90 agreement there vs >=0.98 at scale=4). The timing pair stays at
    the table's ``scale=16`` config so the wall-clock row is comparable
    with the rest of the bench.

    ``backend_mode`` records where the ratio was measured: on a CPU host
    XLA *emulates* int8 MACs in wider arithmetic, so ``int8_speedup``
    there measures emulation cost, not the packed-MAC win — the regression
    guard only gates the ratio on hardware with real int8 paths, exactly
    like ``pallas_vs_xla``'s interpret-mode caveat. The parity metric is
    named ``dequant_max_abs_err`` (NOT ``max_abs_diff``): ~1e-1 logit
    error is the quantization design point, not a numerical regression.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.models import resnet

    rng = np.random.default_rng(0)
    x_np = rng.standard_normal((batch, img, img, 3)).astype(np.float32)
    specs = network_specs(img=img, scale=scale, n_classes=10)
    acc32 = api.Accelerator.build(specs, target=pm.V5E, seed=0, batch=batch)
    acc8 = api.Accelerator.build(specs, target=pm.V5E, seed=0, batch=batch,
                                 params=acc32.params, dtype="int8",
                                 calib=x_np)
    x = jnp.asarray(x_np)
    y32 = jax.block_until_ready(acc32(x))      # trace + compile both
    y8 = jax.block_until_ready(acc8(x))

    # interleaved best-of-rounds (same rationale as run_fused_vs_blocked)
    wall = {"fp32": float("inf"), "int8": float("inf")}
    for _ in range(3):
        for tag, acc in (("fp32", acc32), ("int8", acc8)):
            t0 = time.monotonic()
            for _ in range(iters):
                jax.block_until_ready(acc(x))
            wall[tag] = min(wall[tag], (time.monotonic() - t0) / iters)

    # int8 executor must match the strict int8 interpreter BITWISE —
    # integer accumulation is exact, so any lowering rewrite that broke
    # the requantize ordering would show up here as a hard False
    y8_raw = acc8._request(x)
    y8_strict = acc8.strict_request()(x)
    bitwise = bool(jnp.array_equal(y8_raw, y8_strict))

    # top-1 agreement: fp32 vs int8 argmax over the eval set, one pair of
    # builds per model at the agreement configs documented above
    calib = rng.standard_normal((n_calib, img, img, 3)).astype(np.float32)
    xe = jnp.asarray(rng.standard_normal(
        (n_eval, img, img, 3)), jnp.float32)

    def _agreement(aspecs) -> tuple[float, bool]:
        a32 = api.Accelerator.build(aspecs, target=pm.V5E, seed=0,
                                    batch=batch)
        a8 = api.Accelerator.build(aspecs, target=pm.V5E, seed=0,
                                   batch=batch, params=a32.params,
                                   dtype="int8", calib=calib,
                                   observer="minmax")
        agree = float(jnp.mean(
            jnp.argmax(a8(xe), -1) == jnp.argmax(a32(xe), -1)))
        bit = bool(jnp.array_equal(a8._request(a8.quant.quantize_input(xe)),
                                   a8.strict_request()(xe)))
        return agree, bit

    agree_vgg, v_bitwise = _agreement(
        network_specs(img=img, scale=4, n_classes=10))
    agree_resnet, r_bitwise = _agreement(
        resnet.resnet18_specs(img=img, scale=8, n_classes=10))

    on_tpu = jax.default_backend() == "tpu"
    return [{
        "bench": "table4_vgg16", "name": "runtime/int8_vs_fp32",
        "config": (f"img{img}_scale{scale}_batch{batch}"
                   f"_eval{n_eval}_calib{n_calib}"),
        "backend_mode": "tpu" if on_tpu else "cpu",
        "fp32_ms": round(wall["fp32"] * 1e3, 2),
        "int8_ms": round(wall["int8"] * 1e3, 2),
        "int8_speedup": round(wall["fp32"] / wall["int8"], 2),
        "top1_agreement_vgg16": agree_vgg,
        "top1_agreement_resnet18": agree_resnet,
        "executor_interp_bitwise": bitwise and v_bitwise and r_bitwise,
        "dequant_max_abs_err": float(jnp.max(jnp.abs(y8 - y32))),
    }]


def run_serving_queue(*, img: int = 32, scale: int = 16, batch: int = 8,
                      n_requests: int = 128) -> list[dict]:
    """ServingSession throughput: single-image requests coalesced by the
    padding-bucketed batching queue vs direct ``rt.run`` loops.

    ``direct_b{batch}_rps`` is the best case the session must sustain (the
    caller already batched perfectly); ``direct_b1_rps`` is what unbatched
    serving actually gets per request — the gap between the two is the
    batching payoff the queue recovers for independent single-image
    requests. With the pipelined dispatch (batch i+1 staged while batch i
    executes) the session is expected to *beat* the direct pre-batched
    loop (``session_vs_direct_batched`` >= 1.0), since the direct loop
    host-syncs between batches. The row also records the session's
    trace+compile time and steady-state p50/p95 request latency.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api

    specs = network_specs(img=img, scale=scale, n_classes=10)
    plans = _alternating_plans(specs)
    acc = api.Accelerator.build(specs, plans=plans, seed=0, batch=batch)
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.standard_normal((batch, img, img, 3)), jnp.float32)
    x1 = xb[:1]

    yb = jax.block_until_ready(acc(xb))             # warm both batch shapes
    jax.block_until_ready(acc(x1))
    iters = max(1, n_requests // batch)

    # materialize the request list up front — clients arrive with their own
    # host arrays; slicing xb per request inside the timed region would
    # charge the session for 64 jax dispatch calls the direct loop never pays
    reqs = [np.asarray(xb[i % batch]) for i in range(n_requests)]
    yb_np = np.asarray(yb)
    # interleaved best-of-rounds: direct loop and session alternate inside
    # each round so shared-machine load hits both sides alike — a single
    # long measurement per side charges whichever ran during a noisy
    # stretch for the whole comparison
    direct_bN_rps = direct_b1_rps = session_rps = 0.0
    p50 = p95 = 0.0
    with acc.serve(max_batch=batch, buckets=(batch,), warmup=True) as s:
        compile_ms = s.stats.compile_ms
        s.run_many(reqs[:batch * 2])        # warm the dispatch/drain threads
        warm_batches = s.stats.batches
        for _ in range(3):
            t0 = time.monotonic()
            for _ in range(iters):
                jax.block_until_ready(acc(xb))
            direct_bN_rps = max(direct_bN_rps,
                                batch * iters / (time.monotonic() - t0))
            s.stats.latencies_ms.clear()    # percentiles: this pass only
            t0 = time.monotonic()
            outs = s.run_many(reqs)
            jax.block_until_ready(outs[-1])
            rps = n_requests / (time.monotonic() - t0)
            if rps > session_rps:
                session_rps = rps
                p50, p95 = s.stats.p50_ms(), s.stats.p95_ms()
            t0 = time.monotonic()
            for _ in range(n_requests // 2):
                jax.block_until_ready(acc(x1))
            direct_b1_rps = max(
                direct_b1_rps, (n_requests // 2) / (time.monotonic() - t0))
        err = max(float(np.max(np.abs(np.asarray(o) - yb_np[i % batch])))
                  for i, o in enumerate(outs))
        n_batches = (s.stats.batches - warm_batches) // 3
        padded = s.stats.padded_rows

    return [{
        "bench": "table4_vgg16", "name": "serving/batched_queue",
        "scheduler": "continuous",
        "config": f"img{img}_scale{scale}_maxbatch{batch}_n{n_requests}",
        "session_rps": round(session_rps, 1),
        f"direct_b{batch}_rps": round(direct_bN_rps, 1),
        "direct_b1_rps": round(direct_b1_rps, 1),
        "session_vs_direct_batched": round(session_rps / direct_bN_rps, 2),
        "session_vs_direct_single": round(session_rps / direct_b1_rps, 2),
        "device_batches": n_batches, "padded_rows": padded,
        "compile_ms": round(compile_ms, 1),
        "latency_p50_ms": round(p50, 2),
        "latency_p95_ms": round(p95, 2),
        "max_abs_diff": err,
    }]


# cold-start subprocess body: argv[1] is "cold" (plain program.json — trace
# + compile on first use) or "warm" (AOT bundle — deserialize the saved
# executables), argv[2] the saved path. Each runs under a FRESH interpreter
# so the measurement is an honest process cold start, not a warm-cache replay.
_AOT_COLD_START_SUBPROC = r"""
import json, sys, time
import numpy as np
from repro import api
from repro.core.program_cache import ProgramCache

mode, path = sys.argv[1], sys.argv[2]
img, batch, n_req = 32, 8, 32
doc_path = path + "/program.json" if mode == "cold" else path
with open(path + "/program.json") as f:
    doc = json.load(f)
specs = [api._spec_from_dict(d) for d in doc["specs"]]
params = api.random_params(specs, seed=0)

t0 = time.monotonic()
acc = api.Accelerator.from_program(doc_path, params=params,
                                   cache=ProgramCache())
rng = np.random.default_rng(0)
reqs = [rng.standard_normal((img, img, 3)).astype(np.float32)
        for _ in range(n_req)]
with acc.serve(max_batch=batch, buckets=(batch,), warmup=True) as s:
    outs = s.run_many(reqs)
    ready_ms = (time.monotonic() - t0) * 1e3
    st = s.stats
print("AOT_ROW:" + json.dumps({
    "compile_ms": st.compile_ms, "warm_load_ms": st.warm_load_ms,
    "ready_ms": ready_ms,
    "outs": [np.asarray(y).tolist() for y in outs]}))
"""


def run_aot_cold_start(*, img: int = 32, scale: int = 16,
                       batch: int = 8) -> list[dict]:
    """AOT cold-start row: a fresh process loading the serialized-executable
    bundle (``save_program(..., aot=True)``) vs a fresh process compiling
    the same program from its ``program.json`` — the autoscaling-event
    number the artifact layer exists for.

    The parent builds the serving row's reduced VGG16 and saves both forms;
    each side then runs under its own interpreter (the only honest cold
    start — in-process "cold" timings inherit warm XLA/jax state). The row
    records the cold process's ``compile_ms``, the warm process's
    ``warm_load_ms`` (its ``compile_ms`` must be 0 — enforced here), their
    ratio (gated lower-is-better by ``tools/bench_compare.py``; the issue
    targets <= 0.10), end-to-end process-ready wall clocks, and the max
    |diff| between the two processes' outputs — bitwise 0.0 by construction,
    since a deserialized executable IS the compiled program.
    """
    import subprocess
    import sys
    import tempfile

    import numpy as np

    from repro import api

    specs = network_specs(img=img, scale=scale, n_classes=10)
    plans = _alternating_plans(specs)
    acc = api.Accelerator.build(specs, plans=plans, seed=0, batch=batch)

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")

    def _run(mode, path):
        r = subprocess.run(
            [sys.executable, "-c", _AOT_COLD_START_SUBPROC, mode, path],
            capture_output=True, text=True, env=env, timeout=900)
        if r.returncode != 0:
            raise RuntimeError(f"aot_cold_start {mode} subprocess failed:\n"
                               f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}")
        line = next(l for l in r.stdout.splitlines()
                    if l.startswith("AOT_ROW:"))
        return json.loads(line[len("AOT_ROW:"):])

    with tempfile.TemporaryDirectory() as tmp:
        bundle = os.path.join(tmp, "bundle")
        acc.save_program(bundle, aot=True, buckets=(batch,))
        cold = _run("cold", bundle)
        warm = _run("warm", bundle)

    if warm["compile_ms"] != 0.0:
        raise RuntimeError(f"warm process compiled "
                           f"({warm['compile_ms']:.1f}ms != 0) — the AOT "
                           f"bundle was not used")
    diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
               for a, b in zip(cold["outs"], warm["outs"]))
    return [{
        "bench": "table4_vgg16", "name": "serving/aot_cold_start",
        "config": f"img{img}_scale{scale}_batch{batch}",
        "cold_compile_ms": round(cold["compile_ms"], 1),
        "warm_load_ms": round(warm["warm_load_ms"], 1),
        "warm_over_cold_compile_ratio": round(
            warm["warm_load_ms"] / cold["compile_ms"], 3),
        "cold_ready_ms": round(cold["ready_ms"], 1),
        "warm_ready_ms": round(warm["ready_ms"], 1),
        "max_abs_diff": diff,
    }]


# self-contained subprocess body for the fleet row: the parent bench process
# has already initialized jax with ONE device, so the 4-device measurement
# must run under a fresh interpreter with the forced host-device count
_FLEET_SHARDED_SUBPROC = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp
from repro import api
from repro.launch.mesh import make_fleet_mesh
from repro.models.vgg import network_specs
from repro.core.compiler import LayerPlan
from repro.core.hybrid_conv import ConvSpec

img, scale, batch, n_req = 32, 16, 8, 96
specs = network_specs(img=img, scale=scale, n_classes=10)
ci, plans = 0, []
for s in specs:
    if isinstance(s, ConvSpec):
        plans.append(LayerPlan("wino" if ci % 2 == 0 else "spat",
                               "is" if ci % 2 else "ws", m=2, g_k=2, g_h=2))
        ci += 1
    else:
        plans.append(None)
acc = api.Accelerator.build(specs, plans=plans, seed=0, batch=batch)
mesh = make_fleet_mesh()
ndev = int(np.prod(mesh.devices.shape))
rng = np.random.default_rng(0)
reqs = [rng.standard_normal((img, img, 3)).astype(np.float32)
        for _ in range(n_req)]

def measure(mesh_arg):
    best, outs = 0.0, None
    with acc.serve(max_batch=batch, buckets=(batch,), mesh=mesh_arg,
                   warmup=True) as s:
        s.run_many(reqs[:2 * batch])            # warm threads + executor
        for _ in range(3):
            t0 = time.monotonic()
            o = s.run_many(reqs)
            jax.block_until_ready(o[-1])
            rps = n_req / (time.monotonic() - t0)
            if rps > best:
                best, outs = rps, o
    return best, outs

rps_1, outs_1 = measure(None)
rps_n, outs_n = measure(mesh)
parity = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(outs_1, outs_n))

# pallas under sharding: each shard is an ordinary single-device trace, so
# the Pallas PE kernels run inside the mapped region (interpret mode on CPU)
acc_pal = api.Accelerator.build(specs, plans=plans, params=acc.params,
                                batch=batch, backend="pallas")
with acc_pal.serve(max_batch=batch, buckets=(batch,), mesh=mesh,
                   warmup=True) as sp:
    outs_p = sp.run_many(reqs[:batch])
pallas_diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                  for a, b in zip(outs_1[:batch], outs_p))

# bursty trace: continuous batching vs the legacy fixed-bucket window.
# Unsharded on purpose — isolates the scheduler from the sharding cost.
def bursty(scheduler):
    rngb = np.random.default_rng(1)
    sizes = [int(rngb.integers(2, 7)) for _ in range(24)]
    total, best = sum(sizes), 0.0
    with acc.serve(max_batch=batch, buckets=(batch,), max_wait_ms=1.0,
                   scheduler=scheduler, warmup=True) as s:
        s.run_many(reqs[:2 * batch])
        for _ in range(3):
            futs, i = [], 0
            t0 = time.monotonic()
            for sz in sizes:
                futs += s.submit_many([reqs[(i + j) % n_req]
                                       for j in range(sz)])
                i += sz
                time.sleep(0.0025)              # burst gap
            for f in futs:
                f.result()
            best = max(best, total / (time.monotonic() - t0))
        padded = s.stats.padded_rows
    return best, padded

cont_rps, cont_padded = bursty("continuous")
buck_rps, buck_padded = bursty("bucketed")

print("FLEET_ROW:" + json.dumps({
    "config": f"img{img}_scale{scale}_maxbatch{batch}_n{n_req}",
    "n_devices": ndev,
    "host_cores": os.cpu_count() or 1,
    "session_rps_1dev": round(rps_1, 1),
    "session_rps_4dev": round(rps_n, 1),
    "rps_scaling": round(rps_n / rps_1, 2),
    "continuous_rps": round(cont_rps, 1),
    "bucketed_rps": round(buck_rps, 1),
    "continuous_vs_bucketed": round(cont_rps / buck_rps, 2),
    "continuous_padded_rows": cont_padded,
    "bucketed_padded_rows": buck_padded,
    "pallas_sharded_max_abs_diff": pallas_diff,
    "max_abs_diff": parity,
}))
"""


def run_fleet_sharded(*, n_devices: int = 4) -> list[dict]:
    """Sharded fleet serving row: the shard_map'd executor variant splitting
    each device batch over ``n_devices`` forced host devices, measured
    against the same session on one device, plus the continuous-vs-bucketed
    scheduler comparison on a bursty arrival trace and the Pallas-under-
    sharding parity evidence.

    Runs in a subprocess (the parent process already pinned jax to one
    device) with ``--xla_force_host_platform_device_count``. On a
    single-core host the 4-device row CANNOT show real scaling — four
    shard computations time-slice one core — so the row records
    ``host_cores`` alongside ``rps_scaling`` and the regression guard
    (``tools/bench_compare.py``) only gates scaling when the host has the
    cores to parallelize; multi-core CI regenerates the row with real
    speedup. ``continuous_vs_bucketed`` and both parity metrics are
    load-independent and meaningful everywhere.
    """
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(repo, "src")
    r = subprocess.run([sys.executable, "-c", _FLEET_SHARDED_SUBPROC],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"fleet_sharded subprocess failed:\n"
                           f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}")
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("FLEET_ROW:"))
    row = json.loads(line[len("FLEET_ROW:"):])
    row = {"bench": "table4_vgg16", "name": "serving/fleet_sharded", **row}
    return [row]


def run_fault_injection(*, img: int = 32, scale: int = 16, batch: int = 4,
                        n_requests: int = 40) -> list[dict]:
    """Fault-tolerant serving row: what poisoned-batch isolation costs.

    The same request stream is served twice through one warmed session
    configuration: once clean, once with ~10% of the requests *cursed*
    (a deterministic :class:`FaultSpec` fails every batch containing them
    at the ``execute`` site, forcing the bisect-and-retry recovery). The
    row records:

    * ``survived`` / ``accounting_balanced`` — the liveness invariant
      under load: every future resolved, ``submitted == completed +
      errors + shed``;
    * ``isolation_overhead_ratio`` — faulty-pass wall clock over the
      clean pass (lower is better; both passes run back-to-back in one
      process, so the ratio is machine-load-independent);
    * ``p95_clean_ms`` / ``p95_faulty_ms`` — tail latency with and
      without 10% faults;
    * ``innocent_max_abs_diff`` — innocents co-batched with an offender
      against the clean pass. The bisection retries re-run the same
      compiled executor at the same bucket size and row offsets, so this
      is REQUIRED to be exactly 0.0 (bitwise), not merely small.
    """
    import numpy as np

    from repro import api
    from repro.serving import FaultPlan, FaultSpec

    specs = network_specs(img=img, scale=scale, n_classes=10)
    acc = api.Accelerator.build(specs, seed=0, batch=batch)
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((n_requests, img, img, 3)).astype(np.float32)
    cursed = tuple(range(0, n_requests, 10))        # every 10th request
    # request ids are session-global and the timed stream runs after a
    # 2*batch-request pipeline warmup, so the cursed specs bind to the
    # warmup-offset ids
    plan = FaultPlan([FaultSpec(site="execute", kind="error",
                                requests=(c + 2 * batch,),
                                message=f"cursed request {c}")
                      for c in cursed])

    def _pass(fault_plan):
        with acc.serve(max_batch=batch, buckets=(batch,), max_wait_ms=2.0,
                       warmup=True, fault_plan=fault_plan) as s:
            s.run_many(list(xs[:2 * batch]))        # warm pipeline threads
            s.stats.latencies_ms.clear()
            t0 = time.monotonic()
            futs = [s.submit(x) for x in xs]
            outs = []
            for f in futs:
                try:
                    outs.append(np.asarray(f.result(timeout=120)))
                except Exception as e:  # noqa: BLE001 — typed resolution
                    outs.append(e)
            dt = time.monotonic() - t0
            st = s.stats
            resolved = all(f.done() for f in futs)
        return outs, dt, st, resolved

    clean_outs, t_clean, st_clean, _ = _pass(None)
    faulty_outs, t_faulty, st_faulty, resolved = _pass(plan)
    balanced = (st_faulty.submitted
                == st_faulty.requests + st_faulty.errors + st_faulty.shed)
    innocent_diff = max(
        float(np.max(np.abs(f - c)))
        for i, (f, c) in enumerate(zip(faulty_outs, clean_outs))
        if i not in cursed)
    offenders_isolated = all(isinstance(faulty_outs[i], Exception)
                             for i in cursed)
    return [{
        "bench": "table4_vgg16", "name": "serving/fault_injection",
        "config": (f"img{img}_scale{scale}_maxbatch{batch}_n{n_requests}_"
                   f"cursed{len(cursed)}"),
        "fault_rate": round(len(cursed) / n_requests, 3),
        "survived": bool(resolved and balanced),
        "accounting_balanced": bool(balanced),
        "offenders_isolated": bool(offenders_isolated),
        "retries": st_faulty.retries, "isolated": st_faulty.isolated,
        "isolation_overhead_ratio": round(t_faulty / t_clean, 2),
        "p95_clean_ms": round(st_clean.p95_ms(), 2),
        "p95_faulty_ms": round(st_faulty.p95_ms(), 2),
        "innocent_max_abs_diff": innocent_diff,
    }]
