"""Table 3 reproduction: resource utilization of the DSE-chosen accelerators.

Evaluates the Eq. 3-5 analytical resource model at the paper's configurations
(VU9P: PI=4 PO=4 PT=6 NI=6; PYNQ-Z1: PI=4 PO=4 PT=4 NI=1) and reports
utilization vs the paper's measured Table 3 numbers.
"""
from __future__ import annotations

from repro.core import perf_model as pm

PAPER = {
    "VU9P": {"LUTs": 706353, "DSPs": 5163, "BRAMs": 3169,
             "cfg": (4, 4, 6, 6)},
    "PYNQ-Z1": {"LUTs": 37034, "DSPs": 220, "BRAMs": 277,
                "cfg": (4, 4, 4, 1)},
}


def run() -> list[dict]:
    rows = []
    for target, name in ((pm.VU9P, "VU9P"), (pm.PYNQ_Z1, "PYNQ-Z1")):
        pi, po, pt, ni = PAPER[name]["cfg"]
        m = pt - 2
        model = {
            "DSPs": ni * pm.fpga_dsp(target, pi, po, pt, m),
            "BRAMs": ni * pm.fpga_bram(target, pi, po, pt, m),
            "LUTs": ni * pm.fpga_lut(target, pi, po, pt, m),
        }
        for res in ("DSPs", "BRAMs", "LUTs"):
            paper_val = PAPER[name][res]
            err = abs(model[res] - paper_val) / paper_val * 100
            rows.append({
                "bench": "table3_resources",
                "name": f"{name}/{res}",
                "model": round(model[res], 1),
                "paper": paper_val,
                "err_pct": round(err, 2),
            })
    return rows
