"""Benchmark harness: one bench per paper table/figure + the roofline table.

  PYTHONPATH=src python -m benchmarks.run [--only table4]
Prints one CSV-ish line per result row.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import (
    bench_fig6_layer_sweep,
    bench_kernels,
    bench_model_error,
    bench_roofline_table,
    bench_table3_resources,
    bench_table4_vgg16,
)

BENCHES = {
    "table3": bench_table3_resources.run,
    "table4": bench_table4_vgg16.run,
    "fig6": bench_fig6_layer_sweep.run,
    "model_error": bench_model_error.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline_table.run,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    failed = False
    for name in names:
        print(f"\n== {name} ==")
        try:
            for row in BENCHES[name]():
                print(",".join(f"{k}={v}" for k, v in row.items()))
        except Exception as e:  # noqa: BLE001
            failed = True
            print(f"BENCH FAIL {name}: {type(e).__name__}: {e}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
