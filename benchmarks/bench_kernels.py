"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-path
timings, NOT TPU throughput — the TPU numbers come from the roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm import batched_matmul
from repro.kernels.spatial_conv import spatial_conv2d
from repro.kernels.winograd import winograd_conv2d


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []

    a = jax.random.normal(key, (4, 128, 128), jnp.float32)
    b = jax.random.normal(key, (4, 128, 128), jnp.float32)
    for df in ("is", "ws"):
        us = _time(lambda a, b, df=df: batched_matmul(a, b, dataflow=df), a, b)
        rows.append({"bench": "kernels", "name": f"gemm_pe_4x128_{df}",
                     "us_per_call": round(us, 1)})

    x = jax.random.normal(key, (1, 32, 32, 16), jnp.float32)
    g = jax.random.normal(key, (3, 3, 16, 32), jnp.float32)
    for m in (2, 4):
        us = _time(lambda x, g, m=m: winograd_conv2d(x, g, m=m), x, g)
        rows.append({"bench": "kernels", "name": f"wino_conv_F{m}x{m}",
                     "us_per_call": round(us, 1)})
    us = _time(lambda x, g: spatial_conv2d(x, g), x, g)
    rows.append({"bench": "kernels", "name": "spatial_conv",
                 "us_per_call": round(us, 1)})

    q = jax.random.normal(key, (1, 4, 256, 64), jnp.float32)
    us = _time(lambda q: flash_attention(q, q, q, bq=128, bk=128), q)
    rows.append({"bench": "kernels", "name": "flash_attention_256",
                 "us_per_call": round(us, 1)})
    return rows
