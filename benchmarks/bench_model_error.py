"""Sec. 6.2 reproduction: analytical-model vs implementation error.

The paper validates its latency model at 4.27% (VU9P) / 4.03% (PYNQ-Z1)
against real hardware. Our TPU analog has two parts:

* **Spatial**: the analytical model vs the HLO-derived roofline of the
  compiled direct convolution — a like-for-like validation (the direct conv
  is what the model models). Reported as ``err_pct`` and averaged.
* **Winograd**: the CPU-compilable implementation is the UNFUSED reference
  (transforms materialize in HBM, fp32), while the model targets the fused
  Pallas kernel (transforms VMEM-resident). The measured gap
  (``fusion_gap = hlo/fused_model``) quantifies exactly why the paper (and
  our kernels/) fuse the transforms on-chip — Winograd's bandwidth
  amplification (Eq. 9) executed unfused costs ~3x the fused roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as pm
from repro.core.hybrid_conv import hybrid_conv2d
from repro.core.winograd import winograd_conv2d_reference
from repro.launch import roofline as rl
from repro.models.vgg import conv_specs


def _hlo_latency(spec, mode: str, m: int, batch: int) -> float:
    """Roofline step time of the compiled conv (3-term model)."""
    x = jax.ShapeDtypeStruct((batch, spec.h, spec.w, spec.c), jnp.bfloat16)
    g = jax.ShapeDtypeStruct((spec.r, spec.s, spec.c, spec.k), jnp.bfloat16)
    if mode == "wino":
        fn = lambda x, g: winograd_conv2d_reference(x, g, m=m)
        corr = 1.0   # the reference genuinely computes fp32: no bf16 corr.
    else:
        fn = lambda x, g: hybrid_conv2d(x, g, mode="spat", use_pallas=False)
        corr = 0.5   # bf16 legalized to f32 by the CPU backend
    compiled = jax.jit(fn).lower(x, g).compile()
    st = rl.analyze_hlo(compiled.as_text(), trip_count=1)
    roof = rl.roofline_from_stats(
        rl.HLOStats(st.flops, st.bytes_accessed * corr,
                    st.collective_bytes * corr), 1)
    return roof.step_time_s


def run() -> list[dict]:
    batch = 8
    spat_errors = []
    rows = []
    for spec in conv_specs()[2::3]:
        est = pm.tpu_layer_latency(pm.V5E, spec, "spat", "is", m=4,
                                   batch=batch)
        hlo = _hlo_latency(spec, "spat", 4, batch)
        err = abs(est - hlo) / hlo * 100
        spat_errors.append(err)
        rows.append({
            "bench": "model_error", "name": f"{spec.name}/spat",
            "analytical_ms": round(est * 1e3, 3),
            "hlo_roofline_ms": round(hlo * 1e3, 3),
            "err_pct": round(err, 1),
        })
        fused = pm.tpu_layer_latency(pm.V5E, spec, "wino", "is", m=4,
                                     batch=batch)
        hlo_w = _hlo_latency(spec, "wino", 4, batch)
        rows.append({
            "bench": "model_error", "name": f"{spec.name}/wino",
            "fused_model_ms": round(fused * 1e3, 3),
            "unfused_hlo_ms": round(hlo_w * 1e3, 3),
            "fusion_gap_x": round(hlo_w / fused, 1),
        })
    rows.append({
        "bench": "model_error", "name": "MEAN_spat",
        "err_pct": round(float(np.mean(spat_errors)), 2),
        "paper_err_pct": 4.27,
    })
    return rows
