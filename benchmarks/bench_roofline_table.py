"""Aggregates the dry-run roofline JSONs into the EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def run() -> list[dict]:
    rows = []
    for r in load_records("single"):
        if r["status"] != "OK":
            rows.append({"bench": "roofline", "name":
                         f"{r['arch']}/{r['shape']}", "status": r["status"],
                         "reason": r.get("reason", r.get("error", ""))[:60]})
            continue
        roof = r["roofline"]
        rows.append({
            "bench": "roofline",
            "name": f"{r['arch']}/{r['shape']}",
            "status": "OK",
            "bound": roof["bound"],
            "compute_ms": round(roof["compute_s"] * 1e3, 2),
            "memory_ms": round(roof["memory_s"] * 1e3, 2),
            "collective_ms": round(roof["collective_s"] * 1e3, 2),
            "step_ms": round(roof["step_time_s"] * 1e3, 2),
            "mem_gb_tpu": r.get("bytes_per_device_gb_tpu_est"),
            "useful_flops_ratio": (round(r["useful_flops_ratio"], 3)
                                   if r.get("useful_flops_ratio") else None),
        })
    return rows
