"""Figure 6 reproduction: per-layer GOPS across 60 (VU9P) / 40 (PYNQ) CONV
layers with varying fmap size / channels / kernel size.

Paper claims: Spatial-mode throughput is stable and near peak; Winograd-mode
throughput is higher but fluctuates and DROPS where the layer becomes
memory-bound (Sec. 6.2). We reproduce the sweep with the Eq. 6-15 model and
report the stability statistics + the count of layers where the memory bound
bites Winograd below Spatial.
"""
from __future__ import annotations

import numpy as np

from repro.core import perf_model as pm
from repro.core.hybrid_conv import ConvSpec


def _layer_pool(n: int) -> list[ConvSpec]:
    """n diverse CONV layers (fmap, channels, kernel size)."""
    rng = np.random.default_rng(7)
    specs = []
    for i in range(n):
        h = int(rng.choice([7, 14, 28, 56, 112, 224]))
        c = int(rng.choice([32, 64, 128, 256, 512]))
        k = int(rng.choice([32, 64, 128, 256, 512]))
        r = int(rng.choice([1, 3, 5]))
        specs.append(ConvSpec(f"L{i}", h, h, c, k, r=r, s=r))
    return specs


def _sweep(target: pm.FPGATarget, hw, n_layers: int):
    specs = _layer_pool(n_layers)
    gops_spat, gops_wino = [], []
    wino_membound = 0
    for s in specs:
        lat_s = pm.fpga_layer_latency(target, s, hw[0], hw[1], hw[2],
                                      hw[2] - 2, "spat", "is")
        gops_spat.append(2 * s.macs / lat_s / 1e9)
        if s.wino_eligible():
            lat_w = pm.fpga_layer_latency(target, s, hw[0], hw[1], hw[2],
                                          hw[2] - 2, "wino", "is")
            gops_wino.append(2 * s.macs / lat_w / 1e9)
            # memory-bound check: does LDW dominate COMP in wino mode?
            t_cp = pm.fpga_t_cp(target, s, hw[0], hw[1], hw[2], hw[2] - 2,
                                "wino")
            t_ldw = pm.fpga_t_ldw(target, s, hw[0], hw[1], hw[2], hw[2] - 2,
                                  "wino")
            if t_ldw > t_cp:
                wino_membound += 1
                if lat_w > lat_s:
                    pass
    return (np.array(gops_spat), np.array(gops_wino), wino_membound)


def run() -> list[dict]:
    rows = []
    for target, name, hw, n in ((pm.VU9P, "VU9P", (4, 4, 6), 60),
                                (pm.PYNQ_Z1, "PYNQ-Z1", (4, 4, 4), 40)):
        spat, wino, membound = _sweep(target, hw, n)
        rows.append({
            "bench": "fig6_layer_sweep", "name": name, "n_layers": n,
            "spat_gops_mean": round(float(spat.mean()), 1),
            "spat_cv": round(float(spat.std() / spat.mean()), 3),
            "wino_gops_mean": round(float(wino.mean()), 1),
            "wino_cv": round(float(wino.std() / wino.mean()), 3),
            "wino_membound_layers": membound,
            "claim_spatial_stabler": bool(
                spat.std() / spat.mean() < wino.std() / wino.mean()),
            "claim_wino_faster_mean": bool(wino.mean() > spat.mean()),
        })
    return rows
