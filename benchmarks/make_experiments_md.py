"""Regenerates the EXPERIMENTS.md §Dry-run and §Roofline tables from the
experiments/dryrun JSONs. Run after a sweep:

  PYTHONPATH=src python -m benchmarks.make_experiments_md > /tmp/tables.md
"""
from __future__ import annotations

from benchmarks.bench_roofline_table import load_records


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | mem/dev GB (TPU est) |"
        " collectives (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in load_records():
        if r["status"] == "OK":
            cc = r.get("collective_counts", {})
            col = (f"{cc.get('all-gather',0)}/{cc.get('all-reduce',0)}/"
                   f"{cc.get('reduce-scatter',0)}/{cc.get('all-to-all',0)}/"
                   f"{cc.get('collective-permute',0)}")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
                f"| {r.get('compile_s','')} "
                f"| {r.get('bytes_per_device_gb_tpu_est','')} | {col} |")
        else:
            why = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| {r['status']} | | | {why} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound |"
        " step s | MODEL_FLOPS/HLO | what would move the bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        "compute": "more MXU-efficient layout / lower remat recompute",
        "memory": "larger fused blocks; keep weights resident (WS)",
        "collective": "reduce TP boundary crossings; overlap collectives "
                      "with compute; shard experts/seq differently",
    }
    for r in load_records("single"):
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"{r['status']} | | | {r.get('reason','')[:45]} |")
            continue
        ro = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3g} "
            f"| {ro['memory_s']:.3g} | {ro['collective_s']:.3g} "
            f"| {ro['bound']} | {ro['step_time_s']:.3g} "
            f"| {ratio:.2f} | {notes[ro['bound']]} |"
            if ratio else
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3g} "
            f"| {ro['memory_s']:.3g} | {ro['collective_s']:.3g} "
            f"| {ro['bound']} | {ro['step_time_s']:.3g} | n/a "
            f"| {notes[ro['bound']]} |")
    return "\n".join(lines)


def summary() -> str:
    recs = load_records()
    ok = sum(r["status"] == "OK" for r in recs)
    skip = sum(r["status"] == "SKIP" for r in recs)
    fail = sum(r["status"] == "FAIL" for r in recs)
    return f"{ok} OK / {skip} SKIP / {fail} FAIL of {len(recs)} cells"


if __name__ == "__main__":
    print("## Dry-run\n")
    print(summary(), "\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table())
