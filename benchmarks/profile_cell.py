"""Hillclimb profiler: lower+compile one cell, print the roofline terms and
the top ops by collective / memory bytes with op_name attribution.

  PYTHONPATH=src python -m benchmarks.profile_cell --arch minitron-8b \
      --shape train_4k [--top 10]
"""
from __future__ import annotations

import argparse
import re

from repro.launch import roofline as rl


def profile(arch: str, shape: str, top: int = 10):
    from repro.launch.dryrun import lower_cell, trip_count
    lowered, cfg, shape_spec, mesh = lower_cell(arch, shape, False)
    compiled = lowered.compile()
    txt = compiled.as_text()
    trip = trip_count(cfg)
    st = rl.analyze_hlo(txt, trip_count=trip)
    corr = 0.5 if cfg.dtype == "bfloat16" else 1.0
    roof = rl.roofline_from_stats(
        rl.HLOStats(st.flops, st.bytes_accessed * corr,
                    st.collective_bytes * corr), mesh.devices.size)
    ma = compiled.memory_analysis()
    print(f"== {arch} x {shape} (single-pod) ==")
    print(f"mem/dev: {(ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes * corr)/2**30:.2f} GB (TPU est)")
    print(f"compute {roof.compute_s:.3f}s | memory {roof.memory_s:.3f}s | "
          f"collective {roof.collective_s:.3f}s -> bound={roof.bound} "
          f"step={roof.step_time_s:.3f}s")

    comps = rl.parse_hlo(txt)
    mult = rl._loop_multipliers(comps, trip)
    coll, mem = [], []
    for cname, comp in comps.items():
        m = mult[cname]
        for op in comp.ops:
            base = op.opcode.removesuffix("-start")
            meta = re.search(r'op_name="([^"]+)"', op.rest)
            tag = (meta.group(1) if meta else "")[-70:]
            b = rl._shape_bytes(op.type_str)
            if base in rl.COLLECTIVES and not op.opcode.endswith("-done"):
                coll.append((m * b * corr, base, op.type_str[:40], tag))
            elif op.opcode == "fusion":
                mem.append((m * b * corr, "fusion", op.type_str[:40], tag))
    for title, rows in (("top collectives", coll), ("top fusion outputs", mem)):
        rows.sort(reverse=True)
        print(f"\n-- {title} --")
        for r in rows[:top]:
            print(f"{r[0]/1e9:8.2f}GB {r[1]:18s} {r[2]} | {r[3]}")
    return roof


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()
    profile(args.arch, args.shape, args.top)
