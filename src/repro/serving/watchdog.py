"""Watchdog building blocks for the supervised serving pipeline.

``api.ServingSession`` runs one supervisor thread per session; the pieces
it schedules with live here so they are testable without a device in the
loop:

* :class:`DeadlineTable` — a thread-safe min-heap of request deadlines.
  The supervisor sleeps until the earliest deadline (or its poll tick),
  then fails every due request with ``DeadlineExceeded``. Entries for
  requests that already resolved are dropped lazily when they come due.
* :class:`ThreadSupervisor` — liveness tracking for the dispatch/drain
  threads, adapting ``repro.checkpoint.HeartbeatMonitor`` (the training
  fleet's straggler/dead-worker detector) to pipeline threads: each thread
  ``beat()``s once per loop iteration, and a thread that stays silent for
  ``hang_after_s`` *while the session has work* is reported hung. Idle
  silence is not a hang — ``update_busy`` re-arms every heartbeat on the
  idle->busy edge so a long-quiet session never false-positives the moment
  traffic returns.

Dead-*thread* detection (``Thread.is_alive()`` going false) needs no
heartbeats and is handled directly by the session's supervisor; this
module covers the time-based half of the failure model.
"""
from __future__ import annotations

import heapq
import itertools
import threading

from repro.checkpoint import HeartbeatMonitor


class DeadlineTable:
    """Min-heap of ``(deadline_monotonic, item)`` with thread-safe ops."""

    def __init__(self):
        self._heap: list = []
        self._lock = threading.Lock()
        self._seq = itertools.count()   # tie-break: never compare items

    def add(self, t: float, item) -> bool:
        """Push; True when ``t`` became the new earliest deadline (the
        supervisor must be woken to shorten its sleep)."""
        with self._lock:
            was_min = self._heap[0][0] if self._heap else None
            heapq.heappush(self._heap, (float(t), next(self._seq), item))
            return was_min is None or t < was_min

    def pop_due(self, now: float) -> list:
        """Pop and return every item whose deadline is <= ``now``."""
        due = []
        with self._lock:
            while self._heap and self._heap[0][0] <= now:
                due.append(heapq.heappop(self._heap)[2])
        return due

    def next_at(self) -> float | None:
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)


class ThreadSupervisor:
    """Hang detection for a fixed set of named pipeline threads.

    Wraps :class:`repro.checkpoint.HeartbeatMonitor`: thread ``name`` maps
    to monitor worker index, ``beat`` -> ``report``, and ``hung()`` ->
    ``monitor.dead()`` gated on the session being busy. ``hang_after_s
    = None`` disables time-based detection entirely (``hung()`` is always
    empty) while ``beat`` stays cheap enough to call unconditionally."""

    def __init__(self, names, hang_after_s: float | None = None):
        self.names = tuple(names)
        self._idx = {n: i for i, n in enumerate(self.names)}
        self.hang_after_s = hang_after_s
        self._monitor = HeartbeatMonitor(
            len(self.names),
            dead_after_s=hang_after_s if hang_after_s else 60.0)
        self._busy = False
        self._lock = threading.Lock()

    def beat(self, name: str, step_time: float = 0.0,
             now: float | None = None):
        with self._lock:
            self._monitor.report(self._idx[name], step_time, now=now)

    def update_busy(self, busy: bool, now: float | None = None):
        """Track whether the session has work. On the idle->busy edge every
        heartbeat re-arms: stale idle-era timestamps must not count as
        silence against the hang window."""
        with self._lock:
            if busy and not self._busy:
                for i in range(len(self.names)):
                    self._monitor.report(i, 0.0, now=now)
            self._busy = busy

    def hung(self, now: float | None = None) -> list[str]:
        """Thread names silent past ``hang_after_s`` while busy."""
        if self.hang_after_s is None:
            return []
        with self._lock:
            if not self._busy:
                return []
            return [self.names[i] for i in self._monitor.dead(now=now)]

    def stragglers(self) -> list[str]:
        """Relatively-slow threads (z-score over the set median) — exposed
        for observability, never a restart trigger."""
        with self._lock:
            return [self.names[i] for i in self._monitor.stragglers()]
