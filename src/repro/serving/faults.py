"""Deterministic fault injection for the serving pipeline.

A :class:`FaultPlan` is a set of :class:`FaultSpec` triggers evaluated at
named pipeline boundaries (*sites*). The hardened ``api.ServingSession``
calls ``plan.visit(site, ...)`` at each boundary; matching specs then
raise, sleep, corrupt the payload, or kill the visiting thread. Matching
is purely counter-based — each site keeps an invocation ordinal and specs
fire at chosen ordinals (or for chosen request ids) — so a plan contains
**no wall-clock reads and no RNG draws at visit time**. The only
randomness is in :meth:`FaultPlan.seeded`, which pre-generates the whole
spec list from a ``numpy`` generator at construction; two plans built from
the same seed inject byte-identical schedules.

Sites (see ``docs/ARCHITECTURE.md`` "Failure model")::

    staging   caller thread, request validation/quantize     payload: request
    dispatch  worker thread, before a batch launches         no payload
    execute   just before the PE executor runs a batch       payload: staged buffer
    drain     drain thread, before the host sync             no payload
    aot_load  core/aot.load_entry, inside the warn-and-      no payload
              recompile guard

Kinds: ``error`` (raise :class:`InjectedFault`), ``delay`` (sleep
``delay_ms``), ``nan``/``inf`` (overwrite payload rows), ``kill`` (raise
:class:`ThreadKilled`, a ``BaseException`` — the thread dies and the
session watchdog must recover).

``chaos_soak`` drives a session under a plan and checks the liveness
invariant: every submitted request resolves (result or typed error) and
the accounting balances exactly (``submitted == completed + errors +
shed``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Sequence

import numpy as np

from repro.serving.errors import InjectedFault, ThreadKilled

SITES = ("staging", "dispatch", "execute", "drain", "aot_load")
KINDS = ("error", "delay", "nan", "inf", "kill")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic trigger.

    ``at``: site-invocation ordinals (0-based) this spec fires on; empty
    means *every* visit that passes the other filters. ``requests``:
    request ids the visit must involve (empty = any). ``match``: extra
    ``(key, value)`` context equality filters, e.g.
    ``(("backend", "pallas"),)`` fires only on Pallas dispatches."""

    site: str
    kind: str = "error"
    at: tuple[int, ...] = ()
    requests: tuple[int, ...] = ()
    match: tuple[tuple[str, Any], ...] = ()
    delay_ms: float = 0.0
    message: str = ""

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}: {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}: {KINDS}")


class FaultPlan:
    """Deterministic, thread-safe fault schedule over the serving sites.

    ``visit`` is called by the instrumented pipeline; it advances the
    site's ordinal, applies every matching spec, and returns the (possibly
    corrupted) payload. The fired-event log (``fired()``) is the test
    oracle: it records exactly which spec fired at which ordinal against
    which requests."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = tuple(specs)
        self._counters: dict[str, int] = {s: 0 for s in SITES}
        self._events: list[dict] = []
        self._lock = threading.Lock()

    # -- construction -------------------------------------------------------
    @classmethod
    def seeded(cls, seed: int, *, n_faults: int = 8, horizon: int = 48,
               sites: Sequence[str] = ("dispatch", "execute", "drain"),
               kinds: Sequence[str] = ("error", "delay", "nan"),
               n_requests: int = 0, cursed_fraction: float = 0.25,
               max_delay_ms: float = 5.0) -> "FaultPlan":
        """A reproducible plan: ``n_faults`` specs drawn from ``seed``.

        Ordinals land uniformly in ``[0, horizon)`` site visits. When
        ``n_requests`` is given, ``cursed_fraction`` of the specs bind to a
        request id instead of an ordinal — a *cursed request* that fails at
        its site every time it is dispatched (the poisoned-batch isolation
        workload). All randomness happens HERE; the returned plan is a
        fixed schedule."""
        rng = np.random.default_rng(seed)
        specs = []
        for i in range(n_faults):
            site = str(sites[int(rng.integers(len(sites)))])
            kind = str(kinds[int(rng.integers(len(kinds)))])
            if kind in ("nan", "inf") and site not in ("staging", "execute"):
                site = "execute"   # corruption needs a payload to corrupt
            at: tuple[int, ...] = (int(rng.integers(horizon)),)
            requests: tuple[int, ...] = ()
            if n_requests and float(rng.random()) < cursed_fraction:
                requests, at = (int(rng.integers(n_requests)),), ()
            delay = (float(rng.uniform(0.5, max_delay_ms))
                     if kind == "delay" else 0.0)
            specs.append(FaultSpec(
                site=site, kind=kind, at=at, requests=requests,
                delay_ms=delay, message=f"seeded[{seed}] spec #{i}"))
        return cls(specs)

    # -- the boundary hook --------------------------------------------------
    def visit(self, site: str, payload=None, requests: Sequence[int] = (),
              rows: dict | None = None, **ctx):
        """Advance ``site``'s ordinal and apply matching specs.

        ``payload`` (a numpy array, mutated in place for nan/inf specs) is
        returned so call sites can write ``buf = plan.visit(...)``.
        ``rows`` maps request id -> ``(row_offset, n_rows)`` inside the
        payload, scoping corruption to a cursed request's own rows."""
        with self._lock:
            ordinal = self._counters[site]   # KeyError on unknown site
            self._counters[site] = ordinal + 1
            fired = [s for s in self.specs
                     if self._matches(s, site, ordinal, requests, ctx)]
            for s in fired:
                self._events.append({
                    "site": site, "ordinal": ordinal, "kind": s.kind,
                    "requests": tuple(requests), "message": s.message})
        # apply OUTSIDE the lock: sleeps and raises must not serialize
        # other threads' visits
        for s in fired:
            if s.kind == "delay":
                time.sleep(s.delay_ms / 1e3)
            elif s.kind in ("nan", "inf"):
                self._corrupt(payload, s, rows)
            elif s.kind == "kill":
                raise ThreadKilled(s.message or f"killed at {site}")
            else:
                raise InjectedFault(
                    s.message or f"injected fault at {site}#{ordinal}")
        return payload

    @staticmethod
    def _matches(spec: FaultSpec, site: str, ordinal: int,
                 requests: Sequence[int], ctx: dict) -> bool:
        if spec.site != site:
            return False
        if spec.at and ordinal not in spec.at:
            return False
        if spec.requests and not set(spec.requests) & set(requests):
            return False
        return all(ctx.get(k) == v for k, v in spec.match)

    @staticmethod
    def _corrupt(payload, spec: FaultSpec, rows: dict | None):
        if payload is None or not isinstance(payload, np.ndarray):
            return
        if not np.issubdtype(payload.dtype, np.floating):
            return   # int8 staging has no NaN encoding; spec is a no-op
        val = np.nan if spec.kind == "nan" else np.inf
        if spec.requests and rows:
            for rid in spec.requests:
                if rid in rows:
                    off, k = rows[rid]
                    payload[off:off + k] = val
        elif payload.size:
            payload.reshape(-1)[0] = val

    # -- oracle -------------------------------------------------------------
    def fired(self, site: str | None = None) -> list[dict]:
        """The fired-event log (copies; safe to inspect mid-run)."""
        with self._lock:
            ev = list(self._events)
        return ev if site is None else [e for e in ev if e["site"] == site]

    def counts(self) -> dict[str, int]:
        """Visits per site so far."""
        with self._lock:
            return dict(self._counters)

    def aot_hook(self):
        """The callable ``core.aot.set_fault_hook`` expects: routes AOT
        artifact loads through this plan's ``aot_load`` site."""
        return lambda digest: self.visit("aot_load", digest=digest)


def chaos_soak(acc, *, plan: FaultPlan, n_requests: int = 48, seed: int = 0,
               deadline_ms: float | None = 10_000.0,
               timeout_s: float = 120.0, raise_on_failure: bool = False,
               **session_kwargs) -> dict:
    """Drive ``acc.serve(fault_plan=plan, ...)`` with a seeded request
    stream and report the liveness/accounting verdict.

    Every request's future must resolve — result or typed error — before
    ``timeout_s``; the session counters must balance exactly
    (``submitted == completed + errors + shed``). Returns the report dict;
    with ``raise_on_failure`` a violated invariant raises instead, so CI
    smoke steps fail loudly."""
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal(
        (n_requests, *acc.input_shape)).astype(np.float32)
    kwargs = dict(max_batch=4, max_wait_ms=2.0, warmup=True,
                  guard_numerics=True, deadline_ms=deadline_ms)
    kwargs.update(session_kwargs)
    session = acc.serve(fault_plan=plan, **kwargs)
    futs: list = []
    rejected = 0
    completed = errors = unresolved = 0
    try:
        for i in range(n_requests):
            try:
                futs.append(session.submit(xs[i]))
            except Exception:  # noqa: BLE001 — staging-site injected fault
                rejected += 1
                futs.append(None)
        t_end = time.monotonic() + timeout_s
        for f in futs:
            if f is None:
                continue
            try:
                f.result(timeout=max(0.0, t_end - time.monotonic()))
                completed += 1
            except Exception:  # noqa: BLE001 — classify via done()
                if f.done():
                    errors += 1
                else:
                    unresolved += 1
    finally:
        session.close()
    st = session.stats
    balanced = st.submitted == st.requests + st.errors + st.shed
    report = {
        "n_requests": n_requests, "rejected_at_submit": rejected,
        "completed": completed, "errors": errors, "unresolved": unresolved,
        "submitted": st.submitted, "stats_completed": st.requests,
        "stats_errors": st.errors, "shed": st.shed,
        "deadline_exceeded": st.deadline_exceeded, "retries": st.retries,
        "isolated": st.isolated, "degraded": st.degraded,
        "watchdog_restarts": st.watchdog_restarts,
        "fault_events": len(plan.fired()),
        "balanced": balanced,
        "survived": unresolved == 0 and balanced,
    }
    if raise_on_failure and not report["survived"]:
        raise RuntimeError(f"chaos soak failed liveness/accounting: {report}")
    return report
