"""Typed failures for the serving layer.

Every way a ``ServingSession`` request can fail resolves its ``Future``
with one of these (or the causal exception of a batch failure) — a caller
that catches ``ServingError`` has seen every session-originated failure.
``ThreadKilled`` is the one deliberate exception to that rule: it models a
pipeline thread dying mid-loop (the fault harness's ``kind="kill"``), so it
derives from ``BaseException`` to escape the per-batch ``except Exception``
recovery handlers the way a real ``SystemExit``/segfaulting-extension crash
would — only the thread's outermost wrapper sees it.
"""
from __future__ import annotations


class ServingError(RuntimeError):
    """Base class for every session-originated request failure."""


class DeadlineExceeded(ServingError, TimeoutError):
    """The request's ``deadline_ms`` elapsed before its result drained.

    Also a ``TimeoutError`` so generic timeout handling catches it."""


class Overloaded(ServingError):
    """Admission refused: the pending queue is at ``queue_limit`` and the
    session sheds instead of blocking (``on_overload="shed"``)."""


class NumericsError(ServingError, ArithmeticError):
    """``guard_numerics=True`` quarantined this request: its output rows
    contain NaN/Inf. Co-batched finite requests resolve normally."""


class PipelineCrashed(ServingError):
    """A dispatch/drain thread died (or hung past ``hang_after_s``); the
    watchdog failed this queued/in-flight request and restarted the
    pipeline. Carries the causal exception as ``__cause__`` when known."""


class InjectedFault(ServingError):
    """Raised by a :class:`repro.serving.FaultPlan` ``kind="error"`` spec —
    a deterministic stand-in for device/runtime failures."""


class ThreadKilled(BaseException):
    """Fault-harness ``kind="kill"``: simulates a pipeline thread dying
    without cleanup. Derives from ``BaseException`` so the per-batch
    recovery handlers (``except Exception``) cannot absorb it."""
