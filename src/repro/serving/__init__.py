"""``repro.serving`` — the serving layer's failure model.

Typed request failures (:class:`DeadlineExceeded`, :class:`Overloaded`,
:class:`NumericsError`, :class:`PipelineCrashed`), the deterministic
fault-injection harness (:class:`FaultPlan` / :class:`FaultSpec` /
:func:`chaos_soak`) and the watchdog building blocks
(:class:`DeadlineTable`, :class:`ThreadSupervisor`) used by
``api.ServingSession``. See the "Failure model" section of
``docs/ARCHITECTURE.md``.
"""
from repro.serving.errors import (
    DeadlineExceeded,
    InjectedFault,
    NumericsError,
    Overloaded,
    PipelineCrashed,
    ServingError,
    ThreadKilled,
)
from repro.serving.faults import KINDS, SITES, FaultPlan, FaultSpec, chaos_soak
from repro.serving.watchdog import DeadlineTable, ThreadSupervisor

__all__ = [
    "DeadlineExceeded", "DeadlineTable", "FaultPlan", "FaultSpec",
    "InjectedFault", "KINDS", "NumericsError", "Overloaded",
    "PipelineCrashed", "SITES", "ServingError", "ThreadKilled",
    "ThreadSupervisor", "chaos_soak",
]
