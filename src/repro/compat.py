"""Version-compat shims over JAX APIs that moved between releases.

The repo is written against current JAX, but CI and the dev container pin
older releases; every renamed/moved symbol we depend on funnels through this
module so the rest of the codebase can use one spelling:

* ``pltpu.CompilerParams``      (new)  vs ``pltpu.TPUCompilerParams`` (old)
* ``jax.sharding.AxisType``     (new)  — ``jax.make_mesh(axis_types=...)``
                                         simply isn't available on old JAX
* ``jax.shard_map(check_vma=)`` (new)  vs ``jax.experimental.shard_map``'s
                                         ``shard_map(check_rep=)``   (old)
"""
from __future__ import annotations

import inspect
from typing import Any, Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

# -- Pallas TPU compiler params --------------------------------------------

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs: Any):
    """``pltpu.CompilerParams`` under whichever name this JAX exports."""
    return _COMPILER_PARAMS_CLS(**kwargs)


# -- Mesh construction ------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs: Any):
    """``jax.make_mesh`` with Auto axis_types where supported.

    New JAX wants explicit axis_types to silence the sharding-in-types
    migration; old JAX has no ``AxisType`` and no such parameter.
    """
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        kwargs.setdefault("axis_types", (axis_type,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# -- collective axis size ---------------------------------------------------

def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (new) / ``psum(1, axis)`` fallback (old)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


# -- shard_map --------------------------------------------------------------

def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (old).

    ``check_vma`` maps onto the pre-rename ``check_rep``. The kwarg is
    chosen by inspecting the actual signature — mid-range JAX exposes
    ``jax.shard_map`` but still spells the argument ``check_rep``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kw = "check_vma" if "check_vma" in inspect.signature(sm).parameters \
        else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check_vma})
