"""Post-training int8 quantization for the HybridDNN stack.

The paper's headline GOPS come from fixed-point DSP-packed MACs (Sec. 5.1:
12-bit fixed, two MACs per DSP at low precision); this package brings the
arithmetic — not just the architecture — into the reproduction as an int8
inference mode that threads through every tier:

* ``observers`` / ``calibrate`` — post-training calibration: replay the spec
  chain in fp32 over sample activations and record per-layer ranges
  (min/max or percentile), producing per-tensor symmetric scales.
* ``sidecar``   — the versioned ``QuantSidecar`` carried *alongside* the
  ``Program``: scales ride in a JSON sidecar keyed to the schedule, so the
  128-bit instruction words are untouched and the bit-exact recompile check
  of ``save_program``/``from_program`` still holds.
* ``execute``   — the int8 PE dispatch shared by all three execution paths
  (jitted executor, strict interpreter, Pallas backend): int8 inputs and
  weights, int32 accumulate, fused requantize(+ReLU) epilogue.

Scheme: per-tensor symmetric, zero_point = 0 (``scale = amax / 127``,
values clipped to [-127, 127] — the ``optim.compression`` convention).
Integer convolution is exact, so fused whole-layer and per-block lowerings
of the same stream are *bitwise* identical — the property the strict
interpreter parity tests assert.
"""
from repro.quant.calibrate import calibrate
from repro.quant.execute import (qconv2d, qdense, qdepthwise, qeltwise,
                                 quantize_params, quantize_tensor, requantize)
from repro.quant.observers import MinMaxObserver, PercentileObserver, make_observer
from repro.quant.sidecar import FORMAT, LayerQuant, QuantSidecar

__all__ = [
    "FORMAT", "LayerQuant", "QuantSidecar",
    "MinMaxObserver", "PercentileObserver", "make_observer",
    "calibrate",
    "qconv2d", "qdense", "qdepthwise", "qeltwise",
    "quantize_params", "quantize_tensor", "requantize",
]
