"""Post-training calibration: fp32 replay + range observation -> sidecar.

Replays the spec chain with plain fp32 ops (the same stash-based walk as
``models.resnet.reference_forward``, so every topology the compiler accepts
calibrates — ``inp_from`` reroutes and ``skip_from`` residuals included),
feeding one observer per produced tensor. Weight scales come straight from
``|w|_max`` (weights are known exactly; clipping them buys nothing) —
per-output-channel for CONV/FC, per-tensor for depthwise (its HWIO weight
has a singleton output axis, so the channel axis is the GROUP axis and a
per-channel vector would not broadcast over the conv result) — and
POOL layers are pinned to scale passthrough: ``max()`` commutes with a
positive rescale, so the pooled int8 map IS the pooled fp map quantized at
the input scale — no epilogue, no observer.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.hybrid_conv import (ConvSpec, DepthwiseSpec, EltwiseSpec,
                                    FCSpec, PoolSpec, dense, depthwise_conv2d,
                                    hybrid_conv2d, max_pool2d)
from repro.optim.compression import quantize_int8
from repro.quant.observers import make_observer
from repro.quant.sidecar import LayerQuant, QuantSidecar


def _replay_stash(specs, params, x_nhwc):
    """One fp32 forward pass, returning every intermediate (keyed by spec
    index; -1 = the network input)."""
    stash = {-1: jnp.asarray(x_nhwc, jnp.float32)}
    pi = 0
    for i, spec in enumerate(specs):
        if isinstance(spec, ConvSpec):
            src = -1 if spec.inp_from == -1 else (
                spec.inp_from if spec.inp_from is not None else i - 1)
            w, b = params[pi]
            pi += 1
            y = hybrid_conv2d(stash[src], w, b, mode="spat",
                              stride=spec.stride, padding=spec.padding,
                              relu=spec.relu, use_pallas=False)
        elif isinstance(spec, PoolSpec):
            y = max_pool2d(stash[i - 1], spec.window, spec.stride)
        elif isinstance(spec, EltwiseSpec):
            y = stash[i - 1] + stash[spec.skip_from]
            if spec.relu:
                y = jnp.maximum(y, 0)
        elif isinstance(spec, DepthwiseSpec):
            w, b = params[pi]
            pi += 1
            y = depthwise_conv2d(stash[i - 1], w, b, stride=spec.stride,
                                 padding=spec.padding, relu=spec.relu)
        elif isinstance(spec, FCSpec):
            w, b = params[pi]
            pi += 1
            x = stash[i - 1]
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            y = dense(x, w, b, relu=spec.relu)
        else:
            raise TypeError(f"unknown spec kind {type(spec).__name__}")
        stash[i] = y
    return stash


def calibrate(specs: Sequence, params, calib_data, *,
              observer: str = "percentile") -> QuantSidecar:
    """Build a ``QuantSidecar`` for ``specs``/``params`` from sample inputs.

    ``calib_data`` is one input batch (ndarray) or a list of batches;
    ``observer`` is ``"percentile"`` (default, 99.9th |x|) or ``"minmax"``.
    """
    batches = [calib_data] if isinstance(calib_data, (np.ndarray, jnp.ndarray)) \
        else list(calib_data)
    if not batches:
        raise ValueError("calibrate needs at least one sample batch")

    obs_in = make_observer(observer)
    obs = {i: make_observer(observer) for i, s in enumerate(specs)
           if not isinstance(s, PoolSpec)}
    for x in batches:
        stash = _replay_stash(specs, params, x)
        obs_in.observe(stash[-1])
        for i, o in obs.items():
            o.observe(stash[i])

    def out_scale(i: int) -> float:
        # POOL is scale passthrough — chase back to the real producer.
        while i >= 0 and isinstance(specs[i], PoolSpec):
            i -= 1
        return obs_in.scale if i < 0 else obs[i].scale

    def channel_scales(w) -> tuple[float, ...]:
        # per-output-channel |w|_max over every other axis (the channel
        # axis is last in both HWIO conv and (d_in, d_out) FC weights):
        # one badly-scaled filter no longer poisons the whole layer
        w = np.asarray(w, np.float32)
        amax = np.abs(w).reshape(-1, w.shape[-1]).max(axis=0)
        return tuple(float(s) for s in (amax + 1e-12) / 127.0)

    layers, pi = [], 0
    for i, spec in enumerate(specs):
        if isinstance(spec, ConvSpec):
            src = -1 if spec.inp_from == -1 else (
                spec.inp_from if spec.inp_from is not None else i - 1)
            ws = channel_scales(params[pi][0])
            pi += 1
            layers.append(LayerQuant("conv", out_scale(src), obs[i].scale,
                                     wgt_scale=ws))
        elif isinstance(spec, PoolSpec):
            s = out_scale(i - 1)
            layers.append(LayerQuant("pool", s, s, requantize=False))
        elif isinstance(spec, EltwiseSpec):
            layers.append(LayerQuant("eltwise", out_scale(i - 1), obs[i].scale,
                                     skip_scale=out_scale(spec.skip_from)))
        elif isinstance(spec, DepthwiseSpec):
            _, ws = quantize_int8(np.asarray(params[pi][0], np.float32))
            pi += 1
            layers.append(LayerQuant("dw", out_scale(i - 1), obs[i].scale,
                                     wgt_scale=float(ws)))
        elif isinstance(spec, FCSpec):
            ws = channel_scales(params[pi][0])
            pi += 1
            layers.append(LayerQuant("fc", out_scale(i - 1), obs[i].scale,
                                     wgt_scale=ws))
    return QuantSidecar(input_scale=obs_in.scale, layers=tuple(layers),
                        observer=observer)
