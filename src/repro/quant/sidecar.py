"""The versioned quantization sidecar.

A ``QuantSidecar`` is the complete arithmetic contract of a quantized
program: one input scale plus one ``LayerQuant`` per compiled layer
(indexed by ``CompiledLayer.layer_id`` == spec index). It deliberately
lives OUTSIDE the 128-bit instruction words — the ISA stays fp-agnostic,
``save_program`` keeps its bit-exact recompile check, and the same
``Program`` can serve fp32 and int8 from one schedule. The sidecar joins
the program-cache key through ``digest()`` so two calibrations of the same
network never collide, and ``digest(schedule_key)`` binds it to a specific
instruction stream for the tamper check in ``from_program``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import jax.numpy as jnp
import numpy as np

FORMAT = "hybriddnn-quant/v1"


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """Per-layer quantization parameters (symmetric, zp = 0).

    ``in_scale``/``out_scale`` are the per-tensor dequantization scales of
    the layer's stored int8 input/output (``x_fp ~= x_i8 * scale``);
    ``wgt_scale`` is the weight scale for CONV/FC/DW — a scalar
    (per-tensor) or a tuple of per-output-channel scales (CONV/FC use
    per-channel: activations after 10+ layers are only as good as the
    worst-scaled filter, and per-channel removes that coupling at zero
    runtime cost since the epilogue multiplier just becomes a vector).
    Bias is stored int32 at scale ``in_scale * wgt_scale``.
    ``skip_scale`` is the ELTWISE second operand's scale.
    ``requantize=False`` marks scale-passthrough layers (POOL: max()
    commutes with a positive rescale, so out_scale == in_scale and no
    epilogue runs).
    """
    kind: str                       # "conv" | "pool" | "fc" | "eltwise" | "dw"
    in_scale: float
    out_scale: float
    wgt_scale: float | tuple[float, ...] | None = None
    skip_scale: float | None = None
    requantize: bool = True

    @property
    def multiplier(self):
        """int32 accumulator -> int8 output rescale: a float for per-tensor
        weights, a float32 ``(K,)`` vector (broadcasting over the channel
        axis) for per-channel ones."""
        if isinstance(self.wgt_scale, (tuple, list)):
            return (np.asarray(self.wgt_scale, np.float32)
                    * np.float32(self.in_scale) / np.float32(self.out_scale))
        return float(self.in_scale) * float(self.wgt_scale) / float(self.out_scale)


@dataclasses.dataclass(frozen=True)
class QuantSidecar:
    input_scale: float
    layers: tuple[LayerQuant, ...]
    observer: str = "percentile"    # provenance, not arithmetic

    # -- (de)serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "format": FORMAT,
            "observer": self.observer,
            "input_scale": self.input_scale,
            "layers": [dataclasses.asdict(lq) for lq in self.layers],
        }

    @classmethod
    def from_dict(cls, doc: dict) -> "QuantSidecar":
        if doc.get("format") != FORMAT:
            raise ValueError(
                f"unsupported quant sidecar format {doc.get('format')!r} "
                f"(this build reads {FORMAT!r})")
        layers = []
        for d in doc["layers"]:
            d = dict(d)
            if isinstance(d.get("wgt_scale"), list):  # per-channel: JSON
                d["wgt_scale"] = tuple(d["wgt_scale"])  # lists -> tuples
            layers.append(LayerQuant(**d))
        return cls(
            input_scale=float(doc["input_scale"]),
            layers=tuple(layers),
            observer=doc.get("observer", "percentile"),
        )

    # -- identity -----------------------------------------------------------
    def digest(self, schedule_key: str = "") -> str:
        """Content hash; pass a ``Program.schedule_key()`` to bind the
        sidecar to one instruction stream (the save/load tamper check)."""
        js = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256((js + "|" + schedule_key).encode()).hexdigest()[:16]

    # -- network-edge conversions ------------------------------------------
    @property
    def output_scale(self) -> float:
        return float(self.layers[-1].out_scale)

    def quantize_input(self, x):
        """fp -> int8 at the network input (round-half-even, clip)."""
        q = jnp.round(jnp.asarray(x, jnp.float32) / jnp.float32(self.input_scale))
        return jnp.clip(q, -127, 127).astype(jnp.int8)

    def dequantize_output(self, y_i8):
        return y_i8.astype(jnp.float32) * jnp.float32(self.output_scale)
