"""Activation-range observers for post-training calibration.

Both observers produce a per-tensor symmetric scale in the
``optim.compression`` convention (``scale = amax / 127``, zero_point = 0) —
``MinMaxObserver`` literally reuses ``compression.quantize_int8`` to derive
each batch's scale, so the calibration arithmetic and the gradient
compressor share one definition of "int8".
"""
from __future__ import annotations

import numpy as np

from repro.optim.compression import quantize_int8


class MinMaxObserver:
    """Running |max| over every observed batch (the conservative choice:
    no clipping, widest scale)."""

    def __init__(self) -> None:
        self._scale = 0.0

    def observe(self, x) -> None:
        _, scale = quantize_int8(np.asarray(x, np.float32))
        self._scale = max(self._scale, float(scale))

    @property
    def scale(self) -> float:
        if self._scale <= 0.0:
            raise ValueError("observer saw no data — calibrate first")
        return self._scale


class PercentileObserver:
    """Per-batch |x| percentile, running max across batches.

    Clips the far tail of the activation distribution so the 254 usable
    int8 codes cover the bulk of the range — the standard post-training
    trick when outliers would otherwise blow up the scale. (Running max of
    per-batch percentiles is an approximation of the pooled percentile;
    for calibration sets of a few batches it is equivalent in practice.)
    """

    def __init__(self, pct: float = 99.9) -> None:
        if not 0.0 < pct <= 100.0:
            raise ValueError(f"pct must be in (0, 100], got {pct}")
        self.pct = pct
        self._amax = 0.0

    def observe(self, x) -> None:
        a = np.abs(np.asarray(x, np.float32))
        self._amax = max(self._amax, float(np.percentile(a, self.pct)))

    @property
    def scale(self) -> float:
        if self._amax <= 0.0:
            raise ValueError("observer saw no data — calibrate first")
        return (self._amax + 1e-12) / 127.0


def make_observer(kind: str):
    if kind == "minmax":
        return MinMaxObserver()
    if kind == "percentile":
        return PercentileObserver()
    raise ValueError(f"unknown observer {kind!r} (want 'minmax' or 'percentile')")
