"""The int8 PE dispatch — shared by executor, interpreter, and Pallas paths.

All ops take int8 tensors, accumulate in int32 (exact — integer adds are
associative, so fused whole-layer and per-block lowerings of one stream
are *bitwise* identical), then requantize through a per-layer fp32
multiplier. ReLU runs on the int32 accumulator before the rescale, which
is exact because zero_point = 0. The XLA lowering uses
``lax.conv_general_dilated(..., preferred_element_type=int32)``; the
Pallas lowering routes im2col patches through the int8 GEMM kernel
(``kernels.gemm.int8``), whose epilogue fuses the same bias+ReLU+requant.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.hybrid_conv import (ConvSpec, DepthwiseSpec, FCSpec)
from repro.quant.sidecar import LayerQuant, QuantSidecar


def requantize(y_i32, mult, relu: bool):
    """int32 accumulator -> int8: optional ReLU, rescale, round, clip.
    ``mult`` is a scalar (per-tensor weights) or a ``(K,)`` vector
    (per-channel) broadcasting over the trailing channel axis."""
    if relu:
        y_i32 = jnp.maximum(y_i32, 0)
    y = jnp.round(y_i32.astype(jnp.float32) * jnp.asarray(mult, jnp.float32))
    return jnp.clip(y, -127, 127).astype(jnp.int8)


def quantize_tensor(x, scale: float):
    """fp -> int8 at a known scale (round-half-even, symmetric clip)."""
    q = jnp.round(jnp.asarray(x, jnp.float32) / jnp.float32(scale))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def qconv2d(x_i8, w_i8, b_i32, *, mult, stride: int = 1,
            padding="SAME", relu: bool = False,
            use_pallas: bool = False, interpret: bool | None = None):
    """int8 spatial convolution (Winograd is fp-only — the DSE keeps wino
    plans off quantized builds; see ``api.Accelerator.build``)."""
    if not use_pallas:
        y = lax.conv_general_dilated(
            x_i8, w_i8, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32)
        return requantize(y + b_i32.astype(jnp.int32), mult, relu)
    # im2col -> int8 GEMM PE (patch ordering (c, r, s) matches
    # kernels/spatial_conv's weight reshape convention)
    from repro.kernels.gemm.int8 import quantized_matmul
    n = x_i8.shape[0]
    r, s, c, k = w_i8.shape
    patches = lax.conv_general_dilated_patches(
        x_i8, (r, s), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))      # (N, HO, WO, C*R*S)
    ho, wo = patches.shape[1], patches.shape[2]
    a = patches.reshape(n * ho * wo, c * r * s)
    b = w_i8.transpose(2, 0, 1, 3).reshape(c * r * s, k)
    y = quantized_matmul(a, b, b_i32.astype(jnp.int32), mult=mult,
                         relu=relu, interpret=interpret)
    return y.reshape(n, ho, wo, k)


def qdense(x_i8, w_i8, b_i32, *, mult, relu: bool = False,
           use_pallas: bool = False, interpret: bool | None = None):
    """int8 FC through the shared GEMM PE (int32 accumulate)."""
    if use_pallas:
        from repro.kernels.gemm.int8 import quantized_matmul
        return quantized_matmul(x_i8, w_i8, b_i32.astype(jnp.int32),
                                mult=mult, relu=relu,
                                interpret=interpret)
    y = jnp.dot(x_i8, w_i8, preferred_element_type=jnp.int32)
    return requantize(y + b_i32.astype(jnp.int32), mult, relu)


def qeltwise(a_i8, b_i8, lq: LayerQuant, relu: bool):
    """Residual add across two int8 operands with different scales:
    dequantize both into the OUTPUT scale's units, add, ReLU, round, clip.
    Elementwise and deterministic, so executor == interpreter bitwise."""
    ma = jnp.float32(float(lq.in_scale) / float(lq.out_scale))
    mb = jnp.float32(float(lq.skip_scale) / float(lq.out_scale))
    y = a_i8.astype(jnp.float32) * ma + b_i8.astype(jnp.float32) * mb
    if relu:
        y = jnp.maximum(y, 0.0)
    return jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)


def qdepthwise(x_i8, w_i8, b_i32, *, mult, stride: int = 1,
               padding="SAME", relu: bool = False):
    """int8 depthwise conv: grouped int32 conv + requant (VPU work — no
    Pallas GEMM variant, same as the fp32 path)."""
    c = x_i8.shape[-1]
    y = lax.conv_general_dilated(
        x_i8, w_i8, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c, preferred_element_type=jnp.int32)
    return requantize(y + b_i32.astype(jnp.int32), mult, relu)


def quantize_params(specs, params, sidecar: QuantSidecar):
    """fp32 ``[(w, b), ...]`` -> int8 weights + int32 bias per the sidecar.

    Bias is stored at scale ``in_scale * wgt_scale`` — the int32
    accumulator's own units — so the epilogue adds it before the single
    rescale.
    """
    out, pi = [], 0
    for i, spec in enumerate(specs):
        if not isinstance(spec, (ConvSpec, FCSpec, DepthwiseSpec)):
            continue
        lq = sidecar.layers[i]
        w, b = params[pi]
        pi += 1
        # per-channel scales broadcast over the trailing (output-channel)
        # weight axis and elementwise over the bias
        ws = np.asarray(lq.wgt_scale, np.float32)
        w_i8 = np.clip(np.round(np.asarray(w, np.float32) / ws),
                       -127, 127).astype(np.int8)
        b_i32 = np.round(np.asarray(b, np.float32)
                         / (np.float32(lq.in_scale) * ws)).astype(np.int32)
        out.append((jnp.asarray(w_i8), jnp.asarray(b_i32)))
    if pi != len(params):
        raise ValueError(
            f"params/specs mismatch: {len(params)} param entries for "
            f"{pi} parameterized layers")
    return out
