"""Mesh axes, partition rules, and sharding helpers (DP/TP/EP/SP).

Logical-axis scheme (MaxText-style): every tensor dimension is tagged with a
logical name; ``Rules`` maps logical names to mesh axes. The production mesh
is ``("pod", "data", "model")`` multi-pod or ``("data", "model")`` single-pod:
``pod``+``data`` carry data parallelism (the paper's NI-instances analog),
``model`` carries TP / EP / SP.

``logical_to_mesh``/``shard`` are no-ops when no rules are active, so the same
model code runs on one CPU device and on the 512-chip dry-run mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis names
BATCH = "batch"        # -> (pod, data)
SEQ = "seq"            # -> model (sequence parallelism for caches/long ctx)
EMBED = "embed"        # -> None (replicated d_model)
HEADS = "heads"        # -> model (TP over attention heads)
KV_HEADS = "kv_heads"  # -> model
MLP = "mlp"            # -> model (TP over FFN hidden)
VOCAB = "vocab"        # -> model (TP over vocab/logits)
EXPERT = "expert"      # -> model (EP)
STACK = "stack"        # -> None (scan-stacked layer dim)
SSM_HEADS = "ssm_heads"
CONV = "conv"


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    def spec(self, *logical: str | None) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
            elif name == BATCH:
                parts.append(self.dp_axes if len(self.dp_axes) > 1
                             else self.dp_axes[0])
            elif name in (SEQ, HEADS, KV_HEADS, MLP, VOCAB, EXPERT, SSM_HEADS):
                parts.append(self.tp_axis)
            elif name in (EMBED, STACK, CONV):
                parts.append(None)
            else:
                raise ValueError(f"unknown logical axis {name!r}")
        return P(*parts)

    def sharding(self, *logical: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def make_rules(mesh: Mesh) -> Rules:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return Rules(mesh=mesh, dp_axes=dp or (mesh.axis_names[0],))


# --------------------------------------------------------------------------
# active-rules context (thread-local so model code stays pure-looking)
# --------------------------------------------------------------------------

_state = threading.local()


def current_rules() -> Rules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Apply a sharding constraint if rules are active; else identity.

    Divisibility-aware: a dimension whose size does not divide by its mapped
    mesh axes is left unconstrained (GSPMD's uneven-shard padding causes
    involuntary full rematerialization copies — e.g. 8 KV heads or 40 query
    heads on a 16-way model axis)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(*logical)
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
    parts = []
    for dim, entry in enumerate(spec):
        if entry is None:
            parts.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        parts.append(entry if x.shape[dim] % total == 0 else None)
    parts += [None] * (x.ndim - len(parts))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts)))


# --------------------------------------------------------------------------
# parameter partition specs (path-based rules over the params pytree)
# --------------------------------------------------------------------------

# leaf-name -> logical axes per dimension, EXCLUDING the leading scan-stack
# dim which is added automatically for stacked leaves.
_PARAM_RULES: dict[str, tuple[str | None, ...]] = {
    "embed": (None, MLP),   # d-sharded: token take() stays local; a
                         # vocab-sharded table all-gathers 2-4GB/step
    "lm_head": (None, VOCAB),
    "pos_embed": (None, None),
    "wq": (None, HEADS),
    "wk": (None, KV_HEADS),
    "wv": (None, KV_HEADS),
    "wo": (HEADS, None),
    "bq": (HEADS,), "bk": (KV_HEADS,), "bv": (KV_HEADS,), "bo": (None,),
    "q_norm": (None,),
    "k_norm": (None,),
    "w_gate": (None, MLP),
    "w_up": (None, MLP),
    "w_down": (MLP, None),
    "w_in": (None, MLP),
    "w_out": (MLP, None),
    "b_in": (MLP,), "b_out": (None,),
    # MoE: leading expert dim
    "we_gate": (EXPERT, None, None),
    "we_up": (EXPERT, None, None),
    "we_down": (EXPERT, None, None),
    "router": (None, EXPERT),
    # mamba2 / SSD
    "in_proj": (None, MLP),
    "out_proj": (MLP, None),
    "conv_w": (None, MLP),
    "conv_b": (MLP,),
    "A_log": (SSM_HEADS,),
    "D": (SSM_HEADS,),
    "dt_bias": (SSM_HEADS,),
    "norm": (None,),
    "norm2": (None,),
    "norm3": (None,),
    "final_norm": (None,),
    "enc_norm": (None,),
    "scale": (None,),
}


def _axes_size(rules: Rules, entry) -> int:
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
    axes = entry if isinstance(entry, tuple) else (entry,)
    total = 1
    for a in axes:
        total *= sizes[a]
    return total


def _drop_indivisible(spec: P, shape, rules: Rules) -> P:
    """jit in_shardings require exact divisibility — drop axes that don't."""
    parts = []
    for dim, entry in enumerate(spec):
        if entry is None or shape[dim] % _axes_size(rules, entry) == 0:
            parts.append(entry)
        else:
            parts.append(None)
    return P(*parts)


def _spec_for_path(path, leaf, rules: Rules, stacked_depth: int) -> P:
    name = None
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "name", None))
        if isinstance(key, str):
            name = key
            break
    if name is None or name not in _PARAM_RULES:
        return P()
    logical = _PARAM_RULES[name]
    ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    if ndim == len(logical) + 1:      # scan-stacked leaf: leading L dim
        logical = (None,) + logical
    elif ndim == len(logical) + 2:    # stacked + grouped (e.g. vlm groups)
        logical = (None, None) + logical
    elif ndim != len(logical):
        return P()
    return _drop_indivisible(rules.spec(*logical), leaf.shape, rules)


def param_specs(params, rules: Rules):
    """PartitionSpec pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_path(path, leaf, rules, 1), params)


def param_shardings(params, rules: Rules):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(rules.mesh, spec),
        param_specs(params, rules),
        is_leaf=lambda x: isinstance(x, P))


def zero1_specs(params, rules: Rules):
    """ZeRO-1 optimizer-state specs: param spec + DP sharding on dim 0.

    The AdamW m/v tensors are additionally sharded over the data axes along
    their first dimension (GSPMD pads uneven shards), so optimizer state
    scales with 1/(pod*data) — the ZeRO-1 memory win without changing the
    parameter layout.
    """
    dp = rules.dp_axes

    def widen(spec: P, leaf) -> P:
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        if ndim == 0:
            return P()
        parts = list(spec) + [None] * (ndim - len(spec))
        d0 = parts[0]
        if d0 is None:
            cand = dp if len(dp) > 1 else dp[0]
        elif isinstance(d0, str):
            cand = (d0,) + dp
        else:
            cand = tuple(d0) + dp
        if leaf.shape[0] % _axes_size(rules, cand) == 0:
            parts[0] = cand
        return _drop_indivisible(P(*parts), leaf.shape, rules)

    specs = param_specs(params, rules)
    return jax.tree_util.tree_map(widen, specs, params,
                                  is_leaf=lambda x: isinstance(x, P))
