"""Deterministic synthetic token pipeline — shard-aware, prefetched.

Production posture: every data-parallel shard computes its own slice of the
global batch from a (seed, step, shard) counter-mode PRNG, so (a) no host is
a data bottleneck, (b) restart from checkpoint is bit-exact (the stream is a
pure function of the step), and (c) elastic re-sharding just changes the
shard->rows mapping. A background thread keeps ``prefetch`` batches ahead.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    distribution: str = "zipf"   # "zipf" (learnable marginals) | "uniform"


def batch_for_step(cfg: DataConfig, step: int, shard: int = 0,
                   n_shards: int = 1) -> dict[str, np.ndarray]:
    """The shard's rows of the global batch at ``step``. Deterministic."""
    assert cfg.global_batch % n_shards == 0
    rows = cfg.global_batch // n_shards
    # counter-mode: seed ^ step ^ shard — independent of process layout
    rng = np.random.Generator(np.random.Philox(
        key=cfg.seed, counter=[0, 0, step, shard]))
    if cfg.distribution == "zipf":
        # skewed marginals: training has signal (uniform tokens cap the
        # achievable loss at ln(V) — nothing to learn)
        raw = rng.geometric(p=min(0.5, 8.0 / cfg.vocab_size),
                            size=(rows, cfg.seq_len + 1)) - 1
        tokens = np.minimum(raw, cfg.vocab_size - 1).astype(np.int32)
    else:
        tokens = rng.integers(0, cfg.vocab_size,
                              size=(rows, cfg.seq_len + 1), dtype=np.int32)
    # next-token LM targets
    return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}


class PrefetchingLoader:
    """Iterator with a background prefetch thread (depth ``prefetch``)."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_for_step(self.cfg, step, self.shard, self.n_shards)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
