"""Pallas kernel bodies for Winograd input/output transforms.

Input transform:  tiles (T, PT, PT, C) -> V (PT^2, T, C)   [V = B^T d B]
Output transform: M (PT^2, T, K)       -> Y (T, m, m, K)   [Y = A^T M A]

Both are blocked over (tile, channel); the tiny PT x PT transform matrices are
baked into the kernel as constants (on TPU these contractions are VPU work —
they are reductions of length 4 or 6, far below MXU granularity, exactly like
the adder trees the paper uses next to its DSP GEMM cores).

The output transform optionally fuses bias add + ReLU — the paper's
accumulating-buffer epilogue — saving one full HBM round-trip of the
pre-activation feature map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.winograd import pt_for, transform_matrices
from repro.kernels.common import INTERPRET, round_up


def _input_transform_kernel(bt_ref, d_ref, v_ref, *, m: int):
    bt = bt_ref[...].astype(jnp.float32)          # (PT, PT) = B^T
    d = d_ref[...].astype(jnp.float32)            # (BT, PT, PT, BC)
    # V[i,j] = sum_{p,q} BT[i,p] * d[p,q] * BT[j,q]
    v = jnp.einsum("ip,tpqc,jq->ijtc", bt, d, bt)
    pt = pt_for(m)
    bt_sz, _, _, bc = d.shape
    v_ref[...] = v.reshape(pt * pt, bt_sz, bc).astype(v_ref.dtype)


def _output_transform_kernel(at_ref, m_ref, b_ref, y_ref, *, m: int, relu: bool):
    at = at_ref[...].astype(jnp.float32)          # (m, PT) = A^T
    pt = pt_for(m)
    mm = m_ref[...].astype(jnp.float32)           # (PT^2, BT, BK)
    _, bt_sz, bk = mm.shape
    mm = mm.reshape(pt, pt, bt_sz, bk)
    y = jnp.einsum("ip,pqtk,jq->tijk", at, mm, at)  # (BT, m, m, BK)
    y = y + b_ref[...].astype(jnp.float32)          # (1, 1, 1, BK) broadcast
    if relu:
        y = jnp.maximum(y, 0.0)
    y_ref[...] = y.astype(y_ref.dtype)


def input_transform_kernel(
    tiles: jax.Array,  # (T, PT, PT, C) padded: T % bt == 0, C % bc == 0
    *,
    m: int,
    bt: int,
    bc: int,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:       # (PT^2, T, C)
    if interpret is None:
        interpret = INTERPRET
    t, pt, _, c = tiles.shape
    assert pt == pt_for(m) and t % bt == 0 and c % bc == 0
    grid = (t // bt, c // bc)
    btm, _, _ = transform_matrices(m, jnp.float32)
    return pl.pallas_call(
        functools.partial(_input_transform_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((pt, pt), lambda ti, ci: (0, 0)),
            pl.BlockSpec((bt, pt, pt, bc), lambda ti, ci: (ti, 0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((pt * pt, bt, bc), lambda ti, ci: (0, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((pt * pt, t, c), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(btm, tiles)


def output_transform_kernel(
    m_arr: jax.Array,   # (PT^2, T, K) padded
    bias: jax.Array,    # (K,)
    *,
    m: int,
    bt: int,
    bk: int,
    relu: bool = False,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:         # (T, m, m, K)
    if interpret is None:
        interpret = INTERPRET
    pt2, t, k = m_arr.shape
    pt = pt_for(m)
    assert pt2 == pt * pt and t % bt == 0 and k % bk == 0
    grid = (t // bt, k // bk)
    bias4 = bias.reshape(1, 1, 1, k)
    _, _, atm = transform_matrices(m, jnp.float32)
    return pl.pallas_call(
        functools.partial(_output_transform_kernel, m=m, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, pt), lambda ti, ki: (0, 0)),
            pl.BlockSpec((pt * pt, bt, bk), lambda ti, ki: (0, ti, ki)),
            pl.BlockSpec((1, 1, 1, bk), lambda ti, ki: (0, 0, 0, ki)),
        ],
        out_specs=pl.BlockSpec((bt, m, m, bk), lambda ti, ki: (ti, 0, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((t, m, m, k), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(atm, m_arr, bias4)
