"""Pure-jnp oracles for the Winograd transform kernels and full conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.winograd import (
    pt_for,
    transform_matrices,
    winograd_conv2d_reference,
)


def input_transform_ref(tiles: jax.Array, m: int, out_dtype=jnp.float32) -> jax.Array:
    """(T, PT, PT, C) -> (PT^2, T, C)."""
    bt, _, _ = transform_matrices(m, jnp.float32)
    t, pt, _, c = tiles.shape
    v = jnp.einsum("ip,tpqc,jq->ijtc", bt, tiles.astype(jnp.float32), bt)
    return v.reshape(pt * pt, t, c).astype(out_dtype)


def output_transform_ref(m_arr: jax.Array, bias: jax.Array, m: int,
                         relu: bool = False, out_dtype=jnp.float32) -> jax.Array:
    """(PT^2, T, K), (K,) -> (T, m, m, K)."""
    _, _, at = transform_matrices(m, jnp.float32)
    pt = pt_for(m)
    pt2, t, k = m_arr.shape
    mm = m_arr.astype(jnp.float32).reshape(pt, pt, t, k)
    y = jnp.einsum("ip,pqtk,jq->tijk", at, mm, at)
    y = y + bias.astype(jnp.float32).reshape(1, 1, 1, k)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(out_dtype)


def conv2d_ref(x_nhwc: jax.Array, g_rsck: jax.Array, padding="SAME",
               bias: jax.Array | None = None, relu: bool = False,
               stride: int = 1) -> jax.Array:
    """Direct convolution oracle (lax.conv), fp32 accumulation."""
    y = lax.conv_general_dilated(
        x_nhwc.astype(jnp.float32), g_rsck.astype(jnp.float32),
        (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x_nhwc.dtype)


winograd_conv2d_ref = winograd_conv2d_reference
