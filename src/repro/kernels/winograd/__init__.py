"""Pallas TPU kernels for the Winograd input/output transforms.

The paper's load manager performs the online ``B^T d B`` input transform and
the save manager the ``A^T M A`` output transform (Sec. 4.2.3). Here each is a
Pallas kernel blocked over (tiles x channels); the EWMM-as-GEMM middle stage
is the shared ``kernels/gemm`` PE with leading batch PT^2.
"""
from repro.kernels.winograd.ops import (
    input_transform,
    output_transform,
    winograd_apply_pretransformed_pallas,
    winograd_conv2d,
)

__all__ = ["input_transform", "output_transform",
           "winograd_apply_pretransformed_pallas", "winograd_conv2d"]
