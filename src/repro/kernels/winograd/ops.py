"""Jitted Winograd convolution assembled from Pallas stages.

Pipeline (the paper's COMP-module datapath, Sec. 4.2):

  tile extract (XLA gather)           — LOAD manager addressing
  -> input_transform  (Pallas)        — LOAD manager online B^T d B
  -> batched GEMM, batch PT^2 (Pallas, kernels/gemm) — the PE, Eq. 2
  -> output_transform (Pallas, fused bias+ReLU)      — SAVE manager A^T M A
  -> tile scatter (XLA reshape)       — SAVE manager layout write

Weights are transformed offline (``transform_weights``), matching Sec. 4.2.3.
Kernels with R, S > 3 use the paper's kernel-decomposition (Sec. 4.2.5).
``dataflow`` ("is"/"ws") is forwarded to the GEMM grid order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.winograd import (
    R_WINO,
    decompose_kernel,
    pt_for,
    tile_input,
    transform_weights,
)
from repro.kernels.common import LANE, SUBLANE, round_up
from repro.kernels.gemm.kernel import batched_matmul_kernel
from repro.kernels.winograd.kernel import (
    input_transform_kernel,
    output_transform_kernel,
)


def _pick_tile_blocks(t: int, c: int, k: int) -> tuple[int, int, int]:
    """(bt, bc, bk): tile-block, channel blocks. MXU-aligned where possible."""
    bt = min(round_up(t, SUBLANE), 256)
    bc = min(round_up(c, LANE), 256)
    bk = min(round_up(k, LANE), 256)
    return bt, bc, bk


def _pad_for_conv(x_nhwc, rr, ss, padding):
    """SAME/VALID input padding for a VALID rr x ss conv of the result."""
    if padding.upper() == "SAME":
        ph, pw = (rr - 1) // 2, (ss - 1) // 2
        return jnp.pad(x_nhwc, ((0, 0), (ph, rr - 1 - ph),
                                (pw, ss - 1 - pw), (0, 0)))
    if padding.upper() == "VALID":
        return x_nhwc
    raise ValueError(padding)


def _finish_output(m_acc, bias, *, m, bt, bk, relu, interpret, geom,
                   ho, wo, k, kp, out_dtype):
    """Shared SAVE-manager epilogue: Pallas A^T M A (fused bias/ReLU), then
    the tile scatter/crop back to NHWC. One copy for both entry points so
    the reshape/crop arithmetic can't drift."""
    n, nh, nw, t, tp = geom
    bias_p = jnp.pad(bias.astype(jnp.float32), (0, kp - k))
    y = output_transform_kernel(m_acc, bias_p, m=m, bt=bt, bk=bk, relu=relu,
                                out_dtype=jnp.float32, interpret=interpret)
    y = y[:t].reshape(n, nh, nw, m, m, kp).transpose(0, 1, 3, 2, 4, 5)
    y = y.reshape(n, nh * m, nw * m, kp)[:, :ho, :wo, :k]
    return y.astype(out_dtype)


def _wino_conv_piece(x, u_flat, m, t_blocks, out_dtype, dataflow, interpret):
    """One r x r sub-kernel's Winograd conv. x already padded+shifted.

    u_flat: (PT^2, Cp, Kp) transformed weights (already channel-padded).
    Returns M-space output (PT^2, T, Kp) accumulated later, plus tile geometry.
    """
    tiles, (nh, nw) = tile_input(x, m)
    n = x.shape[0]
    pt = pt_for(m)
    c = tiles.shape[-1]
    t = n * nh * nw
    bt, bc, bk = t_blocks
    tp, cp = round_up(t, bt), round_up(c, bc)
    tiles = tiles.reshape(t, pt, pt, c)
    if (tp, cp) != (t, c):
        tiles = jnp.pad(tiles, ((0, tp - t), (0, 0), (0, 0), (0, cp - c)))
    v = input_transform_kernel(tiles, m=m, bt=bt, bc=bc,
                               out_dtype=jnp.float32, interpret=interpret)
    mm = batched_matmul_kernel(
        v, u_flat, bm=bt, bn=bk, bk=bc, dataflow=dataflow,
        out_dtype=jnp.float32, interpret=interpret)        # (PT^2, Tp, Kp)
    return mm, (n, nh, nw, t, tp)


@functools.partial(
    jax.jit,
    static_argnames=("m", "padding", "relu", "dataflow", "out_dtype", "interpret"),
)
def winograd_conv2d(
    x_nhwc: jax.Array,
    g_rsck: jax.Array,
    bias: jax.Array | None = None,
    *,
    m: int = 4,
    padding: str = "SAME",
    relu: bool = False,
    dataflow: str = "is",
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Winograd F(m x m, 3 x 3) convolution, stride 1, NHWC/HWIO."""
    out_dtype = out_dtype or x_nhwc.dtype
    n, h, w, c = x_nhwc.shape
    rr, ss, _, k = g_rsck.shape
    if bias is None:
        bias = jnp.zeros((k,), jnp.float32)

    x = _pad_for_conv(x_nhwc, rr, ss, padding)
    ho, wo = x.shape[1] - rr + 1, x.shape[2] - ss + 1

    if (rr, ss) == (R_WINO, R_WINO):
        pieces = [(0, 0, g_rsck)]
    else:
        pieces = decompose_kernel(g_rsck, m)
        x = jnp.pad(x, ((0, 0),
                        (0, (-(-rr // R_WINO)) * R_WINO - rr),
                        (0, (-(-ss // R_WINO)) * R_WINO - ss),
                        (0, 0)))

    # geometry is identical across pieces; block sizes from the first
    t_est = n * (-(-ho // m)) * (-(-wo // m))
    bt, bc, bk = _pick_tile_blocks(t_est, c, k)
    cp, kp = round_up(c, bc), round_up(k, bk)
    pt = pt_for(m)

    m_acc = None
    geom = None
    for (oh, ow, sub) in pieces:
        u = transform_weights(sub, m).astype(jnp.float32)  # (PT, PT, C, K)
        u = u.reshape(pt * pt, c, k)
        if (cp, kp) != (c, k):
            u = jnp.pad(u, ((0, 0), (0, cp - c), (0, kp - k)))
        xs = x[:, oh:oh + ho + R_WINO - 1, ow:ow + wo + R_WINO - 1, :]
        mm, geom = _wino_conv_piece(xs, u, m, (bt, bc, bk), out_dtype,
                                    dataflow, interpret)
        m_acc = mm if m_acc is None else m_acc + mm       # accumulate in M-space

    return _finish_output(m_acc, bias, m=m, bt=bt, bk=bk, relu=relu,
                          interpret=interpret, geom=geom, ho=ho, wo=wo,
                          k=k, kp=kp, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("m", "padding", "relu", "dataflow", "out_dtype", "interpret"),
)
def winograd_apply_pretransformed_pallas(
    x_nhwc: jax.Array,
    u_ptck: jax.Array,      # (PT, PT, C, K) offline-transformed weights
    bias: jax.Array | None = None,
    *,
    m: int = 4,
    padding: str = "SAME",
    relu: bool = False,
    dataflow: str = "is",
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Winograd conv from U-space weights, all three stages on Pallas.

    The executor/runtime COMP path: the paper stores *transformed* weights in
    DRAM (Sec. 4.2.3), so the PE consumes U directly — no G g G^T at run
    time. Mirrors ``core.winograd.winograd_apply_pretransformed`` (the XLA
    reference) stage for stage: tile extract -> ``input_transform_kernel`` ->
    the PT^2-batched GEMM -> ``output_transform_kernel`` with the bias/ReLU
    epilogue fused. r = s = 3, stride 1.
    """
    out_dtype = out_dtype or x_nhwc.dtype
    n, h, w, c = x_nhwc.shape
    pt, _, _, k = u_ptck.shape
    assert pt == pt_for(m), (pt, m)
    if bias is None:
        bias = jnp.zeros((k,), jnp.float32)

    x = _pad_for_conv(x_nhwc, R_WINO, R_WINO, padding)
    ho, wo = x.shape[1] - R_WINO + 1, x.shape[2] - R_WINO + 1

    # same tile/GEMM pipeline as winograd_conv2d, minus the weight
    # transform — U comes from DRAM (shared _wino_conv_piece /
    # _finish_output so the tiling, block-padding and scatter/crop
    # arithmetic can't drift between the two entry points)
    t_est = n * (-(-ho // m)) * (-(-wo // m))
    bt, bc, bk = _pick_tile_blocks(t_est, c, k)
    cp, kp = round_up(c, bc), round_up(k, bk)
    u = u_ptck.astype(jnp.float32).reshape(pt * pt, c, k)
    if (cp, kp) != (c, k):
        u = jnp.pad(u, ((0, 0), (0, cp - c), (0, kp - k)))
    mm, geom = _wino_conv_piece(
        x, u, m, (bt, bc, bk), out_dtype, dataflow, interpret)
    return _finish_output(mm, bias, m=m, bt=bt, bk=bk, relu=relu,
                          interpret=interpret, geom=geom, ho=ho, wo=wo,
                          k=k, kp=kp, out_dtype=out_dtype)


def input_transform(tiles, m, **kw):
    """Padded public wrapper for the input-transform Pallas kernel."""
    t, pt, _, c = tiles.shape
    bt, bc, _ = _pick_tile_blocks(t, c, c)
    tp, cp = round_up(t, bt), round_up(c, bc)
    tiles = jnp.pad(tiles, ((0, tp - t), (0, 0), (0, 0), (0, cp - c)))
    v = input_transform_kernel(tiles, m=m, bt=bt, bc=bc, **kw)
    return v[:, :t, :c]


def output_transform(m_arr, bias, m, relu=False, **kw):
    """Padded public wrapper for the output-transform Pallas kernel."""
    pt2, t, k = m_arr.shape
    bt, _, bk = _pick_tile_blocks(t, k, k)
    tp, kp = round_up(t, bt), round_up(k, bk)
    m_arr = jnp.pad(m_arr, ((0, 0), (0, tp - t), (0, kp - k)))
    bias_p = jnp.pad(bias.astype(jnp.float32), (0, kp - k))
    y = output_transform_kernel(m_arr, bias_p, m=m, bt=bt, bk=bk, relu=relu, **kw)
    return y[:t, :, :, :k]
