"""Spatial convolution = im2col + the Spatial-mode Pallas GEMM PE.

im2col is the LOAD manager's Spatial-mode addressing (Sec. 4.2.3: "directly
loads input feature maps and broadcasts them to the PE"): an XLA gather that
produces the (T, C*R*S) patch matrix; the matmul against (C*R*S, K) reshaped
weights runs on the dedicated ``kernels/spatial_conv/kernel.py`` Pallas PE
(all GEMM cores merged into one broadcast array, Sec. 4.2.2) with the bias /
ReLU epilogue fused at the accumulator flush.

``padding`` accepts the usual "SAME"/"VALID" strings or an explicit
``((top, bottom), (left, right))`` pair — the executor's blocked lowering
slices the vertical halo itself and passes explicit horizontal pads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.common import LANE, SUBLANE, round_up
from repro.kernels.spatial_conv.kernel import conv_gemm_kernel


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "relu", "dataflow", "out_dtype", "interpret"),
)
def spatial_conv2d(
    x_nhwc: jax.Array,
    g_rsck: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding="SAME",
    relu: bool = False,
    dataflow: str = "is",
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    out_dtype = out_dtype or x_nhwc.dtype
    n, h, w, c = x_nhwc.shape
    r, s, _, k = g_rsck.shape
    if bias is None:
        bias = jnp.zeros((k,), jnp.float32)
    if not isinstance(padding, str):
        # explicit ((top, bottom), (left, right)). Must arrive hashable (it
        # is a jit static arg); coerce any numpy ints to plain ints for the
        # patches call
        padding = tuple(tuple(int(v) for v in p) for p in padding)

    # im2col: (N, HO, WO, C*R*S), feature dim ordered channel-major (C, R, S)
    patches = lax.conv_general_dilated_patches(
        x_nhwc, filter_shape=(r, s), window_strides=(stride, stride),
        padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    _, ho, wo, crs = patches.shape
    t = n * ho * wo
    a = patches.reshape(t, crs)                                # (T, C*R*S)
    # match the channel-major patch ordering: (R,S,C,K) -> (C,R,S,K)
    b = g_rsck.transpose(2, 0, 1, 3).reshape(crs, k)

    bm = min(round_up(t, SUBLANE), 256)
    bk_ = min(round_up(crs, LANE), 512)
    bn = min(round_up(k, LANE), 256)
    tp, crsp, kp = round_up(t, bm), round_up(crs, bk_), round_up(k, bn)
    a = jnp.pad(a, ((0, tp - t), (0, crsp - crs)))
    b = jnp.pad(b, ((0, crsp - crs), (0, kp - k)))
    bias_p = jnp.pad(bias.astype(jnp.float32), (0, kp - k))

    y = conv_gemm_kernel(
        a, b, bias_p, bm=bm, bn=bn, bk=bk_, dataflow=dataflow, relu=relu,
        out_dtype=jnp.float32, interpret=interpret)             # (Tp, Kp)
    y = y[:t, :k].reshape(n, ho, wo, k)
    return y.astype(out_dtype)
