"""Direct-convolution oracle for the spatial kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def spatial_conv2d_ref(
    x_nhwc: jax.Array,
    g_rsck: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
) -> jax.Array:
    y = lax.conv_general_dilated(
        x_nhwc.astype(jnp.float32), g_rsck.astype(jnp.float32),
        (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x_nhwc.dtype)
