"""Spatial (direct) convolution on the shared GEMM PE.

The paper's Spatial mode merges all GEMM cores into one large broadcast array
(Sec. 4.2.2) — here: im2col patch extraction followed by the *same*
``kernels/gemm`` Pallas kernel with a singleton leading batch (PT^2 = 1).
"""
from repro.kernels.spatial_conv.ops import spatial_conv2d

__all__ = ["spatial_conv2d"]
