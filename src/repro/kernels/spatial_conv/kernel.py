"""Pallas TPU kernel: the Spatial-mode PE — an im2col patch GEMM.

The paper's Spatial mode merges all ``PI x PO`` GEMM cores into one large
broadcast array (Sec. 4.2.2): a single 2-D GEMM over the im2col patch matrix
``(T, C*R*S) @ (C*R*S, K)`` with the accumulating-buffer epilogue (bias add +
optional ReLU) fused at the flush. Unlike ``kernels/gemm`` this kernel has no
leading Winograd-batch axis — Spatial conv is ONE GEMM, so the grid is the
plain blocked ``(Mb, Nb, Kb)`` iteration with the paper's two dataflows:

* ``"is"`` (Input Stationary)  — grid ``(Mb, Nb, Kb)``: a patch block-row
  stays VMEM-resident while all weight block-columns sweep past it.
* ``"ws"`` (Weight Stationary) — grid ``(Nb, Mb, Kb)``: a weight block-column
  stays resident while patch block-rows stream through.

``K`` is innermost in both orders so one fp32 VMEM scratch tile carries the
partial sums (the paper's accumulating output buffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.common import INTERPRET


def _conv_gemm_body(p_ref, w_ref, bias_ref, o_ref, acc_ref, *,
                    n_kb: int, relu: bool):
    """One (m, n, k) grid step: acc += P[m,k] @ W[k,n]; epilogue at flush."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(p_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_kb - 1)
    def _flush():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)  # (1, BN) bcast
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def conv_gemm_kernel(
    patches: jax.Array,     # (T, CRS) im2col patch matrix, block-padded
    weights: jax.Array,     # (CRS, K) reshaped kernel, block-padded
    bias: jax.Array,        # (K,) fp32, block-padded
    *,
    bm: int,
    bn: int,
    bk: int,
    dataflow: str = "is",   # "is" | "ws"
    relu: bool = False,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:             # (T, K)
    """Raw pallas_call wrapper. Shapes must already be padded to block multiples."""
    if interpret is None:
        interpret = INTERPRET
    t, crs = patches.shape
    crs2, k = weights.shape
    assert crs == crs2, (patches.shape, weights.shape)
    assert t % bm == 0 and k % bn == 0 and crs % bk == 0, \
        (patches.shape, weights.shape, bm, bn, bk)
    n_kb = crs // bk

    if dataflow == "is":
        grid = (t // bm, k // bn, n_kb)
        p_map = lambda mi, ni, ki: (mi, ki)
        w_map = lambda mi, ni, ki: (ki, ni)
        o_map = lambda mi, ni, ki: (mi, ni)
        b_map = lambda mi, ni, ki: (0, ni)
    elif dataflow == "ws":
        grid = (k // bn, t // bm, n_kb)
        p_map = lambda ni, mi, ki: (mi, ki)
        w_map = lambda ni, mi, ki: (ki, ni)
        o_map = lambda ni, mi, ki: (mi, ni)
        b_map = lambda ni, mi, ki: (0, ni)
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    return pl.pallas_call(
        functools.partial(_conv_gemm_body, n_kb=n_kb, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), p_map),
            pl.BlockSpec((bk, bn), w_map),
            pl.BlockSpec((1, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((t, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(patches, weights, bias.reshape(1, -1))
