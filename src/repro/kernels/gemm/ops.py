"""Jitted public wrappers around the batched GEMM Pallas kernel.

Handles padding to block multiples, block-shape selection (the PI/PO/PT
parallel-factor analog: MXU wants the last dim a multiple of 128 and the
second-to-last a multiple of 8), and un-padding of the result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import LANE, SUBLANE, cdiv, round_up
from repro.kernels.gemm.kernel import batched_matmul_kernel


def pick_block_shapes(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Choose (bm, bk, bn) hardware-aligned block shapes.

    Heuristic mirrors the paper's DSE Step (1): grow parallel factors until the
    VMEM working set would be exceeded. Working set per step is
    bm*bk + bk*bn + bm*bn fp32 words; we stay well under VMEM with margin for
    double buffering.
    """
    bm = min(round_up(m, SUBLANE), 512)
    bn = min(round_up(n, LANE), 512)
    bk = min(round_up(k, LANE), 512)
    return bm, bk, bn


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "dataflow", "out_dtype", "interpret"),
)
def batched_matmul(
    a: jax.Array,              # (G, M, K)
    b: jax.Array,              # (G, K, N)
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    dataflow: str = "is",
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    g, m, k = a.shape
    _, _, n = b.shape
    dbm, dbk, dbn = pick_block_shapes(m, k, n)
    bm = bm or dbm
    bn = bn or dbn
    bk = bk or dbk

    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, 0), (0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, 0), (0, kp - k), (0, np_ - n)))

    out = batched_matmul_kernel(
        a, b, bm=bm, bn=bn, bk=bk, dataflow=dataflow,
        out_dtype=out_dtype, interpret=interpret,
    )
    if (mp, np_) != (m, n):
        out = out[:, :m, :n]
    return out


def matmul(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """2-D convenience wrapper: (M, K) @ (K, N)."""
    return batched_matmul(a[None], b[None], **kw)[0]
