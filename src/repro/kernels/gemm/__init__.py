"""The shared PE: a batched, blocked Pallas GEMM with IS/WS dataflows.

This is the TPU analog of the paper's ``PT x PT`` array of ``PI x PO`` GEMM
cores (Sec. 4.2.2): the leading grid axis ranges over the PT^2 independent
GEMMs of the Winograd formulation (Eq. 2); Spatial convolution and every
transformer matmul use the same kernel with a singleton leading axis.
"""
from repro.kernels.gemm.ops import batched_matmul, matmul

__all__ = ["batched_matmul", "matmul"]
