"""Pallas TPU kernel: int8 GEMM with int32 accumulate + requantize epilogue.

The int8 PE: A (M, K) int8 @ B (K, N) int8 accumulates exactly in an int32
VMEM tile (``preferred_element_type=jnp.int32`` feeds the MXU's widened
accumulation path), and the flush step fuses the whole quantized epilogue —
add int32 bias, optional ReLU (valid pre-rescale because zero_point = 0),
then requantize ``clip(round(acc * mult), -127, 127)`` back to int8 — so
the pre-activation int32 map never round-trips through HBM. ``mult`` rides
in as a ``(1, N)`` fp32 operand (per-OUTPUT-CHANNEL requantize multipliers
broadcast down each column), so per-channel weight quantization costs the
epilogue nothing and a scalar multiplier is just the broadcast case.

Same grid discipline as ``gemm/kernel.py``: K innermost so one accumulator
tile carries the partial sums; blocks honor the int8 minimum tile
(SUBLANE_I8=32, LANE=128). Zero padding is exact under zero_point = 0:
padded K rows contribute 0 to every dot product.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.common import INTERPRET, LANE, SUBLANE_I8, round_up


def _qmm_kernel(a_ref, b_ref, bias_ref, mult_ref, o_ref, acc_ref, *,
                n_kb: int, relu: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(k == n_kb - 1)
    def _flush():
        acc = acc_ref[...] + bias_ref[...].astype(jnp.int32)  # (1, BN) bcast
        if relu:
            acc = jnp.maximum(acc, 0)
        y = jnp.round(acc.astype(jnp.float32) * mult_ref[...])
        o_ref[...] = jnp.clip(y, -127, 127).astype(jnp.int8)


def pick_int8_block_shapes(m: int, k: int, n: int) -> tuple[int, int, int]:
    """(bm, bk, bn) aligned to the int8 tile (32, 128), capped like fp32."""
    bm = min(round_up(m, SUBLANE_I8), 512)
    bk = min(round_up(k, LANE), 512)
    bn = min(round_up(n, LANE), 512)
    return bm, bk, bn


@functools.partial(jax.jit, static_argnames=("relu", "interpret"))
def _qmm(a, b, bias, mult_vec, *, relu: bool, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = pick_int8_block_shapes(m, k, n)

    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    bias2 = jnp.pad(bias.astype(jnp.int32), (0, np_ - n))[None]   # (1, Np)
    mult2 = jnp.pad(mult_vec, (0, np_ - n))[None]                 # (1, Np)

    n_kb = kp // bk
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, n_kb=n_kb, relu=relu),
        grid=(mp // bm, np_ // bn, n_kb),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, bias2, mult2)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def quantized_matmul(
    a: jax.Array,            # (M, K) int8
    b: jax.Array,            # (K, N) int8
    bias: jax.Array,         # (N,)   int32
    *,
    mult,                    # in_scale * wgt_scale / out_scale — scalar
                             # (per-tensor) or (N,) (per-channel weights)
    relu: bool = False,
    interpret: bool | None = None,
) -> jax.Array:              # (M, N) int8
    if interpret is None:
        interpret = INTERPRET
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8, (a.dtype, b.dtype)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and bias.shape == (n,), (a.shape, b.shape, bias.shape)
    mult_vec = jnp.broadcast_to(
        jnp.asarray(mult, jnp.float32), (n,))     # scalar -> uniform vector
    return _qmm(a, b, bias, mult_vec, relu=relu, interpret=bool(interpret))
