"""Pallas TPU kernel: batched blocked GEMM with selectable dataflow.

Dataflow (the paper's Sec. 4.2.4, adapted to TPU grid iteration order):

* ``"is"`` (Input Stationary)  — grid ``(G, Mb, Nb, Kb)``. For a fixed input
  block-row ``m`` the kernel sweeps all weight block-columns ``n``; the input
  block's VMEM residency is reused across the ``n`` sweep (Pallas does not
  re-fetch a block whose index map is unchanged between consecutive steps).
* ``"ws"`` (Weight Stationary) — grid ``(G, Nb, Mb, Kb)``. The weight block
  column ``n`` stays resident while input block-rows stream past it.

Both orders keep ``K`` innermost so a single fp32 VMEM accumulator tile
carries the partial sums (the paper's accumulating buffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.common import INTERPRET


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_kb: int):
    """One (g, m, n, k) grid step: acc += A[g,m,k] @ B[g,k,n]."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]  # (BM, BK)
    b = b_ref[0]  # (BK, BN)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == n_kb - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _mm_epilogue_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *,
                        n_kb: int, relu: bool):
    """GEMM with fused bias + optional ReLU at the accumulator flush.

    The paper adds bias in its accumulating buffer before SAVE; fusing the
    activation too saves one HBM round-trip of the pre-activation map.
    """
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_kb - 1)
    def _flush():
        out = acc_ref[...] + bias_ref[0].astype(jnp.float32)  # (1, BN) bcast
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def batched_matmul_kernel(
    a: jax.Array,           # (G, M, K)
    b: jax.Array,           # (G, K, N)
    bias: jax.Array | None = None,   # (G, N) fused epilogue, optional
    *,
    bm: int,
    bn: int,
    bk: int,
    dataflow: str = "is",   # "is" | "ws"
    relu: bool = False,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:             # (G, M, N)
    """Raw pallas_call wrapper. Shapes must already be padded to block multiples."""
    if interpret is None:
        interpret = INTERPRET
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    n_kb = k // bk

    if dataflow == "is":
        grid = (g, m // bm, n // bn, n_kb)
        a_map = lambda gi, mi, ni, ki: (gi, mi, ki)
        b_map = lambda gi, mi, ni, ki: (gi, ki, ni)
        o_map = lambda gi, mi, ni, ki: (gi, mi, ni)
        bias_map = lambda gi, mi, ni, ki: (gi, ni)
    elif dataflow == "ws":
        grid = (g, n // bn, m // bm, n_kb)
        a_map = lambda gi, ni, mi, ki: (gi, mi, ki)
        b_map = lambda gi, ni, mi, ki: (gi, ki, ni)
        o_map = lambda gi, ni, mi, ki: (gi, mi, ni)
        bias_map = lambda gi, ni, mi, ki: (gi, ni)
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    compiler_params = tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
    )
    in_specs = [
        pl.BlockSpec((1, bm, bk), a_map),
        pl.BlockSpec((1, bk, bn), b_map),
    ]
    operands = [a, b]
    if bias is None:
        kernel = functools.partial(_mm_kernel, n_kb=n_kb)
        assert not relu, "relu epilogue requires a bias operand (may be zeros)"
    else:
        kernel = functools.partial(_mm_epilogue_kernel, n_kb=n_kb, relu=relu)
        in_specs.append(pl.BlockSpec((1, bn), bias_map))
        operands.append(bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((g, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*operands)
