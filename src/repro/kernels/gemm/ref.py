"""Pure-jnp oracle for the batched GEMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_matmul_ref(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """(G, M, K) @ (G, K, N) -> (G, M, N), fp32 accumulation."""
    out = jnp.einsum(
        "gmk,gkn->gmn",
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(out_dtype)


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    return batched_matmul_ref(a[None], b[None], out_dtype)[0]
