"""Jitted public wrapper: padding, GQA head expansion, block selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import round_up
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, Hkv, Skv, D)
    v: jax.Array,   # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0
    if hkv != h:  # GQA: expand KV heads
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    bq = min(bq, round_up(sq, 8))
    bk = min(bk, round_up(skv, 8))
    sqp, skvp = round_up(sq, bq), round_up(skv, bk)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    if sqp != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sqp - sq), (0, 0)))
    if skvp != skv:
        # padded KV columns are masked in-kernel past kv_len
        kf = jnp.pad(kf, ((0, 0), (0, skvp - skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skvp - skv), (0, 0)))

    o = flash_attention_kernel(
        qf, kf, vf, bq=bq, bk=bk, causal=causal,
        scale=d ** -0.5, kv_len=skv, interpret=interpret)
    return o[:, :sq].reshape(b, h, sq, d)
