"""Pallas TPU flash-attention kernel.

Grid ``(BH, Sq/BQ, Skv/BK)`` with the KV axis innermost ("arbitrary");
running max / sum / weighted-accumulator live in VMEM scratch across the KV
sweep — the same accumulating-buffer pattern as the GEMM PE.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.kernels.common import INTERPRET

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               n_kb: int, scale: float, causal: bool, bq: int, bk: int,
               kv_len: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)   # (BQ, D)
    k = k_ref[0].astype(jnp.float32)   # (BK, D)
    v = v_ref[0].astype(jnp.float32)   # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        qi = pl.program_id(1)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        s = jnp.where(rows >= cols, s, NEG_INF)
    if kv_len % bk != 0:  # mask padded KV columns past the true length
        s = jnp.where(cols < kv_len, s, NEG_INF)

    m_prev = m_ref[...]                       # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)           # (BQ, 1)
    p = jnp.exp(s - m_new)                    # (BQ, BK)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kb - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,   # (BH, Sq, D) padded: Sq % bq == 0
    k: jax.Array,   # (BH, Skv, D) padded: Skv % bk == 0
    v: jax.Array,   # (BH, Skv, D)
    *,
    bq: int,
    bk: int,
    causal: bool = True,
    scale: float | None = None,
    kv_len: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = INTERPRET
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bk == 0
    scale = scale if scale is not None else d ** -0.5
    kv_len = skv if kv_len is None else kv_len
    n_kb = skv // bk
    grid = (bh, sq // bq, n_kb)
    kernel = functools.partial(
        _fa_kernel, n_kb=n_kb, scale=scale, causal=causal, bq=bq, bk=bk,
        kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
