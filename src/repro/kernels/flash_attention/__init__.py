"""Blocked online-softmax attention (TPU Pallas), for 32k-prefill cells.

Not part of the paper (HybridDNN is a CNN framework) but required by the
assigned LM architectures: attention is their dominant compute hot-spot and
gets the same treatment the paper gives CONV — a VMEM-tiled kernel on the
shared-MXU engine.
"""
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["flash_attention"]
