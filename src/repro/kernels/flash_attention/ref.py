"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True, scale=None):
    """(BH, Sq, D) x (BH, Skv, D) -> (BH, Sq, D). fp32 softmax."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
