"""Shared helpers for Pallas TPU kernels.

All kernels in this package are written against the TPU backend
(``pl.pallas_call`` with explicit ``BlockSpec`` VMEM tiling) and validated on
CPU with ``interpret=True``.  ``INTERPRET`` flips interpret mode globally so the
whole test-suite runs on the CPU container while the lowering path stays
TPU-shaped.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

# Interpret unless we are actually on TPU hardware.
INTERPRET = jax.default_backend() != "tpu"

# TPU hardware constants (v5e) used for block-shape heuristics.
LANE = 128          # last-dim tiling (VREG lane count, MXU edge)
SUBLANE = 8         # second-to-last dim tiling for fp32
SUBLANE_I8 = 32     # second-to-last dim tiling for int8 (min tile 32x128)
VMEM_BYTES = 128 * 1024 * 1024  # per-core VMEM budget (v5e ~128MB)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.lru_cache(None)
def is_cpu() -> bool:
    return jax.default_backend() == "cpu"
