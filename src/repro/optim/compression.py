"""Error-feedback gradient compression for the DP all-reduce.

int8 quantization with per-tensor scale and an error-feedback accumulator:
the quantization residual is carried into the next step, so the compressed
optimizer provably converges (the compression error telescopes). Used with
``shard_map`` on the data axes: compress shard-locally, all-reduce the int8
payload (8x less ICI traffic than fp32 / 2x less than bf16), decompress, add
the residual back into the feedback buffer.

Off by default; ``train.train_loop(make_train_step(..., grad_compression=
True))`` enables it. The exactness invariant (decompressed + error ==
original, telescoped over steps) is property-tested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grad(g: jax.Array, err: jax.Array):
    """Error-feedback compress: returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    decoded = dequantize_int8(q, scale)
    new_err = corrected - decoded
    return q, scale, new_err


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, err_state, axis_names):
    """shard_map body: compress + all-reduce int8 + mean-decompress.

    The quantization scale must be GLOBALLY agreed before the integer
    all-reduce (sum_i q_i * s_common == decodable; per-shard scales are not)
    — one tiny pmax of the amax establishes it. Error feedback is taken
    against the common-scale decoding, preserving the telescoping invariant
    per shard. Must run inside shard_map over ``axis_names`` (the DP axes).
    Returns (mean_grads, new_err_state).
    """
    from repro.compat import axis_size
    n = 1
    for ax in axis_names:
        n *= axis_size(ax)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_names) + 1e-12
        scale = amax / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        # int8 payloads sum without overflow in int32
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        mean = summed.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_e

    pairs = jax.tree.map(one, grads, err_state)
    mean_grads = jax.tree.map(lambda t: t[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean_grads, new_err
