"""AdamW with global-norm clipping, cosine schedule, ZeRO-1 state sharding.

Pure-functional: ``init`` builds the (m, v, step) state, ``update`` returns
(new_params, new_state). Optimizer-state shardings come from
``parallel.sharding.zero1_specs`` — m/v are additionally sharded over the
data axes so state memory scales 1/(pod*data).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
