"""Optimizer-side numerics: AdamW and int8 gradient compression.

``compression`` defines the repo's canonical per-tensor symmetric int8
scheme (``scale = amax / 127``, zero_point = 0, clip to [-127, 127]) —
originally for error-feedback gradient all-reduce, and reused verbatim by
``repro.quant``'s post-training calibration observers so training-time and
inference-time "int8" mean the same arithmetic.
"""
from repro.optim.compression import (compress_grad, compressed_psum,
                                     dequantize_int8, init_error_state,
                                     quantize_int8)

__all__ = [
    "compress_grad", "compressed_psum", "dequantize_int8",
    "init_error_state", "quantize_int8",
]
