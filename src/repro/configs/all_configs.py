"""The 10 assigned architectures (+ VGG16, the paper's own model).

Exact dimensions from the assignment; source tags in each docstring.
Import this module to populate the registry (``base.get_config`` does so
lazily).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, register


@register("llama4-scout-17b-16e")
def llama4_scout():
    """[moe] MoE every layer, 16 routed experts top-1 + shared expert.
    [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
    return ModelConfig(
        name="llama4-scout-17b-16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, head_dim=128,
        n_experts=16, experts_per_tok=1, moe_every=1, shared_expert=True)


@register("llama4-maverick-400b-a17b")
def llama4_maverick():
    """[moe] 128 routed experts top-1 + shared, MoE on alternating layers.
    [hf:meta-llama/Llama-4-Maverick-17B-128E; unverified]"""
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, head_dim=128,
        n_experts=128, experts_per_tok=1, moe_every=2, shared_expert=True)


@register("minitron-8b")
def minitron():
    """[dense] pruned nemotron [arXiv:2407.14679; hf]"""
    return ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
        vocab_size=256000, head_dim=128)


@register("internlm2-20b")
def internlm2():
    """[dense] GQA [arXiv:2403.17297; hf]"""
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab_size=92544, head_dim=128)


@register("qwen3-32b")
def qwen3():
    """[dense] qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]"""
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
        vocab_size=151936, head_dim=128, qk_norm=True)


@register("command-r-35b")
def command_r():
    """[dense] GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
        vocab_size=256000, head_dim=128)


@register("llama-3.2-vision-11b")
def llama32_vision():
    """[vlm] cross-attn image layers every 5th layer; patch embeddings are a
    stub frontend input. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=128256, head_dim=128,
        cross_attn_every=5, n_image_tokens=1600)


@register("zamba2-7b")
def zamba2():
    """[hybrid] Mamba2 backbone + shared attention block.
    [arXiv:2411.15242; unverified]"""
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
        vocab_size=32000, head_dim=112,
        ssm_state=64, ssm_head_dim=64, shared_attn_every=6)


@register("whisper-base")
def whisper_base():
    """[audio] enc-dec; conv frontend STUB (precomputed frame embeddings).
    [arXiv:2212.04356; unverified]"""
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab_size=51865, head_dim=64,
        n_audio_frames=1500, rope_theta=10000.0)


@register("mamba2-130m")
def mamba2_130m():
    """[ssm] SSD (state-space duality), attention-free.
    [arXiv:2405.21060; unverified]"""
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=50280, ssm_state=128, ssm_head_dim=64)


@register("vgg16")
def vgg16():
    """The paper's case-study CNN (Sec. 6.1) — runs on the hybrid engine."""
    return ModelConfig(name="vgg16", family="cnn", vocab_size=1000)
