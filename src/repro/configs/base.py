"""Model configuration schema + the architecture registry.

One ``ModelConfig`` describes any architecture in the assigned pool (dense /
MoE / VLM / hybrid-SSM / audio enc-dec / pure SSM) plus the paper's own VGG16.
Reduced configs (``cfg.reduced()``) drive the CPU smoke tests; full configs
are only ever lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | hybrid | audio | ssm | cnn
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 128
    qk_norm: bool = False
    attn_bias: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 1
    moe_every: int = 1           # MoE FFN every N layers (2 = alternating)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # --- hybrid (zamba2): one shared attention block every N mamba blocks ---
    shared_attn_every: int = 0
    # --- VLM ---
    cross_attn_every: int = 0    # cross-attention layer every N layers
    n_image_tokens: int = 0      # stub frontend: precomputed patch embeddings
    # --- audio enc-dec (whisper) ---
    encoder_layers: int = 0
    n_audio_frames: int = 0      # stub frontend: precomputed frame embeddings
    # --- misc ---
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "none"   # "none" (save nothing) | "dots"
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_ssm // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters (approx; exact for the transformer families)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * 2  # embed + lm_head (untied)
        if self.family in ("dense", "moe", "vlm"):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                + self.n_heads * self.head_dim * d
            dense_ffn = 3 * d * f
            n_moe = (self.n_layers // self.moe_every
                     if self.n_experts else 0)
            n_dense = self.n_layers - n_moe
            moe_ffn = 3 * d * f * self.n_experts + d * self.n_experts \
                + (3 * d * f if self.shared_expert else 0)
            per_cross = 0
            n_cross = 0
            if self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                per_cross = attn  # cross-attn block of the same shape
            return (emb + self.n_layers * (attn + 2 * d)
                    + n_dense * dense_ffn + n_moe * moe_ffn
                    + n_cross * per_cross)
        if self.family in ("ssm", "hybrid"):
            di = self.d_ssm
            per = d * (2 * di + 2 * self.ssm_state + self.n_ssm_heads) \
                + di * self.ssm_conv + di * d + 2 * d
            total = emb + self.n_layers * per
            if self.shared_attn_every:
                attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                    + self.n_heads * self.head_dim * d + 3 * d * self.d_ff
                total += attn  # one shared block
            return total
        if self.family == "audio":
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                + self.n_heads * self.head_dim * d
            ffn = 2 * d * f  # whisper uses GELU MLP (w_in, w_out)
            enc = self.encoder_layers * (attn + ffn + 2 * d)
            dec = self.n_layers * (2 * attn + ffn + 3 * d)
            return emb + enc + dec
        return 0

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE top-1: one routed expert)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_moe = self.n_layers // self.moe_every
        inactive = 3 * d * f * (self.n_experts - self.experts_per_tok)
        return self.param_count() - n_moe * inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        def cap(v, c):
            return min(v, c) if v else v
        return dataclasses.replace(
            self,
            n_layers=cap(self.n_layers, 4) or 0,
            d_model=cap(self.d_model, 64),
            n_heads=cap(self.n_heads, 4),
            n_kv_heads=cap(self.n_kv_heads, 2),
            d_ff=cap(self.d_ff, 128),
            vocab_size=cap(self.vocab_size, 512),
            head_dim=16 if self.head_dim else 0,
            n_experts=cap(self.n_experts, 4),
            ssm_state=cap(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            shared_attn_every=2 if self.shared_attn_every else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_image_tokens=cap(self.n_image_tokens, 16),
            encoder_layers=cap(self.encoder_layers, 2),
            n_audio_frames=cap(self.n_audio_frames, 32),
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import config modules lazily so the registry is populated
        from repro.configs import all_configs  # noqa: F401
        if name not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from repro.configs import all_configs  # noqa: F401
    return sorted(_REGISTRY)
