"""The assigned input shapes and (arch x shape) cell applicability.

  train_4k     seq 4,096  global_batch 256   -> train_step
  prefill_32k  seq 32,768 global_batch 32    -> serve prefill
  decode_32k   KV len 32,768 global_batch 128 -> serve decode (1 new token)
  long_500k    KV len 524,288 global_batch 1  -> decode; sub-quadratic only

``long_500k`` is SKIPped for pure full-attention archs (a 524k dense KV cache
is the quadratic regime the assignment excludes) and runs for the SSM/hybrid
archs, whose decode state is O(1) in sequence length (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicability(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if cfg.family == "cnn":
        return (shape.kind == "train", "CNN: image cells only")
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return (False, "full-attention arch: 524k dense KV cache is the "
                       "quadratic regime the assignment excludes")
    return (True, "")


def cells(archs: list[ModelConfig]) -> list[tuple[str, str, bool, str]]:
    """All (arch, shape, runs, reason) rows — 40 for the 10 LM archs."""
    rows = []
    for cfg in archs:
        for sname in SHAPE_NAMES:
            ok, why = applicability(cfg, SHAPES[sname])
            rows.append((cfg.name, sname, ok, why))
    return rows
