"""HybridDNN on TPU: hybrid Spatial/Winograd conv engine + multi-pod JAX
training/serving framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"
