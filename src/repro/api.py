"""``repro.api`` — one façade for the paper's full design flow (Fig. 1).

The paper's headline contribution is a *framework*: model + hardware target
in, deployed accelerator out. This module is that framework's user surface:

    from repro import api
    from repro.core import perf_model as pm
    from repro.models import vgg

    specs = vgg.network_specs(img=64, scale=8, n_classes=10)
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=8)
    logits = acc(x)                 # cached, validated, jitted executor
    print(acc.summary())            # per-layer mode/dataflow/latency table

``Accelerator.build`` runs the DSE (Sec. 5) through the unified ``Target``
protocol — any object with ``run_dse(specs, batch)`` works, so ``pm.V5E``
and the ``pm.FPGATarget`` instances dispatch identically — compiles ONE
``Program`` (Sec. 4.1), validates the hazard schedule once, and returns a
callable accelerator whose requests hit the cached jitted executor.

``Accelerator.save_program`` / ``Accelerator.from_program`` persist the
compiled instruction stream (plus specs/plans and the DSE verdict) so a
deployment can skip the DSE; the loader recompiles and verifies the stream
bit-exactly.

``ServingSession`` (via ``Accelerator.serve()``) is the paper's NI-instances
analog on the host mesh: a continuous-batching request queue that coalesces
single-image requests into device batches (admitting late arrivals while the
device pipeline is busy, deadline-capped), pads stragglers up to a fixed set
of bucket sizes (so the jit cache holds one executor per bucket), and
optionally shards full buckets over a device mesh via the shard_map'd
executor variant — with BOTH backends, since each shard is an ordinary
single-device trace. ``Fleet`` stacks several sessions over one process,
one program cache, and one FIFO-fair device-slot pool for multi-model
tenancy.

``backend="xla" | "pallas"`` (on ``build``, ``from_program``, and inherited
by sessions) selects the PE implementation every CONV/FC block lowers
through — the XLA ops (the default) or the Pallas PE kernels
(interpret-mode fallback off-TPU). See ``docs/ARCHITECTURE.md`` for
the plug-in table and ``docs/API.md`` for the full reference.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import logging
import os
import threading
import time
import warnings
from collections import deque
from contextlib import contextmanager
from concurrent.futures import Future, InvalidStateError
from typing import Any, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as pm
from repro.core.compiler import NO_PLAN, LayerPlan, Program, compile_network
from repro.core.dse import DSEResult, FPGACandidate, TPUCandidate
from repro.core.hybrid_conv import (
    ConvSpec,
    DepthwiseSpec,
    EltwiseSpec,
    FCSpec,
    PoolSpec,
)
from repro.core.runtime import HybridRuntime
from repro.quant import QuantSidecar, quantize_params
from repro.quant import calibrate as quant_calibrate
from repro.serving import (
    DeadlineExceeded,
    DeadlineTable,
    NumericsError,
    Overloaded,
    PipelineCrashed,
    ThreadSupervisor,
)

PROGRAM_FORMAT = "hybriddnn-program/v1"

log = logging.getLogger("repro.serving")


class ProgramLoadError(ValueError):
    """A saved program/bundle that cannot be loaded: truncated or non-JSON
    file, unknown format version, instruction-stream or quant-sidecar
    digest mismatch. Subclasses ``ValueError`` so pre-existing callers that
    catch the broad class keep working; new callers should catch this."""


@contextmanager
def _expected_donation_noise():
    """ServingSession opts into best-effort input donation: when a bucket's
    input buffer has no same-shape reuse inside the executor (e.g. the
    entry layout transform changes its shape immediately), XLA warns at
    compile time and keeps a copy — expected by design. Suppress exactly
    that message around the session's own compile sites only, so a user's
    own ``jax.jit(..., donate_argnums=...)`` diagnostics stay visible.

    ``warnings.catch_warnings`` mutates process-global filter state and is
    not thread-safe, so this is a no-op off the main thread: a cold bucket
    compiled lazily in the dispatch worker emits the (harmless, one-time)
    note rather than risk corrupting a user thread's filter stack."""
    if threading.current_thread() is not threading.main_thread():
        yield
        return
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable",
            category=UserWarning)
        yield


@runtime_checkable
class Target(Protocol):
    """Anything that can run the paper's DSE for a layer chain.

    ``pm.TPUTarget`` and ``pm.FPGATarget`` both implement this, so callers
    never branch on ``run_tpu_dse`` vs ``run_fpga_dse`` — they hand any
    target instance to ``Accelerator.build``.
    """

    def run_dse(self, specs, batch: int = 1) -> DSEResult: ...


def random_params(specs: Sequence[Any], seed: int = 0) -> list:
    """Random ``[(w, b), ...]`` for every parameterized layer (CONV, FC and
    DEPTHWISE; POOL and ELTWISE carry no params), fan-in scaled — the
    stand-in for trained weights throughout the repo."""
    rng = np.random.default_rng(seed)
    params = []
    for s in specs:
        if isinstance(s, ConvSpec):
            w = jnp.asarray(rng.standard_normal((s.r, s.s, s.c, s.k)),
                            jnp.float32) * (s.r * s.s * s.c) ** -0.5
            params.append((w, jnp.zeros((s.k,), jnp.float32)))
        elif isinstance(s, DepthwiseSpec):
            w = jnp.asarray(rng.standard_normal((s.r, s.s, 1, s.c)),
                            jnp.float32) * (s.r * s.s) ** -0.5
            params.append((w, jnp.zeros((s.c,), jnp.float32)))
        elif isinstance(s, FCSpec):
            w = jnp.asarray(rng.standard_normal((s.d_in, s.d_out)),
                            jnp.float32) * s.d_in ** -0.5
            params.append((w, jnp.zeros((s.d_out,), jnp.float32)))
    return params


def _conv_segments_of(specs) -> list[int]:
    """Consecutive-CONV run lengths between maxpools (VGG16: [2,2,3,3,3]).

    The segmented request glues segments with a host-side maxpool, so the
    chain must be ``(CONV+ POOL)+ FC*`` — anything else (trailing CONVs
    without a pool, a pool before any CONV, CONVs after the FC tail) gets a
    descriptive error instead of an opaque crash downstream."""
    segments, run, seen_fc = [], 0, False
    for s in specs:
        if isinstance(s, (EltwiseSpec, DepthwiseSpec)):
            raise ValueError(
                f"segmented path: {type(s).__name__} {s.name!r} — residual "
                f"adds and depthwise convs need the single-Program path "
                f"(segmented=False); the legacy glue only handles "
                f"(CONV+ POOL)+ FC*")
        if isinstance(s, ConvSpec):
            if s.inp_from is not None:
                raise ValueError(
                    f"segmented path: CONV {s.name!r} reroutes its input "
                    f"(inp_from={s.inp_from}) — skip wiring needs the "
                    f"single-Program path (segmented=False)")
            if seen_fc:
                raise ValueError("segmented path: CONV after the FC tail")
            run += 1
        elif isinstance(s, PoolSpec):
            if seen_fc:
                raise ValueError("segmented path: POOL after the FC tail")
            if run == 0:
                raise ValueError(
                    "segmented path: maxpool without a preceding CONV "
                    "segment — the chain must be (CONV+ POOL)+ FC*")
            segments.append(run)
            run = 0
        else:
            seen_fc = True
    if run:
        raise ValueError(
            "segmented path: trailing CONV segment without a maxpool — "
            "use the single-Program path (segmented=False) for this chain")
    if not segments:
        raise ValueError("segmented path: no CONV+POOL segment in the chain")
    return segments


def build_segmented_request(specs, plans, params, *, strict: bool = False,
                            cache=None, backend: str = "xla",
                            interpret: bool | None = None,
                            opt_level: int = 1):
    """The legacy multi-Program path: one compiled Program per CONV segment,
    host-side 2x2 maxpool glue between segments, and the FC tail outside
    the runtime. Kept as ``Accelerator.build(..., segmented=True)``;
    asserted numerically identical to the single-Program path in
    ``tests/test_integration.py``. ``strict=True`` builds the per-segment
    runtimes on the per-instruction interpreter instead of the cached
    jitted executor; ``cache`` overrides the process-global program cache
    for every segment runtime; ``backend``/``interpret`` select the PE
    implementation for the segment runtimes AND the host-side FC tail;
    ``opt_level`` is the lowering-optimizer level of each segment
    executor."""
    from repro.core.executor import resolve_backend, resolve_opt_level
    from repro.core.hybrid_conv import dense, max_pool2d

    resolve_backend(backend, interpret)   # reject bad combos before building
    resolve_opt_level(opt_level)

    # params align with the non-pool specs, in network order
    nonpool = [s for s in specs if not isinstance(s, PoolSpec)]
    assert len(nonpool) == len(params)
    conv_specs = [s for s in specs if isinstance(s, ConvSpec)]
    conv_plans = [p for s, p in zip(specs, plans) if isinstance(s, ConvSpec)]
    conv_params = [p for s, p in zip(nonpool, params)
                   if isinstance(s, ConvSpec)]
    pool_specs = [s for s in specs if isinstance(s, PoolSpec)]
    fc_specs = [s for s in nonpool if isinstance(s, FCSpec)]
    fc_params = [p for s, p in zip(nonpool, params) if isinstance(s, FCSpec)]

    runtimes, idx, n_instr = [], 0, 0
    for n in _conv_segments_of(specs):
        program = compile_network(conv_specs[idx:idx + n],
                                  conv_plans[idx:idx + n])
        rt = HybridRuntime(program, strict=strict, cache=cache,
                           backend=backend, interpret=interpret,
                           opt_level=opt_level)
        rt.load_params(conv_params[idx:idx + n])
        runtimes.append(rt)
        n_instr += len(program.instructions)
        idx += n

    assert len(pool_specs) == len(runtimes), \
        "segmented path expects one maxpool after each CONV segment"

    def request(x):
        for rt, ps in zip(runtimes, pool_specs):
            x = max_pool2d(rt.run(x), ps.window, ps.stride)
        x = x.reshape(x.shape[0], -1)
        for s, (w, b) in zip(fc_specs, fc_params):
            x = dense(x, w, b, relu=s.relu,
                      use_pallas=backend == "pallas", interpret=interpret)
        return x

    return request, runtimes, n_instr


# ---------------------------------------------------------------------------
# Program (de)serialization helpers
# ---------------------------------------------------------------------------

_SPEC_KINDS = {"conv": ConvSpec, "pool": PoolSpec, "fc": FCSpec,
               "eltwise": EltwiseSpec, "dw": DepthwiseSpec}


def _spec_to_dict(spec) -> dict:
    kind = next(k for k, cls in _SPEC_KINDS.items()
                if type(spec) is cls)
    return {"kind": kind, **dataclasses.asdict(spec)}


def _spec_from_dict(d: dict):
    d = dict(d)
    return _SPEC_KINDS[d.pop("kind")](**d)


def _hw_to_dict(hw) -> dict:
    if isinstance(hw, TPUCandidate):
        return {"type": "tpu", **dataclasses.asdict(hw)}
    if isinstance(hw, FPGACandidate):
        return {"type": "fpga", **dataclasses.asdict(hw)}
    return {"type": "other", "repr": repr(hw)}


def _hw_from_dict(d: dict):
    d = dict(d)
    typ = d.pop("type")
    if typ == "tpu":
        return TPUCandidate(**d)
    if typ == "fpga":
        return FPGACandidate(**d)
    return d.get("repr")


def _fmt_t(seconds: float) -> str:
    if seconds < 1e-3:
        return f"{seconds * 1e6:8.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:8.2f} ms"
    return f"{seconds:8.3f} s "


# ---------------------------------------------------------------------------
# The façade
# ---------------------------------------------------------------------------

class Accelerator:
    """A built accelerator: DSE verdict + ONE compiled Program + the cached,
    validated, jitted executor behind ``__call__``.

    Construct with :meth:`build` (the full flow) or :meth:`from_program`
    (reuse a saved instruction stream, skipping the DSE). ``backend``
    selects the PE implementation the executor lowers each CONV/FC block
    through — ``"xla"`` (default) or ``"pallas"`` (the Pallas TPU kernels,
    interpret-mode on CPU unless overridden) — see ``docs/ARCHITECTURE.md``.

    Instances are callable: ``acc(x)`` runs one inference request through
    the cached executor. :meth:`summary` prints the per-layer DSE verdict,
    :meth:`save_program` / :meth:`from_program` persist/restore the
    compiled stream, and :meth:`serve` opens a batching
    :class:`ServingSession`.
    """

    def __init__(self, *, specs, plans, params, request, target=None,
                 batch: int = 1, program: Program | None = None,
                 runtime: HybridRuntime | None = None,
                 dse: DSEResult | None = None, segmented: bool = False,
                 segment_runtimes: list | None = None,
                 backend: str = "xla", interpret: bool | None = None,
                 opt_level: int = 1, quant=None):
        self.specs = list(specs)
        self.plans = list(plans)
        self.params = params
        self.target = target
        self.batch = batch
        self.program = program
        self.runtime = runtime
        self.dse = dse
        self.segmented = segmented
        self.segment_runtimes = segment_runtimes
        self.backend = backend
        self.interpret = interpret
        self.opt_level = opt_level
        self.quant = quant          # QuantSidecar for int8 accelerators
        self._request = request

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, specs, target: Target = pm.V5E, *, batch: int = 8,
              params: list | None = None, seed: int = 0,
              plans: Sequence[LayerPlan | None] | None = None,
              segmented: bool = False, strict: bool = False,
              cache=None, backend: str = "xla",
              interpret: bool | None = None,
              opt_level: int = 1, dtype: str = "float32",
              calib=None, observer: str = "percentile") -> "Accelerator":
        """DSE -> compile -> validate, in one call.

        ``target`` is any :class:`Target` (``pm.V5E``, ``pm.VU9P``,
        ``pm.PYNQ_Z1``, or a custom instance). ``plans`` overrides the DSE
        (skips it entirely — useful for benchmarks pinning a schedule).
        ``params`` defaults to :func:`random_params`. ``segmented=True``
        builds the legacy multi-Program path instead (one Program per CONV
        segment, host-side glue); ``strict=True`` runs the per-instruction
        interpreter instead of the cached executor.

        ``backend="pallas"`` routes every CONV/FC block through the Pallas
        PE kernels instead of the XLA ops; ``interpret`` overrides the
        Pallas interpret-mode auto-selection (``None`` = interpret mode
        everywhere but real TPU). ``opt_level`` selects the lowering
        optimizer — ``1`` (default) collapses each layer's per-block loop
        into one whole-layer PE dispatch where provably equivalent, ``0``
        keeps the literal per-block lowering (the reference). Backend and
        opt_level both join the program-cache key, so the same Program
        serves every variant side by side.

        ``dtype="int8"`` builds a fully quantized accelerator: the DSE
        plans against the target's int8 variant (Winograd gated off — no
        int8 U-space transform), ``calib`` (an (n, H, W, C) array or list
        of batches; defaults to seeded random data) drives post-training
        calibration into a ``repro.quant.QuantSidecar``, params are
        quantized per-tensor symmetric (int8 weights, int32 bias), and
        every path — cached executor, strict interpreter, Pallas PEs —
        runs int8 GEMMs with a fused requantize+ReLU epilogue. ``observer``
        picks the activation-range estimator (``"percentile"`` default,
        or ``"minmax"``). The accelerator stays float-in/float-out:
        ``__call__`` quantizes inputs by the calibrated input scale and
        dequantizes the int8 logits (a positive per-tensor rescale, so
        top-1 is taken on the same ordering the device computed).
        """
        specs = list(specs)
        if dtype not in ("float32", "int8"):
            raise ValueError(f"unsupported dtype {dtype!r}: expected "
                             f"'float32' or 'int8'")
        if dtype == "int8" and segmented:
            raise ValueError("segmented accelerators are fp32-only — the "
                             "int8 path needs the single-Program runtime "
                             "(the sidecar is keyed to one schedule)")
        dse = None
        if plans is None:
            if not isinstance(target, Target):
                raise TypeError(
                    f"target {target!r} does not implement the Target "
                    f"protocol (needs a run_dse(specs, batch) method) — pass "
                    f"e.g. pm.V5E, pm.VU9P, pm.PYNQ_Z1, or supply plans=")
            # dtype is only passed when quantizing, so custom fp32 targets
            # that predate the dtype parameter keep working unchanged
            dse = (target.run_dse(specs, batch=batch, dtype=dtype)
                   if dtype != "float32"
                   else target.run_dse(specs, batch=batch))
            plans = list(dse.plans)
        else:
            plans = list(plans)
        if params is None:
            params = random_params(specs, seed)

        quant = None
        if dtype == "int8":
            if calib is None:
                # stand-in calibration data, seeded like random_params: real
                # deployments pass a slice of the training set instead
                s0 = specs[0]
                shape = ((8, s0.d_in) if isinstance(s0, FCSpec)
                         else (8, s0.h, s0.w, s0.c))
                calib = np.random.default_rng(seed + 1).standard_normal(
                    shape).astype(np.float32)
            quant = quant_calibrate(specs, params, calib, observer=observer)
            params = quantize_params(specs, params, quant)

        if segmented:
            request, seg_rts, _ = build_segmented_request(
                specs, plans, params, strict=strict, cache=cache,
                backend=backend, interpret=interpret, opt_level=opt_level)
            return cls(specs=specs, plans=plans, params=params,
                       request=request, target=target, batch=batch, dse=dse,
                       segmented=True, segment_runtimes=seg_rts,
                       backend=backend, interpret=interpret,
                       opt_level=opt_level)

        program = compile_network(specs, plans)
        rt = HybridRuntime(program, strict=strict, cache=cache,
                           backend=backend, interpret=interpret,
                           opt_level=opt_level, quant=quant)
        rt.load_params(params)
        if not strict:
            rt.cache.validate(program)   # schedule check once, at build time
        return cls(specs=specs, plans=plans, params=params, request=rt.run,
                   target=target, batch=batch, program=program, runtime=rt,
                   dse=dse, backend=backend, interpret=interpret,
                   opt_level=opt_level, quant=quant)

    # -- inference ----------------------------------------------------------
    def __call__(self, x):
        """One inference request. ``x``: (n, H, W, C) for CONV-first models,
        (n, D) for FC-first. Steady-state calls are cache hits only.
        Quantized accelerators are float-in/float-out: float inputs are
        quantized by the calibrated input scale (already-int8 inputs pass
        through) and the int8 logits are dequantized back to fp32."""
        if self.quant is not None:
            y = self._request(jnp.asarray(x))   # runtime quantizes floats
            return self.quant.dequantize_output(y)
        return self._request(jnp.asarray(x, self.input_dtype))

    @property
    def input_dtype(self):
        if self.params:
            return self.params[0][0].dtype
        return jnp.float32

    @property
    def input_shape(self) -> tuple[int, ...]:
        """Shape of ONE request item (no batch dim)."""
        s0 = self.specs[0]
        if isinstance(s0, FCSpec):
            return (s0.d_in,)
        return (s0.h, s0.w, s0.c)

    @property
    def n_instructions(self) -> int:
        if self.program is not None:
            return len(self.program.instructions)
        return sum(len(rt.program.instructions)
                   for rt in self.segment_runtimes or [])

    def strict_request(self):
        """A per-instruction-interpreter request fn over the same Program(s)
        and params — the hazard-faithful baseline for comparisons. Always
        runs the XLA PE, regardless of this accelerator's ``backend``, so
        it can serve as the numerical oracle for the Pallas path too. For
        quantized accelerators the interpreter carries the same sidecar, so
        its int8 outputs are bitwise-comparable to the raw executor's."""
        if self.segmented:
            return build_segmented_request(
                self.specs, self.plans, self.params, strict=True)[0]
        rt = HybridRuntime(self.program, strict=True, quant=self.quant)
        rt.load_params(self.params)
        return rt.run

    # -- reporting ----------------------------------------------------------
    def _hw_desc(self) -> str:
        if self.dse is None:
            return "plans supplied (no DSE)"
        hw = self.dse.hw
        if isinstance(hw, TPUCandidate):
            return (f"blocks=({hw.bm},{hw.bk},{hw.bn}) m={hw.m} | DSE over "
                    f"{self.dse.candidates_searched} candidates")
        if isinstance(hw, FPGACandidate):
            return (f"PI={hw.pi} PO={hw.po} PT={hw.pt} NI={hw.ni} | DSE over "
                    f"{self.dse.candidates_searched} candidates")
        return str(hw)

    def summary(self) -> str:
        """Per-layer plan/latency table — the DSE verdict, human-readable."""
        # target is an instance with .name, or the bare name string a
        # from_program-restored accelerator carries
        tname = (self.target if isinstance(self.target, str)
                 else getattr(self.target, "name", None)) or "-"
        kind_of = {ConvSpec: "conv", PoolSpec: "pool", FCSpec: "fc",
                   EltwiseSpec: "eltwise", DepthwiseSpec: "dw"}
        head = (f"{len(self.specs)} layers as "
                + (f"{len(self.segment_runtimes)} segment Programs + host "
                   f"glue" if self.segmented else
                   f"ONE Program ({self.n_instructions} instructions)"))
        lines = [f"Accelerator[{tname}]: {head}",
                 f"  {self._hw_desc()}, batch={self.batch}",
                 f"  {'layer':<12}{'kind':<9}{'dtype':<9}{'mode':<6}"
                 f"{'df':<4}{'m':>2}{'g_h':>5}{'g_k':>5}"
                 f"  {'latency':>11}{'share':>8}"]
        lats = self.dse.layer_latencies if self.dse else None
        total = self.dse.total_latency if self.dse else None
        for i, (s, p) in enumerate(zip(self.specs, self.plans)):
            kind = kind_of[type(s)]
            p = p or NO_PLAN
            mode, df, m = (p.mode, p.dataflow, str(p.m)) \
                if kind == "conv" else ("-", "-", "-")
            gh, gk = ((str(p.g_h), str(p.g_k)) if kind == "conv"
                      else ("-", "-"))
            # precision per layer: "int8+rq" = int8 math with the fused
            # requantize epilogue, "int8" = scale-passthrough (pool)
            if self.quant is None:
                dt = "fp32"
            else:
                dt = ("int8+rq" if self.quant.layers[i].requantize
                      else "int8")
            lat = _fmt_t(lats[i]) if lats else "          -"
            share = (f"{100 * lats[i] / total:6.1f}%"
                     if lats and total else "      -")
            lines.append(f"  {s.name:<12}{kind:<9}{dt:<9}{mode:<6}{df:<4}"
                         f"{m:>2}{gh:>5}{gk:>5}  {lat}{share}")
        if total is not None:
            macs = sum(s.macs for s in self.specs)
            scale = self.batch if isinstance(self.dse.hw, TPUCandidate) else 1
            gops = 2.0 * macs * scale / total / 1e9
            lines.append(f"  est. total {_fmt_t(total).strip()} "
                         f"({gops:.1f} effective GOPS)")
        return "\n".join(lines)

    # -- persistence --------------------------------------------------------
    def save_program(self, path: str, *, aot: bool = False,
                     buckets: Sequence[int] | None = None) -> str:
        """Persist the compiled instruction stream + specs/plans + DSE
        verdict as JSON, so :meth:`from_program` can rebuild this
        accelerator without re-running the DSE. Params are NOT saved (they
        are the model's weights — supply them at load time).

        ``aot=True`` writes a **bundle directory** instead of a single
        file: ``program.json`` (the same document) plus ``aot/`` holding
        one serialized XLA executable per warmed entry — every serving
        ``bucket`` with input donation (the :class:`ServingSession` hot
        path; defaults to the session's power-of-two buckets up to
        ``self.batch``) and the direct-call entry at ``self.batch``. A
        bundle loaded by :meth:`from_program` serves its first request
        without tracing OR compiling; see ``repro.core.aot`` for the keying
        and fallback semantics."""
        if self.program is None:
            raise ValueError("segmented accelerators hold multiple Programs; "
                             "save_program supports the single-Program path")
        doc = {
            "format": PROGRAM_FORMAT,
            "target": (self.target if isinstance(self.target, str)
                       else getattr(self.target, "name", None)),
            "batch": self.batch,
            "specs": [_spec_to_dict(s) for s in self.specs],
            "plans": [dataclasses.asdict(cl.plan)
                      for cl in self.program.layers],
            "instructions": self.program.instruction_image().tolist(),
            "dse": None if self.dse is None else {
                "hw": _hw_to_dict(self.dse.hw),
                "layer_latencies": [float(v)
                                    for v in self.dse.layer_latencies],
                "total_latency": float(self.dse.total_latency),
                "candidates_searched": self.dse.candidates_searched,
            },
            # the quant sidecar rides ALONGSIDE the instruction stream (the
            # 128-bit words are untouched — int8 never changes the ISA);
            # its digest is bound to this schedule so a sidecar pasted from
            # a different calibration or program is rejected at load
            "quant": None if self.quant is None else {
                "sidecar": self.quant.to_dict(),
                "digest": self.quant.digest(self.program.schedule_key()),
            },
        }
        if not aot:
            with open(path, "w") as f:
                json.dump(doc, f)
            return path
        rt = self.runtime
        if rt is None or rt.strict:
            raise ValueError("aot=True needs the cached-executor runtime — "
                             "strict-interpreter accelerators have no "
                             "compiled executable to export")
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "program.json"), "w") as f:
            json.dump(doc, f)
        aot_dir = os.path.join(path, "aot")
        if buckets is None:
            buckets, b = [], 1
            while b < self.batch:
                buckets.append(b)
                b *= 2
            buckets.append(self.batch)
        in_shape = tuple(self.input_shape)
        dt = self.input_dtype
        for b in sorted({int(b) for b in buckets}):
            # the serving hot path: per-bucket executors donate their
            # staged input buffer
            rt.export_aot(aot_dir, (b, *in_shape), dt, donate_input=True)
        # the direct acc(x) path: batch-sized, no donation
        rt.export_aot(aot_dir, (self.batch, *in_shape), dt,
                      donate_input=False)
        return path

    @classmethod
    def from_program(cls, path: str, *, params: list | None = None,
                     strict: bool = False, cache=None, backend: str = "xla",
                     interpret: bool | None = None,
                     opt_level: int = 1) -> "Accelerator":
        """Rebuild an accelerator from :meth:`save_program` output — no DSE.

        The layer chain is recompiled from the saved specs/plans and the
        resulting stream is verified bit-exact against the saved instruction
        image; a mismatch (compiler/schedule drift) raises ``ValueError``
        rather than serving from a stream that was never validated.

        ``params`` is required: saved programs carry no weights, and
        silently substituting random ones would make a reloaded deployment
        serve garbage — pass ``api.random_params(specs, seed)`` explicitly
        if stand-in weights are what you want. ``backend``/``interpret``/
        ``opt_level`` select the PE implementation and lowering-optimizer
        level exactly as in :meth:`build` — the saved stream is agnostic to
        both, so one artifact deploys to every variant.

        ``path`` may also be an AOT bundle directory written by
        ``save_program(..., aot=True)``: the instruction image loads from
        its ``program.json`` and the runtime warm-starts executors from the
        serialized executables in ``aot/`` — skipping trace AND compile —
        whenever the full artifact key (including this host's device kind
        and jax version) matches; stale artifacts fall back to a fresh
        compile with the reason logged on ``repro.aot``.

        Malformed input — truncated/non-JSON file, unknown format version,
        instruction-stream mismatch, quant-sidecar digest bound to a
        different schedule — raises :class:`ProgramLoadError`.
        """
        if params is None:
            raise ValueError(
                "saved programs carry no weights — pass params=[...] "
                "(api.random_params(specs, seed) for stand-ins)")
        aot_dir = None
        doc_path = path
        if os.path.isdir(path):
            doc_path = os.path.join(path, "program.json")
            if not os.path.exists(doc_path):
                raise ProgramLoadError(
                    f"{path}: directory is not an AOT bundle — no "
                    f"program.json inside")
            d = os.path.join(path, "aot")
            aot_dir = d if os.path.isdir(d) else None
        try:
            with open(doc_path) as f:
                doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ProgramLoadError(
                f"{doc_path}: truncated or not JSON ({e}) — the save was "
                f"interrupted or the file corrupted in transit") from e
        if doc.get("format") != PROGRAM_FORMAT:
            raise ProgramLoadError(
                f"{doc_path}: not a {PROGRAM_FORMAT} file "
                f"(format={doc.get('format')!r})")
        specs = [_spec_from_dict(d) for d in doc["specs"]]
        plans = [LayerPlan(**d) for d in doc["plans"]]
        program = compile_network(specs, plans)
        image = np.asarray(doc["instructions"], np.uint32).reshape(-1, 4)
        if not np.array_equal(program.instruction_image(), image):
            raise ProgramLoadError(
                f"{doc_path}: saved instruction stream does not match its "
                f"recompilation (compiler or schedule drift) — re-run "
                f"Accelerator.build and save again")
        quant = None
        if doc.get("quant"):
            q = doc["quant"]
            quant = QuantSidecar.from_dict(q["sidecar"])
            if quant.digest(program.schedule_key()) != q.get("digest"):
                raise ProgramLoadError(
                    f"{doc_path}: quant sidecar digest does not match this "
                    f"program's schedule — the sidecar was edited or "
                    f"belongs to a different calibration/program; re-run "
                    f"Accelerator.build(dtype='int8') and save again")
            # accept either fp32 weights (quantized here, deterministically
            # — the sidecar fixes every scale) or pre-quantized int8 ones
            if np.asarray(params[0][0]).dtype != np.int8:
                params = quantize_params(specs, params, quant)
        dse = None
        if doc.get("dse"):
            d = doc["dse"]
            dse = DSEResult(hw=_hw_from_dict(d["hw"]), plans=plans,
                            layer_latencies=d["layer_latencies"],
                            total_latency=d["total_latency"],
                            candidates_searched=d["candidates_searched"])
        rt = HybridRuntime(program, strict=strict, cache=cache,
                           backend=backend, interpret=interpret,
                           opt_level=opt_level, quant=quant,
                           aot_dir=aot_dir)
        rt.load_params(params)
        if not strict:
            rt.cache.validate(program)
        return cls(specs=specs, plans=plans, params=params, request=rt.run,
                   target=doc.get("target"), batch=doc.get("batch", 1),
                   program=program, runtime=rt, dse=dse,
                   backend=backend, interpret=interpret,
                   opt_level=opt_level, quant=quant)

    # -- serving ------------------------------------------------------------
    def serve(self, **kwargs) -> "ServingSession":
        """Open a :class:`ServingSession` over this accelerator — a
        padding-bucketed request-batching queue (see the class docs).
        ``mesh="host"`` shards batches over all local devices."""
        if kwargs.get("mesh") == "host":
            from repro.launch.mesh import make_host_mesh
            kwargs["mesh"] = make_host_mesh()
        return ServingSession(self, **kwargs)


# ---------------------------------------------------------------------------
# Serving: the request-batching queue (NI-instances analog)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionStats:
    requests: int = 0        # requests completed
    batches: int = 0         # executor invocations
    padded_rows: int = 0     # zero rows added to reach a bucket size
    dispatched_rows: int = 0  # real (non-pad) rows sent to the device(s)
    # -- failure model (see docs/ARCHITECTURE.md "Failure model") ----------
    # the accounting invariant every session maintains and the chaos soak
    # asserts: submitted == requests + errors + shed. A request lands in
    # exactly one of the three; deadline_exceeded is the subset of errors
    # failed by the deadline enforcer, isolated the subset quarantined
    # individually (poisoned-batch bisection or a numerics guard hit).
    submitted: int = 0           # requests accepted by submit()/run_many()
    errors: int = 0              # requests resolved with an exception
    deadline_exceeded: int = 0   # ... of which: missed their deadline_ms
    shed: int = 0                # refused at admission (queue_limit)
    retries: int = 0             # bisection re-dispatches after a failure
    isolated: int = 0            # requests individually quarantined
    degraded: int = 0            # batches recovered on the XLA fallback
    watchdog_restarts: int = 0   # pipeline restarts after a dead thread
    # first-use cost per bucket, split by how the executor came to exist so
    # the AOT warm-start win is measurable: compile_ms counts buckets that
    # traced + XLA-compiled in this process (warmup or first use);
    # warm_load_ms counts buckets whose executable deserialized from an AOT
    # bundle (repro.core.aot) — disk read + load + first dispatch, no
    # compile. One bucket lands in exactly one of the two.
    compile_ms: float = 0.0
    warm_load_ms: float = 0.0
    # device id -> batches dispatched there. A sharded batch counts once on
    # EVERY device it spans; a single-device batch counts on its one device
    # — so the table reads as per-device occupancy of the fleet.
    device_batches: dict = dataclasses.field(default_factory=dict)
    # per-request latency samples (submit -> result ready), most recent
    # window only — enough for steady-state percentiles without unbounded
    # growth on a long-lived session. Appends (drain thread) and percentile
    # reads (any caller) share _lat_lock: sorting a deque the drain thread
    # is appending to would raise "deque mutated during iteration".
    latencies_ms: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))
    # per-request queue-wait samples (submit -> admitted into a dispatched
    # device batch) — the scheduler-health metric: continuous batching keeps
    # this bounded by the batching window even under backpressure
    waits_ms: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))
    _lat_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    def bump(self, name: str, k: int = 1):
        """Thread-safe counter increment — the failure counters are bumped
        from the worker, drain, supervisor AND caller threads, and a bare
        ``+=`` read-modify-write can drop updates across them, which would
        break the exact-accounting invariant the chaos soak asserts."""
        with self._lat_lock:
            setattr(self, name, getattr(self, name) + k)

    def record_latency(self, ms: float):
        with self._lat_lock:
            self.latencies_ms.append(ms)

    def record_latencies(self, ms_list):
        """Batch append — one lock acquisition per device batch, not per
        request (the drain thread calls this on the completion hot path)."""
        with self._lat_lock:
            self.latencies_ms.extend(ms_list)

    def record_waits(self, ms_list):
        with self._lat_lock:
            self.waits_ms.extend(ms_list)

    def _pct(self, xs_deque, q: float) -> float:
        with self._lat_lock:
            xs = sorted(xs_deque)
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def p50_ms(self) -> float:
        """Median request latency over the recent window."""
        return self._pct(self.latencies_ms, 0.50)

    def p95_ms(self) -> float:
        """95th-percentile request latency over the recent window."""
        return self._pct(self.latencies_ms, 0.95)

    def wait_p50_ms(self) -> float:
        """Median queue wait (submit -> dispatch) over the recent window."""
        return self._pct(self.waits_ms, 0.50)

    def wait_p95_ms(self) -> float:
        """95th-percentile queue wait over the recent window."""
        return self._pct(self.waits_ms, 0.95)

    def occupancy(self) -> float:
        """Real-row fraction of all dispatched device rows (1.0 = no
        padding waste). The continuous-batching scheduler's win over fixed
        buckets on bursty traffic shows up here first."""
        total = self.dispatched_rows + self.padded_rows
        return self.dispatched_rows / total if total else 1.0


class _SlotPool:
    """FIFO-fair counting semaphore over device-pipeline slots.

    Each :class:`ServingSession` bounds its outstanding device batches with
    one of these (the classic triple buffer: one syncing, one executing,
    one staged). A :class:`Fleet` shares ONE pool across every tenant
    session, so device time round-robins between models: dispatch workers
    queue FIFO for the next free slot, and a model that just dispatched
    re-queues behind its peers — the paper's NI-instances arbitration,
    host-side.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("slot pool capacity must be >= 1")
        self.capacity = int(capacity)
        self._free = self.capacity
        self._cv = threading.Condition()
        self._waiters: deque = deque()
        self._subscribers: list[threading.Condition] = []

    def subscribe(self, cv: threading.Condition):
        """Register a condition to notify on every release — session
        admitters sleep on their own ``_cv`` while the pipeline is full, so
        a freed slot must wake them there."""
        with self._cv:
            self._subscribers.append(cv)

    def available(self) -> bool:
        """Lock-free hint (admission heuristics only, never correctness)."""
        return self._free > 0

    def busy(self) -> bool:
        """Lock-free hint: any slot taken — the device (pool-wide, across a
        Fleet's tenants) still has dispatched work in flight."""
        return self._free < self.capacity

    def acquire(self, cancelled=None) -> bool:
        """Block for a slot; returns True once acquired. ``cancelled`` (a
        nullary predicate, polled while waiting) lets a dispatch worker
        abandon the wait when its pipeline generation is retired — without
        it, a worker queued on a pool whose holder crashed would block a
        watchdog restart forever. Returns False when cancelled."""
        token = object()
        with self._cv:
            self._waiters.append(token)
            while self._free <= 0 or self._waiters[0] is not token:
                if cancelled is not None and cancelled():
                    self._waiters.remove(token)
                    self._cv.notify_all()   # next in line may now be eligible
                    return False
                self._cv.wait(None if cancelled is None else 0.05)
            self._waiters.popleft()
            self._free -= 1
            if self._free > 0:
                self._cv.notify_all()   # next waiter in line may also go
            return True

    def release(self):
        with self._cv:
            # clamp: watchdog crash-recovery frees slots on behalf of dead
            # threads; if a presumed-dead thread still manages a release,
            # the pool must not inflate past its capacity
            self._free = min(self._free + 1, self.capacity)
            self._cv.notify_all()
        for cv in self._subscribers:
            with cv:
                cv.notify_all()


class _Request:
    """One staged request flowing through the session pipeline."""

    __slots__ = ("x", "single", "fut", "t_submit", "rid", "deadline",
                 "deadline_ms", "off")

    def __init__(self, x, single: bool, fut: Future | None,
                 t_submit: float, rid: int,
                 deadline: float | None = None,
                 deadline_ms: float | None = None):
        self.x = x                    # staged host array (k, *input_shape)
        self.single = single          # un-batched submit: scatter row 0
        self.fut = fut                # None on run_many's inline bulk path
        self.t_submit = t_submit
        self.rid = rid                # session-unique id (fault targeting)
        self.deadline = deadline      # absolute monotonic, None = none
        self.deadline_ms = deadline_ms
        self.off = 0                  # row offset inside its staged bucket


class ServingSession:
    """Padding-bucketed request-batching queue over the cached executor,
    with pipelined dispatch.

    Callers ``submit()`` single items (H, W, C) or small batches
    (n, H, W, C) and get a ``Future``; a dispatch worker coalesces pending
    requests into device batches of at most ``max_batch`` items, pads each
    batch up to the nearest size in ``buckets`` (so the jit cache holds one
    executor per bucket instead of one per observed batch size), runs the
    accelerator's cached executor directly (no per-request DRAM dict work),
    and scatters the rows back to the futures in submission order.

    The hot path is **pipelined**, the software analog of the paper's
    LOAD/COMP/SAVE overlap: the dispatch worker launches device batch i+1
    while batch i is still in flight (JAX dispatch is asynchronous), and a
    separate drain thread blocks on completed batches and resolves their
    futures — the host-side numpy staging of one batch overlaps the device
    compute of the previous one. Staging uses two preallocated numpy
    buffers per bucket, reused alternately; a buffer is free for refill as
    soon as its batch is dispatched, because ``jnp.asarray`` copies
    host->device. Outstanding device batches are hard-capped at 3 (one
    being synced by the drain thread, one executing, one freshly staged —
    triple buffering), so the session never runs unboundedly ahead of the
    device. Per-bucket executors donate their input buffer (the staged
    device array is never reused), so steady-state batches allocate no
    fresh activation input.

    The session inherits the accelerator's PE ``backend`` and lowering
    ``opt_level``: per-bucket executors are fetched through
    ``HybridRuntime.executor_entry``, which keys the program cache on
    ``(schedule, bucket, dtype, backend, interpret, opt_level, donate,
    mesh)`` — an ``Accelerator.build(..., backend="pallas")`` session
    serves every request through the Pallas PE kernels.

    ``mesh``: a ``jax.sharding.Mesh`` — device batches whose bucket size is
    a multiple of the device count run through the **shard_map'd executor
    variant** (batch axis split over every mesh axis, weights replicated
    once at session start), the paper's NI-instances analog. Because each
    shard replays the whole per-shard program locally, this works for
    ``backend="pallas"`` too — GSPMD can't split the custom call, but
    inside the mapped region there is nothing left to split. Straggler
    buckets that don't divide by the device count fall back to the
    single-device executor, so both entry families coexist in one cache.

    ``scheduler`` selects the admission policy:

    * ``"continuous"`` (default) — continuous batching: the admitter fills
      the next in-flight device batch straight from the pending queue. The
      batching window (``max_wait_ms``) only caps the wait while a device
      slot is FREE; while the pipeline is full the admitter keeps admitting
      into the open batch instead of cutting it (dispatch is impossible
      anyway), so batches grow to fill devices under backpressure and
      padding collapses on bursty traffic.
    * ``"bucketed"`` — the legacy fixed-window policy: cut the batch when
      the window expires regardless of pipeline state, pad up to the
      bucket. Kept as the reference the scheduler tests compare against.

    ``stats`` records, besides request/batch counts, the trace+compile
    time spent on warmup and first-use buckets (``compile_ms``), recent
    windows of per-request submit-to-result latency (``p50_ms()`` /
    ``p95_ms()``) and queue wait (``wait_p50_ms()``), per-device batch
    counts (``device_batches``) and padding ``occupancy()``.

    ``slot_pool`` shares the device-pipeline slots with other sessions — a
    :class:`Fleet` passes one pool to every tenant model so device slots
    round-robin between them; standalone sessions get a private pool of 3.

    **Failure model** (full semantics in ``docs/ARCHITECTURE.md``):

    * ``deadline_ms`` (session default, overridable per ``submit``) — a
      request whose result has not drained by its deadline resolves with
      :class:`repro.serving.DeadlineExceeded` instead of hanging; the
      continuous admitter caps its coalescing hold at the earliest
      deadline in the open batch.
    * ``queue_limit`` + ``on_overload`` (``"shed"`` | ``"block"``) —
      bounded admission: past the limit, ``"shed"`` returns a future
      pre-failed with :class:`repro.serving.Overloaded`; ``"block"``
      makes ``submit`` wait for queue space.
    * poisoned-batch isolation — a failed coalesced batch is bisected and
      re-dispatched at the SAME bucket size with the excluded rows zeroed
      in place, so innocent co-batched requests still succeed
      **bitwise-identically** to a fault-free run; the offender fails with
      the causal exception (``stats.retries`` / ``stats.isolated``).
    * graceful backend degradation — on a ``backend="pallas"`` execution
      failure the whole batch is re-dispatched once through the XLA
      lowering (``stats.degraded``) before bisection, mirroring the AOT
      warn-and-recompile path.
    * ``guard_numerics`` — per-request NaN/Inf quarantine at drain time
      (:class:`repro.serving.NumericsError`); finite co-batched results
      still resolve.
    * supervision — a per-session supervisor thread enforces deadlines and
      watches the dispatch/drain threads (``is_alive`` + the
      ``HeartbeatMonitor``-based hang detector when ``hang_after_s`` is
      set). A dead thread fails every queued/in-flight future with
      :class:`repro.serving.PipelineCrashed` (causal exception chained),
      frees the dead thread's device slots and restarts the pipeline
      (``stats.watchdog_restarts``); ``close()`` stays idempotent through
      all of it.
    * ``fault_plan`` — a :class:`repro.serving.FaultPlan` wired into the
      pipeline boundaries for deterministic fault injection (tests/CI).

    The accounting invariant across all of the above:
    ``stats.submitted == stats.requests + stats.errors + stats.shed``
    once every accepted future has resolved.
    """

    SCHEDULERS = ("continuous", "bucketed")

    def __init__(self, acc: Accelerator, *, max_batch: int = 8,
                 buckets: Sequence[int] | None = None, mesh=None,
                 max_wait_ms: float = 5.0, warmup: bool = False,
                 scheduler: str = "continuous",
                 slot_pool: _SlotPool | None = None,
                 deadline_ms: float | None = None,
                 queue_limit: int | None = None,
                 on_overload: str = "shed",
                 guard_numerics: bool = False,
                 fault_plan=None,
                 supervise: bool = True,
                 hang_after_s: float | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if scheduler not in self.SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}: expected "
                             f"one of {self.SCHEDULERS}")
        if on_overload not in ("shed", "block"):
            raise ValueError(f"on_overload must be 'shed' or 'block', "
                             f"got {on_overload!r}")
        if queue_limit is not None and queue_limit < 1:
            raise ValueError("queue_limit must be >= 1")
        self.acc = acc
        self.scheduler = scheduler
        self.max_batch = int(max_batch)
        if buckets is None:
            buckets, b = [], 1
            while b < self.max_batch:
                buckets.append(b)
                b *= 2
            buckets.append(self.max_batch)
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        if self.buckets[-1] < self.max_batch or self.buckets[0] < 1:
            raise ValueError(
                f"buckets {self.buckets} must cover max_batch={max_batch}")
        self.stats = SessionStats()
        # resolve once: input_dtype/input_shape are properties that walk
        # the param tree — too costly to re-derive on every submit()
        self._in_dtype = np.dtype(acc.input_dtype)
        self._in_shape = tuple(acc.input_shape)
        # quantized accelerators keep the session float-in/float-out:
        # floats are quantized host-side at staging (so the device batch is
        # int8 end to end) and int8 logits dequantized at drain
        self._quant = acc.quant
        self._single_rank = len(self._in_shape)
        self._max_wait = max(0.0, max_wait_ms) / 1e3
        self._pending: deque = deque()
        self._cv = threading.Condition()
        self._closed = False

        # -- failure model state --------------------------------------------
        self._deadline_default = (None if deadline_ms is None
                                  else max(0.0, float(deadline_ms)))
        self.queue_limit = queue_limit
        self.on_overload = on_overload
        self._guard_numerics = bool(guard_numerics)
        self._faults = fault_plan
        self._rid_counter = itertools.count()
        self._deadlines = DeadlineTable()
        self._backend_tag = getattr(acc, "backend", "xla") or "xla"
        self._fallback_entries: dict[int, Any] = {}  # lazy XLA degradation
        self._fallback_lock = threading.Lock()
        # pipeline generation: bumped by the watchdog on restart; stale
        # threads check it and stand down without touching shared state
        self._gen = 0
        self._life_lock = threading.Lock()   # serializes restart vs close
        self._closed_done = False
        self._worker_exited_clean = False
        # slot bookkeeping the watchdog uses to free a dead thread's slots:
        # flags only ever flip in the owning thread, and are only read by
        # the watchdog after that thread is confirmed dead/joined
        self._worker_holds_slot = False
        self._drain_popped_unreleased = False
        # the group a pipeline thread is actively working on, visible so a
        # crash mid-dispatch / mid-deliver (group popped from the shared
        # deques, held only in the thread's locals) cannot strand futures:
        # the watchdog fails whatever a confirmed-dead thread left here
        self._worker_group: list | None = None
        self._drain_group: list | None = None
        self._thread_exc: BaseException | None = None   # causal, for restart
        self._sup = (ThreadSupervisor(("dispatch", "drain"),
                                      hang_after_s=hang_after_s)
                     if supervise else None)
        self._sup_cv = threading.Condition()
        self._sup_stop = False
        self._sup_thread: threading.Thread | None = None

        # hot path: one cached executor entry per bucket (validated once,
        # lowered once per bucket), donating the staged input buffer.
        # Falls back to acc(x) for segmented / strict accelerators.
        self._entries: dict[int, Any] = {}
        self._sharded_entries: dict[int, Any] = {}
        self._params = None
        self._params_sharded = None
        rt = acc.runtime
        if rt is not None and not rt.strict:
            # donation is best-effort (see the module-level warnings filter).
            # With an AOT bundle the deserialize happens HERE, inside
            # executor_entry -> cache.get — count it as warm-load time so
            # the stats line shows where the cold start went
            for b in self.buckets:
                t0 = time.monotonic()
                self._entries[b], self._params = rt.executor_entry(
                    b, acc.input_dtype, donate_input=True)
                if getattr(self._entries[b], "aot_loaded", False):
                    self.stats.warm_load_ms += (time.monotonic() - t0) * 1e3

        self._mesh = mesh
        self._n_devices = 1
        self._fleet_device_ids: tuple[int, ...] = (
            int(jax.devices()[0].id),)      # where unsharded batches land
        self._local_device_ids = self._fleet_device_ids
        if mesh is not None:
            self._n_devices = int(np.prod(mesh.devices.shape))
            if self._n_devices > 1 and self._params is None:
                # refuse rather than silently serve unsharded: sharding
                # needs the direct executor-entry hot path
                raise ValueError(
                    "mesh sharding requires the single-Program cached "
                    "executor path — segmented/strict accelerators can't "
                    "shard over the mesh")
            if self._n_devices > 1:
                # sharded executor variants for every bucket the mesh
                # divides evenly; stragglers keep the single-device entries.
                # Works for backend="pallas" too: each shard runs the whole
                # per-shard program locally under shard_map, so there is no
                # custom call left for GSPMD to split.
                for b in self.buckets:
                    if b % self._n_devices == 0:
                        self._sharded_entries[b], _ = rt.executor_entry(
                            b, acc.input_dtype, donate_input=True, mesh=mesh)
                if not self._sharded_entries:
                    raise ValueError(
                        f"no bucket in {self.buckets} divides evenly over "
                        f"the mesh's {self._n_devices} devices — sharded "
                        f"serving would never engage")
                # weights replicated once at session start; the separate
                # unsharded copy stays for straggler buckets (a replicated
                # array handed to the single-device jit would reshard on
                # every call)
                self._params_sharded = jax.device_put(
                    self._params,
                    jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
                self._fleet_device_ids = tuple(
                    int(d.id) for d in mesh.devices.flat)
                self._local_device_ids = (self._fleet_device_ids[0],)

        # completion pipeline: dispatched-but-unresolved batches, FIFO.
        # The slot pool bounds every outstanding device batch — the one the
        # drain thread is syncing, one executing, and one freshly staged —
        # the classic triple-buffer pipeline. The drainer holds its slot
        # until the host sync completes, so this is a hard device-memory
        # cap, not a soft target. A Fleet passes one shared pool so its
        # tenant models round-robin the same slots.
        self._inflight: deque = deque()
        self._inflight_cv = threading.Condition()
        # serializes staging+dispatch between the worker thread and
        # run_many's inline bulk path (both cycle the staging ring)
        self._dispatch_mutex = threading.Lock()
        self._slots = slot_pool if slot_pool is not None else _SlotPool(3)
        self._slots.subscribe(self._cv)   # full-pipeline admitters sleep
                                          # on _cv; wake them on slot free

        # host staging: a ring of numpy buffers per bucket, one per pipeline
        # slot, cycled per dispatch. The ring size MUST be >= the slot
        # capacity: a buffer is only refilled once its batch's slot has been
        # released (drained), so even if jax's CPU device_put zero-copies an
        # aligned host buffer instead of copying, no refill can race an
        # in-flight execution still reading it. (Two buffers against a
        # 3-deep pipeline let batch i+2 clobber batch i's input mid-run —
        # observed as rare wrong-row outputs under load.)
        self._staging = {
            b: [np.empty((b, *acc.input_shape),
                         np.dtype(acc.input_dtype))
                for _ in range(self._slots.capacity)]
            for b in self.buckets}
        self._staging_flip: dict[int, int] = {b: 0 for b in self.buckets}
        # run_many's inline bulk path gets its OWN ring: the worker and the
        # bulk path each release slots FIFO within themselves but interleave
        # arbitrarily across threads, so a shared ring could refill a buffer
        # whose batch is still in flight on the other path (lazily built —
        # most sessions never bulk-run every bucket)
        self._staging_bulk: dict[int, list] = {}
        self._bulk_flip: dict[int, int] = {}

        self._warm: set[int] = set()
        if warmup:   # pre-trace every bucket so first requests don't stall
            with _expected_donation_noise():
                for b in self.buckets:
                    z = jnp.zeros((b, *acc.input_shape), acc.input_dtype)
                    t0 = time.monotonic()
                    jax.block_until_ready(self._run_bucket(z))
                    self._count_first_use(b, t0)
                    self._warm.add(b)

        self._start_pipeline_threads()
        if supervise:
            self._sup_thread = threading.Thread(
                target=self._supervise, daemon=True,
                name="hybriddnn-serving-watchdog")
            self._sup_thread.start()

    def _start_pipeline_threads(self):
        """(Re)start the dispatch + drain pair for the current generation.
        Thread targets take the generation by value: a restarted pipeline
        must never process state a stale thread still thinks it owns."""
        gen = self._gen
        self._worker_exited_clean = False
        self._dispatch_thread = threading.Thread(
            target=self._worker, args=(gen,), daemon=True,
            name=f"hybriddnn-serving-g{gen}")
        self._drain_thread = threading.Thread(
            target=self._drainer, args=(gen,), daemon=True,
            name=f"hybriddnn-serving-drain-g{gen}")
        self._dispatch_thread.start()
        self._drain_thread.start()

    # -- client side --------------------------------------------------------
    def _stage(self, x) -> tuple[np.ndarray, bool]:
        """Validate + host-stage one request (no jax dispatch, no locks)."""
        x = np.asarray(x)
        if self._quant is not None and np.issubdtype(x.dtype, np.floating):
            # round-and-clip by the calibrated input scale — a bare dtype
            # cast would TRUNCATE floats toward zero and skip the clip
            x = np.clip(
                np.round(x.astype(np.float32)
                         / np.float32(self._quant.input_scale)),
                -127, 127).astype(self._in_dtype)
        else:
            x = np.asarray(x, self._in_dtype)
        if x.ndim == self._single_rank:
            x, single = x[None], True
        elif x.ndim == self._single_rank + 1:
            single = False
        else:
            raise ValueError(
                f"request rank {x.ndim} does not match input shape "
                f"{self._in_shape} (+ optional batch dim)")
        if not 1 <= x.shape[0] <= self.max_batch:
            raise ValueError(
                f"request batch {x.shape[0]} must be between 1 and "
                f"max_batch={self.max_batch}")
        if tuple(x.shape[1:]) != self._in_shape:
            # reject here, not in the worker: a malformed item would fail
            # the batch assembly and poison every co-batched request
            raise ValueError(
                f"request item shape {tuple(x.shape[1:])} does not match "
                f"the accelerator input shape {self.acc.input_shape}")
        return x, single

    def _make_request(self, x, fut: Future | None, now: float,
                      deadline_ms: float | None) -> _Request:
        """Stage + wrap one request; assigns its session-unique id and
        resolves its absolute deadline. The fault harness's ``staging``
        site fires here, on the caller's thread, against a private copy of
        the staged array (corruption must never alias the caller's
        buffer)."""
        xs, single = self._stage(x)
        rid = next(self._rid_counter)
        if self._faults is not None:
            xs = self._faults.visit(
                "staging", payload=np.array(xs), requests=(rid,),
                rows={rid: (0, xs.shape[0])})
        dl_ms = (self._deadline_default if deadline_ms is None
                 else max(0.0, float(deadline_ms)))
        dl = None if dl_ms is None else now + dl_ms / 1e3
        return _Request(xs, single, fut, now, rid, dl, dl_ms)

    def _queue_full(self) -> bool:
        """Caller holds ``_cv``. Compacts already-resolved (deadline-
        expired/cancelled) entries out of the queue before refusing —
        a dead request must not occupy admission capacity."""
        if len(self._pending) < self.queue_limit:
            return False
        self._pending = deque(
            r for r in self._pending
            if r.fut is None or not r.fut.done())
        return len(self._pending) >= self.queue_limit

    def _enqueue(self, reqs: list[_Request]):
        """Admission control: bounded queue with shed-or-block overflow,
        deadline registration, exact ``submitted`` accounting."""
        st = self.stats
        notify_sup = False
        with self._cv:
            if self._closed:
                raise RuntimeError("ServingSession is closed")
            for req in reqs:
                if self.queue_limit is not None and self._queue_full():
                    if self.on_overload == "block":
                        while self._queue_full() and not self._closed:
                            self._cv.wait(0.05)
                        if self._closed:
                            raise RuntimeError("ServingSession is closed")
                    else:
                        st.bump("submitted")
                        st.bump("shed")
                        req.fut.set_exception(Overloaded(
                            f"pending queue at queue_limit="
                            f"{self.queue_limit}; request shed"))
                        continue
                st.bump("submitted")
                self._pending.append(req)
                if req.deadline is not None:
                    if self._deadlines.add(req.deadline, req):
                        notify_sup = True
            self._cv.notify()
        if notify_sup and self._sup_thread is not None:
            with self._sup_cv:   # new earliest deadline: shorten the nap
                self._sup_cv.notify_all()

    def submit(self, x, *, deadline_ms: float | None = None) -> Future:
        """Enqueue one request; returns a Future of the result (a single
        item's logits for single-item requests, a batch for batched ones).

        The request is staged host-side (numpy): no jax dispatch happens on
        the caller's thread — the dispatch worker launches one device call
        per coalesced bucket. ``deadline_ms`` overrides the session default
        for this request: past it, the future resolves with
        :class:`repro.serving.DeadlineExceeded` rather than waiting for a
        result. When the session has a ``queue_limit`` and the queue is
        full, ``on_overload="shed"`` returns a future pre-failed with
        :class:`repro.serving.Overloaded`; ``"block"`` waits for space."""
        now = time.monotonic()
        req = self._make_request(x, Future(), now, deadline_ms)
        self._enqueue([req])
        return req.fut

    def submit_many(self, xs, *, deadline_ms: float | None = None
                    ) -> list[Future]:
        """Enqueue a whole request list under ONE lock acquisition.

        Per-request ``submit`` wakes the dispatch worker once per call —
        for a burst of hundreds of already-materialized requests that lock
        traffic alone costs more than a device batch. Validation happens
        before anything enqueues, so a malformed request poisons nothing.
        """
        now = time.monotonic()
        reqs = [self._make_request(x, Future(), now, deadline_ms)
                for x in xs]
        self._enqueue(reqs)
        return [r.fut for r in reqs]

    def __call__(self, x):
        """Synchronous convenience: submit + wait."""
        return self.submit(x).result()

    def run_many(self, xs) -> list:
        """Run a whole request list; returns results in request order.

        Bulk traffic takes an inline pipelined path: the calling thread
        stages and dispatches full device batches itself (same executor
        entries, same slot pool, same stats), keeping up to the pool's
        capacity in flight and syncing oldest-first. Skipping the
        worker/drain thread handoff matters on small hosts: two context
        switches per ~5ms batch is a few percent of throughput — the
        difference between beating the caller-batched direct loop and
        trailing it. Concurrent ``submit()`` traffic stays correct (the
        dispatch mutex serializes staging; the shared slot pool keeps
        device arbitration FIFO-fair), it just isn't co-batched with the
        bulk run."""
        t0 = time.monotonic()
        reqs = [self._make_request(x, None, t0, None) for x in xs]
        if not reqs:
            return []
        with self._cv:
            if self._closed:
                raise RuntimeError("ServingSession is closed")
        self.stats.bump("submitted", len(reqs))
        # cut [start, end) item groups of <= max_batch rows
        groups, start, n = [], 0, 0
        for i, r in enumerate(reqs):
            k = r.x.shape[0]
            if n + k > self.max_batch:
                groups.append((start, i, n))
                start, n = i, 0
            n += k
        groups.append((start, len(reqs), n))
        out: list = [None] * len(reqs)
        errs: list[Exception] = []
        inflight: deque = deque()   # (start, end, y, bucket, buf)

        def _deliver_bulk(s0, outcomes):
            st = self.stats
            for i, (r, ok, val) in enumerate(outcomes):
                if ok:
                    gexc = self._guard(r, val)
                    if gexc is None:
                        out[s0 + i] = val[0] if r.single else val
                        st.bump("requests")
                        continue
                    st.bump("isolated")
                    val = gexc
                errs.append(val)
                st.bump("errors")

        def _sync_oldest():
            s0, e0, y, bucket, buf = inflight.popleft()
            group = reqs[s0:e0]
            try:
                if self._faults is not None:
                    self._faults.visit(
                        "drain", requests=[r.rid for r in group])
                y_np = self._to_host(y)          # host sync (+ dequant)
            except Exception as exc:  # noqa: BLE001 — recover per request
                # recover BEFORE releasing the slot: the staging ring must
                # not refill ``buf`` until the bisection has re-read it
                try:
                    _deliver_bulk(s0, self._recover(group, bucket, buf, exc))
                finally:
                    self._slots.release()
                return
            self._slots.release()
            done_t = time.monotonic()
            self.stats.bump("batches")
            _deliver_bulk(
                s0, [(r, True, y_np[r.off:r.off + r.x.shape[0]])
                     for r in group])
            self.stats.record_latencies(
                [(done_t - t0) * 1e3] * (e0 - s0))

        try:
            for s0, e0, n in groups:
                if len(inflight) >= self._slots.capacity:
                    _sync_oldest()   # never self-deadlock on the pool
                group = reqs[s0:e0]
                self._slots.acquire()
                bucket = buf = None
                try:
                    with self._dispatch_mutex:
                        bucket, buf = self._stage_group(group, n, bulk=True)
                    y = self._launch(bucket, buf, group)
                except Exception as e:  # noqa: BLE001 — recover per request
                    try:
                        if buf is None:
                            raise    # staging failed: nothing to recover
                        _deliver_bulk(
                            s0, self._recover(group, bucket, buf, e))
                    finally:
                        self._slots.release()
                    continue
                except BaseException:
                    self._slots.release()
                    raise
                inflight.append((s0, e0, y, bucket, buf))
        finally:
            while inflight:     # release EVERY held slot even on error
                try:
                    _sync_oldest()
                except Exception as e:  # noqa: BLE001 — keep draining
                    errs.append(e)
        if errs:
            self._raise_joined(errs)
        return out

    @staticmethod
    def _raise_joined(errs: list[Exception]):
        """Raise the first error; the rest are attached as notes (3.11+)
        and ``secondary_errors``, and logged — a multi-slot failure must
        not silently swallow every error after the first."""
        first, rest = errs[0], errs[1:]
        for e in rest:
            log.error("serving: additional in-flight batch failure "
                      "(suppressed by %r): %r", first, e)
            if hasattr(first, "add_note"):   # pragma: no cover — py3.11+
                first.add_note(f"additionally failed: {e!r}")
        first.secondary_errors = tuple(rest)
        raise first

    def close(self):
        """Drain and shut down. Idempotent, and safe mid-failure: a
        pipeline that crashed (dead worker/drain thread) cannot strand
        ``close`` — joins are bounded, a missing drain sentinel is
        re-queued, and whatever is left queued/in-flight afterwards is
        failed with :class:`repro.serving.PipelineCrashed` and its device
        slots returned to the pool."""
        with self._life_lock:
            if self._closed_done:
                return
            with self._cv:
                self._closed = True
                self._cv.notify_all()
            self._dispatch_thread.join(timeout=60.0)
            if not self._worker_exited_clean:
                # the worker died without queueing the drain sentinel
                # (crashed or stale): queue it so the drainer can exit
                with self._inflight_cv:
                    self._inflight.append(None)
                    self._inflight_cv.notify_all()
            self._drain_thread.join(timeout=60.0)
            exc = PipelineCrashed("ServingSession closed while its "
                                  "pipeline was down")
            exc.__cause__ = self._thread_exc
            self._fail_all_queued(exc)
            self._closed_done = True
        if self._sup_thread is not None:
            with self._sup_cv:
                self._sup_stop = True
                self._sup_cv.notify_all()
            self._sup_thread.join(timeout=10.0)

    def _fail_all_queued(self, exc):
        """Fail every queued + in-flight request and return their pipeline
        slots. Only called with the pipeline threads dead or joined (close
        after join; watchdog after gen retirement), so the deques are not
        concurrently drained."""
        with self._cv:
            pending = list(self._pending)
            self._pending.clear()
            self._cv.notify_all()
        with self._inflight_cv:
            items = [it for it in self._inflight if it is not None]
            self._inflight.clear()
            self._inflight_cv.notify_all()
        for _ in range(len(items)):
            self._slots.release()
        # a dead thread's locals: its held slot, and the group it popped
        # from the shared deques but never handed off/delivered — without
        # collecting these, a crash mid-dispatch or mid-deliver would
        # strand futures forever (the liveness invariant's hardest case)
        stranded = []
        if not self._dispatch_thread.is_alive():
            if self._worker_holds_slot:
                self._worker_holds_slot = False
                self._slots.release()
            if self._worker_group:
                stranded.extend(self._worker_group)
                self._worker_group = None
        if not self._drain_thread.is_alive():
            if self._drain_popped_unreleased:
                self._drain_popped_unreleased = False
                self._slots.release()
            if self._drain_group:
                stranded.extend(self._drain_group)
                self._drain_group = None
        for it in items:
            for r in it[0]:
                self._reject_req(r, exc)
        for r in stranded:
            self._reject_req(r, exc)
        for r in pending:
            self._reject_req(r, exc)
        return len(items) + (1 if stranded else 0), len(pending)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- dispatch side ------------------------------------------------------
    def _take_group(self, gen: int):
        """Admit pending requests into one device batch (<= max_batch).

        ``"bucketed"``: the legacy fixed window — cut when ``max_wait_ms``
        expires, whatever the pipeline is doing. ``"continuous"``: the
        window only caps the wait while the device pipeline is IDLE — while
        any batch is still in flight, cutting a partial group early buys
        nothing (it would only queue behind the in-flight work) and wastes
        device time on padding, so the admitter keeps folding arrivals into
        the open batch until the pipeline drains or the batch fills. A hard
        cap (several windows) bounds the hold so a co-tenant model that
        keeps the shared slot pool busy can never starve a straggler —
        past it the group is cut and padded like the legacy path. The
        drainer wakes us (via the slot pool's subscriber hook) the moment a
        slot frees; the short wait below is only a backstop against a
        missed wakeup.

        Failure-model extensions: already-resolved requests (deadline
        expired / cancelled while queued) are dropped instead of admitted;
        the coalescing hold is additionally capped at the earliest
        deadline in the open batch (holding past it would guarantee a
        ``DeadlineExceeded``); and a retired generation (watchdog restart)
        hands its partial batch back to the queue and stands down.

        Returns ``(group, n, stale)``.
        """
        continuous = self.scheduler == "continuous"
        with self._cv:
            while (not self._pending and not self._closed
                   and self._gen == gen):
                self._beat("dispatch")
                self._cv.wait(0.25)
            if self._gen != gen:
                return None, 0, True
            if not self._pending:
                return None, 0, False    # closed and drained
            group, n = [], 0
            deadline = time.monotonic() + self._max_wait
            hard_deadline = deadline + 8 * self._max_wait
            while True:
                while (self._pending
                       and n + self._pending[0].x.shape[0] <= self.max_batch):
                    r = self._pending.popleft()
                    if r.fut is not None and r.fut.done():
                        continue     # expired/cancelled while queued
                    group.append(r)
                    n += r.x.shape[0]
                self._cv.notify_all()    # queue shrank: wake blocked admitters
                if (n >= self.max_batch or self._pending or self._closed
                        or self._gen != gen):
                    break                # full, head won't fit, or draining
                dls = [r.deadline for r in group if r.deadline is not None]
                batch_cap = min(dls) if dls else None
                now = time.monotonic()
                if batch_cap is not None and now >= batch_cap:
                    break                # earliest deadline reached: cut
                if (continuous and self._slots.busy() and now < hard_deadline
                        and (batch_cap is None or now < batch_cap)):
                    self._cv.wait(0.005)     # device busy: keep admitting
                    continue
                timeout = deadline - now
                if batch_cap is not None:
                    timeout = min(timeout, batch_cap - now)
                if timeout <= 0:
                    break                # batching window expired
                self._cv.wait(timeout)
            if self._gen != gen:
                # retired mid-take: hand the batch to the new pipeline
                self._pending.extendleft(reversed(group))
                return None, 0, True
            return group, n, False

    def _to_host(self, y) -> np.ndarray:
        """Host-sync one device batch; dequantize int8 logits to fp32.

        Dequantization is gated on the ARRAY dtype, not just the session:
        the ``acc(x)`` fallback path (segmented/strict accelerators)
        already returns dequantized fp32, and rescaling it twice would
        corrupt every co-batched result."""
        y_np = np.asarray(y)
        if self._quant is not None and y_np.dtype == np.int8:
            return (y_np.astype(np.float32)
                    * np.float32(self._quant.output_scale))
        return y_np

    def _run_bucket(self, x):
        b = x.shape[0]
        entry = self._sharded_entries.get(b)
        if entry is not None:
            return entry(self._params_sharded, x)
        entry = self._entries.get(b)
        if entry is not None:
            return entry(self._params, x)
        return self.acc(x)

    def _stage_group(self, group, n, *, bulk: bool = False):
        """Assemble one device batch into the staging ring — no dispatch.

        Assembly is numpy into a preallocated staging ring (one buffer per
        pipeline slot — see ``__init__``): per-op jax dispatch dominates at
        this granularity (8 expand_dims + concat + 8 slices per batch), so
        the queue would otherwise run slower than the direct loop it exists
        to beat. Records each request's row offset (``req.off``) so a
        failed batch can be bisected at the same offsets. Returns
        ``(bucket, buf)``; ``_launch`` dispatches it.
        """
        bucket = next(b for b in self.buckets if b >= n)
        if bulk:
            ring = self._staging_bulk.get(bucket)
            if ring is None:
                ring = self._staging_bulk[bucket] = [
                    np.empty_like(self._staging[bucket][0])
                    for _ in range(self._slots.capacity)]
                self._bulk_flip[bucket] = 0
            flips = self._bulk_flip
        else:
            ring, flips = self._staging[bucket], self._staging_flip
        buf = ring[flips[bucket]]
        flips[bucket] = (flips[bucket] + 1) % len(ring)
        off = 0
        for r in group:
            k = r.x.shape[0]
            buf[off:off + k] = r.x
            r.off = off
            off += k
        if bucket > n:
            buf[n:] = 0
            self.stats.padded_rows += bucket - n
        self.stats.dispatched_rows += n
        now = time.monotonic()
        self.stats.record_waits([(now - r.t_submit) * 1e3 for r in group])
        dev_ids = (self._fleet_device_ids
                   if bucket in self._sharded_entries
                   else self._local_device_ids)
        for d in dev_ids:
            self.stats.device_batches[d] = \
                self.stats.device_batches.get(d, 0) + 1
        return bucket, buf

    def _launch(self, bucket, buf, group):
        """Launch a staged batch — no host sync. The fault harness's
        ``dispatch`` and ``execute`` sites fire here; the drain thread (or
        the bulk path) syncs the returned in-flight device result."""
        if self._faults is not None:
            rids = [r.rid for r in group]
            self._faults.visit("dispatch", requests=rids)
            buf = self._faults.visit(
                "execute", payload=buf, requests=rids,
                rows={r.rid: (r.off, r.x.shape[0]) for r in group},
                backend=self._backend_tag)
        first_use = bucket not in self._warm
        t0 = time.monotonic()
        # the staging ring guarantees this buffer is not refilled until its
        # slot drains, so jnp.asarray may copy OR zero-copy-alias it safely
        if first_use:
            with _expected_donation_noise():   # compile happens in this call
                y = self._run_bucket(jnp.asarray(buf))
            self._count_first_use(bucket, t0)
            self._warm.add(bucket)
        else:
            y = self._run_bucket(jnp.asarray(buf))
        return y

    # -- failure handling ---------------------------------------------------
    def _beat(self, name: str):
        if self._sup is not None:
            self._sup.beat(name)

    def _guard(self, req: _Request, rows):
        """``guard_numerics``: the NumericsError for non-finite output rows
        of this request, else None."""
        if not self._guard_numerics:
            return None
        rows = np.asarray(rows)
        if (np.issubdtype(rows.dtype, np.floating)
                and not np.all(np.isfinite(rows))):
            return NumericsError(
                f"request {req.rid}: non-finite values in its output rows "
                f"quarantined (guard_numerics=True)")
        return None

    def _reject_req(self, req: _Request, exc: BaseException) -> bool:
        """Resolve ``req`` with ``exc``; True when THIS call resolved it.
        The set_exception winner does the error accounting, so a request
        racing the deadline enforcer against the drain thread is counted
        exactly once."""
        if req.fut is None:
            return False    # bulk path: run_many accounts for it inline
        try:
            req.fut.set_exception(exc)
        except InvalidStateError:
            return False
        st = self.stats
        with st._lat_lock:
            st.errors += 1
            if isinstance(exc, DeadlineExceeded):
                st.deadline_exceeded += 1
        return True

    def _resolve_req(self, req: _Request, rows) -> bool:
        """Resolve ``req`` with its output rows (numerics-guarded); True
        when this call delivered the result."""
        gexc = self._guard(req, rows)
        if gexc is not None:
            if self._reject_req(req, gexc):
                self.stats.bump("isolated")
            return False
        try:
            req.fut.set_result(rows[0] if req.single else rows)
        except InvalidStateError:
            return False    # expired/cancelled first; already accounted
        return True

    def _deliver(self, group, y_np):
        """Scatter a drained batch's rows to its futures + count it."""
        done_t = time.monotonic()
        n_ok, lats = 0, []
        for r in group:
            rows = y_np[r.off:r.off + r.x.shape[0]]
            if self._resolve_req(r, rows):
                n_ok += 1
                lats.append((done_t - r.t_submit) * 1e3)
        st = self.stats
        st.bump("batches")
        if n_ok:
            st.bump("requests", n_ok)
            st.record_latencies(lats)

    def _deliver_outcomes(self, group, outcomes):
        """Resolve per-request recovery outcomes ``(req, ok, rows|exc)``."""
        done_t = time.monotonic()
        n_ok, lats = 0, []
        for r, ok, val in outcomes:
            if ok:
                if self._resolve_req(r, val):
                    n_ok += 1
                    lats.append((done_t - r.t_submit) * 1e3)
            else:
                self._reject_req(r, val)
        if n_ok:
            self.stats.bump("requests", n_ok)
            self.stats.record_latencies(lats)

    def _fallback_entry(self, bucket: int):
        """The lazily-compiled XLA degradation executor for ``bucket`` —
        same Program, same params, ``backend="xla"`` keyed separately in
        the program cache. Raises for strict/segmented accelerators (no
        cached-entry hot path to degrade onto)."""
        with self._fallback_lock:
            pair = self._fallback_entries.get(bucket)
            if pair is None:
                rt = self.acc.runtime
                if rt is None or rt.strict or not self._entries:
                    raise RuntimeError("no XLA fallback entry available")
                pair = rt.executor_entry(bucket, self.acc.input_dtype,
                                         donate_input=False, backend="xla")
                self._fallback_entries[bucket] = pair
            return pair

    def _execute_staged(self, bucket, buf, group, *, fallback: bool = False):
        """Synchronously execute an already-staged buffer — the recovery
        path (XLA degradation and bisection retries). Re-visits the fault
        plan's ``execute`` site so request-bound ("cursed") faults keep
        firing on retry and the bisection converges on the offender."""
        if self._faults is not None:
            buf = self._faults.visit(
                "execute", payload=buf, requests=[r.rid for r in group],
                rows={r.rid: (r.off, r.x.shape[0]) for r in group},
                backend="xla" if fallback else self._backend_tag)
        if fallback:
            entry, params = self._fallback_entry(bucket)
            y = entry(params, jnp.asarray(buf))
        else:
            y = self._run_bucket(jnp.asarray(buf))
        return self._to_host(y)

    def _recover(self, group, bucket, buf, exc):
        """Per-request outcomes for a failed device batch.

        Order of escalation: (1) a ``backend="pallas"`` failure re-runs the
        WHOLE batch once through the XLA lowering (``stats.degraded``) —
        the kernel-level analog of the AOT warn-and-recompile path; (2)
        bisection — re-dispatch each half **at the same bucket size with
        the other half's rows zeroed in place**, recursing into halves
        that still fail until the offender is alone. Same bucket + same
        row offsets means the innocent rows run through the *identical*
        compiled executor at identical positions, so their results are
        bitwise-identical to a fault-free run (changing the bucket would
        change the lowering and drift the floats). Runs on the thread that
        detected the failure while the batch's pipeline slot is still held
        (the staging buffer must survive the re-reads).

        Returns ``[(req, ok, rows_or_exc), ...]`` in group order.
        """
        if self._backend_tag == "pallas":
            try:
                y_np = self._execute_staged(bucket, buf, group,
                                            fallback=True)
                self.stats.bump("degraded")
                log.warning(
                    "serving: batch of %d requests re-dispatched on the "
                    "XLA backend after a pallas failure: %r",
                    len(group), exc)
                return [(r, True, y_np[r.off:r.off + r.x.shape[0]])
                        for r in group]
            except Exception as e2:  # noqa: BLE001 — fall through to bisect
                log.warning("serving: XLA fallback also failed (%r); "
                            "bisecting the batch", e2)
        return self._bisect(group, bucket, buf, exc)

    def _bisect(self, group, bucket, buf, exc):
        if len(group) == 1:
            self.stats.bump("isolated")
            log.warning("serving: request %d isolated as the batch "
                        "offender: %r", group[0].rid, exc)
            return [(group[0], False, exc)]
        mid = len(group) // 2
        outcomes = []
        for part in (group[:mid], group[mid:]):
            part_buf = np.zeros_like(buf)
            for r in part:
                k = r.x.shape[0]
                part_buf[r.off:r.off + k] = buf[r.off:r.off + k]
            self.stats.bump("retries")
            try:
                y_np = self._execute_staged(bucket, part_buf, part)
            except Exception as e:  # noqa: BLE001 — recurse on the half
                outcomes.extend(self._bisect(part, bucket, part_buf, e))
                continue
            outcomes.extend((r, True, y_np[r.off:r.off + r.x.shape[0]])
                            for r in part)
        return outcomes

    def _count_first_use(self, bucket: int, t0: float):
        """Attribute a bucket's first-use stall to ``warm_load_ms`` when its
        executor deserialized from an AOT bundle (no compile happened —
        this is the warm-start cost), to ``compile_ms`` otherwise. Sharded
        entries always compile in-process (AOT binaries would pin one
        host's device ids), so they count as compile."""
        dt = (time.monotonic() - t0) * 1e3
        entry = (None if bucket in self._sharded_entries
                 else self._entries.get(bucket))
        if getattr(entry, "aot_loaded", False):
            self.stats.warm_load_ms += dt
        else:
            self.stats.compile_ms += dt

    def _worker(self, gen: int):
        """Dispatch loop: batch i+1 is staged and launched while batch i is
        still executing on the device (the drain thread owns completion).

        Crash containment: any escaping exception (including the fault
        harness's ``ThreadKilled``, a BaseException) is recorded as the
        causal ``_thread_exc`` and the thread dies — the supervisor
        detects the dead thread, fails stranded futures and restarts the
        pipeline under a new generation. A retired (stale-generation)
        worker hands unstarted work back to the queue and stands down
        without touching shared pipeline state."""
        try:
            while True:
                group, n, stale = self._take_group(gen)
                if stale:
                    return
                if group is None:
                    with self._inflight_cv:   # closed: wake the drain thread
                        self._inflight.append(None)
                        self._inflight_cv.notify_all()
                    self._worker_exited_clean = True
                    return
                if not group:
                    continue    # every admitted request had already expired
                # the group now lives only in this thread: publish it so the
                # watchdog can fail its futures if we die before handoff
                self._worker_group = group
                self._beat("dispatch")
                # acquire the pipeline slot BEFORE launching, so at most
                # pool-capacity device batches are ever outstanding — across
                # the whole Fleet when the pool is shared. The wait is
                # cancellable on generation retirement: a wedged pool (its
                # holder crashed) must not block the watchdog restart.
                if not self._slots.acquire(
                        cancelled=lambda: self._gen != gen):
                    with self._cv:
                        self._pending.extendleft(reversed(group))
                    self._worker_group = None
                    return
                self._worker_holds_slot = True
                bucket = buf = None
                try:
                    with self._dispatch_mutex:
                        bucket, buf = self._stage_group(group, n)
                    y = self._launch(bucket, buf, group)
                except Exception as e:  # noqa: BLE001 — recover per request
                    try:
                        outcomes = (self._recover(group, bucket, buf, e)
                                    if buf is not None else None)
                    finally:
                        self._slots.release()
                        self._worker_holds_slot = False
                    if outcomes is None:    # staging failed: nothing staged
                        self._fail_group(group, e)
                    else:
                        self._deliver_outcomes(group, outcomes)
                    self._worker_group = None
                    continue
                retired = False
                with self._inflight_cv:
                    if self._gen != gen:
                        retired = True    # watchdog owns cleanup now
                    else:
                        self._inflight.append((group, y, bucket, buf))
                        self._worker_holds_slot = False
                        self._worker_group = None
                        self._inflight_cv.notify_all()
                if retired:
                    self._slots.release()
                    self._worker_holds_slot = False
                    with self._cv:
                        self._pending.extendleft(reversed(group))
                    self._worker_group = None
                    return
        except BaseException as e:  # noqa: BLE001 — watchdog handles it
            self._thread_exc = e
            log.error("serving: dispatch worker died: %r", e)

    # -- completion side ----------------------------------------------------
    def _drainer(self, gen: int):
        """Completion loop: block on the oldest in-flight batch, scatter its
        rows back to the futures in submission order. The batch is PEEKED,
        synced, and only then released — releasing the dispatch slot before
        the host sync would let a third batch launch (and its staging
        buffer be refilled) while this one may still be executing, breaking
        the documented in-flight bound of the slot pool.

        A sync failure triggers per-request recovery (XLA degradation /
        bisection — see ``_recover``) BEFORE the slot is released, while
        the staged buffer is still guaranteed intact. A retired generation
        abandons its peeked batch untouched: after the generation bump the
        watchdog owns every in-flight item, and a stale pop/release here
        would double-free its slot."""
        try:
            while True:
                with self._inflight_cv:
                    while not self._inflight and self._gen == gen:
                        self._beat("drain")
                        self._inflight_cv.wait(0.25)
                    if self._gen != gen:
                        return
                    item = self._inflight[0]     # peek: slot stays occupied
                if item is None:
                    return
                self._beat("drain")
                group, y, bucket, buf = item
                exc = None
                try:
                    if self._faults is not None:
                        self._faults.visit(
                            "drain", requests=[r.rid for r in group])
                    y_np = self._to_host(y)  # the one host sync per batch
                                             # (+ dequant for int8 sessions)
                except Exception as e:  # noqa: BLE001 — device error lands here
                    exc = e
                outcomes = (None if exc is None
                            else self._recover(group, bucket, buf, exc))
                with self._inflight_cv:
                    if self._gen != gen or not self._inflight:
                        return               # retired mid-sync: abandon
                    self._inflight.popleft()     # only this thread pops
                    self._drain_popped_unreleased = True
                    self._drain_group = group    # local-only until delivered
                    self._inflight_cv.notify_all()
                self._slots.release()            # batch done: free the slot
                self._drain_popped_unreleased = False
                if outcomes is not None:
                    self._deliver_outcomes(group, outcomes)
                else:
                    self._deliver(group, y_np)
                self._drain_group = None
        except BaseException as e:  # noqa: BLE001 — watchdog handles it
            self._thread_exc = e
            log.error("serving: drain thread died: %r", e)

    def _fail_group(self, group, e):
        for r in group:
            self._reject_req(r, e)

    # -- supervision --------------------------------------------------------
    def _supervise(self):
        """Watchdog loop (own thread): enforce request deadlines and watch
        the pipeline threads. Sleeps until the earliest registered
        deadline (or a 50ms poll tick), fails due requests with
        ``DeadlineExceeded``, and triggers a pipeline restart when a
        dispatch/drain thread is dead — or silent past ``hang_after_s``
        while the session has work."""
        while True:
            with self._sup_cv:
                if self._sup_stop:
                    return
                timeout = 0.05
                nxt = self._deadlines.next_at()
                if nxt is not None:
                    timeout = min(timeout, max(0.001, nxt - time.monotonic()))
                self._sup_cv.wait(timeout)
                if self._sup_stop:
                    return
            now = time.monotonic()
            expired = False
            for req in self._deadlines.pop_due(now):
                if req.fut is not None and not req.fut.done():
                    if self._reject_req(req, DeadlineExceeded(
                            f"request {req.rid} missed its "
                            f"{req.deadline_ms:.1f}ms deadline")):
                        expired = True
            if expired:
                with self._cv:
                    self._cv.notify_all()    # free queue space / admitters
            if self._closed:
                continue    # keep enforcing deadlines until close() stops us
            if self._sup is not None:
                with self._cv:
                    busy = bool(self._pending)
                if not busy:
                    with self._inflight_cv:
                        busy = any(it is not None for it in self._inflight)
                self._sup.update_busy(busy, now=now)
                hung = self._sup.hung(now=now)
            else:
                hung = []
            dead = [name for name, t
                    in (("dispatch", self._dispatch_thread),
                        ("drain", self._drain_thread))
                    if not t.is_alive()]
            if dead or hung:
                self._restart_pipeline(hung)

    def _restart_pipeline(self, hung):
        """Retire the current pipeline generation, fail every queued and
        in-flight future with ``PipelineCrashed`` (causal exception
        chained), return the dead threads' device slots to the pool, and
        start fresh dispatch/drain threads. Serialized against ``close``
        by ``_life_lock``; re-validates liveness under the lock so a
        concurrent clean shutdown is never mistaken for a crash."""
        with self._life_lock:
            if self._closed or self._sup_stop or self._closed_done:
                return
            old = (self._dispatch_thread, self._drain_thread)
            dead = [name for name, t in zip(("dispatch", "drain"), old)
                    if not t.is_alive()]
            if not dead and not hung:
                return
            causal = self._thread_exc
            exc = PipelineCrashed(
                f"pipeline thread(s) {dead or hung} "
                f"{'died' if dead else 'hung'}; the watchdog failed this "
                f"request and restarted the pipeline")
            exc.__cause__ = causal
            with self._cv:
                self._gen += 1           # retire survivors
                self._cv.notify_all()
            with self._inflight_cv:
                self._inflight_cv.notify_all()
            for t in old:
                t.join(timeout=15.0)
            n_inflight, n_pending = self._fail_all_queued(exc)
            self._thread_exc = None
            self.stats.bump("watchdog_restarts")
            log.warning(
                "serving: watchdog restarted the pipeline (gen %d) after "
                "%s %s; failed %d in-flight batch(es) + %d queued "
                "request(s) with PipelineCrashed (causal: %r)",
                self._gen, dead or hung, "died" if dead else "hung",
                n_inflight, n_pending, causal)
            if self._sup is not None:
                self._sup.update_busy(False)     # re-arm hang detection
            self._start_pipeline_threads()


# ---------------------------------------------------------------------------
# Fleet: multi-model tenancy over one process / one device pool
# ---------------------------------------------------------------------------

class Fleet:
    """Several :class:`Accelerator` models served from ONE process over one
    device pool — the paper's NI-instances analog taken to a rack.

    Each model gets its own :class:`ServingSession` (own pending queue, own
    staging buffers, own stats), but every session shares:

    * **one device-slot pool** — the in-flight pipeline slots are a single
      FIFO-fair pool, so device time round-robins between tenant models
      instead of one model's burst starving the rest;
    * **one program cache** — accelerators built against the process-global
      ``core.program_cache.default_cache()`` (the default) land their
      executors side by side in it, keyed by schedule/backend/mesh, so two
      models never recompile each other's entries away by identity;
    * **one mesh** (optional) — full buckets of every model shard over the
      same devices via the shard_map'd executor variant.

    ::

        fleet = api.Fleet({"vgg16": acc_vgg, "resnet18": acc_res},
                          mesh="host", max_batch=8)
        fut = fleet.submit("resnet18", x)       # routed to that model
        y = fleet("vgg16", x)                   # submit + wait

    Per-model outputs are bitwise-stable under tenancy: a model's requests
    run through exactly the cached executor entries its standalone session
    would use — co-tenancy only changes *when* a batch gets a device slot,
    never what it computes (asserted in ``tests/test_fleet_serving.py``).
    """

    def __init__(self, accelerators, *, mesh=None, max_batch: int = 8,
                 buckets: Sequence[int] | None = None,
                 max_wait_ms: float = 5.0, warmup: bool = False,
                 scheduler: str = "continuous", max_inflight: int = 3,
                 deadline_ms: float | None = None,
                 queue_limit: int | None = None,
                 on_overload: str = "shed",
                 guard_numerics: bool = False,
                 fault_plan=None,
                 supervise: bool = True,
                 hang_after_s: float | None = None):
        items = dict(accelerators)
        if not items:
            raise ValueError("Fleet needs at least one named Accelerator")
        if mesh == "host":
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.mesh = mesh
        self._pool = _SlotPool(max_inflight)
        self.sessions: dict[str, ServingSession] = {}
        for name, acc in items.items():
            # the failure model is per-session (each tenant gets its own
            # deadlines/queue bound/watchdog) over the SHARED slot pool —
            # a tenant's watchdog restart returns its dead pipeline's
            # slots so co-tenants never lose pool capacity
            self.sessions[name] = ServingSession(
                acc, max_batch=max_batch, buckets=buckets, mesh=mesh,
                max_wait_ms=max_wait_ms, warmup=warmup, scheduler=scheduler,
                slot_pool=self._pool, deadline_ms=deadline_ms,
                queue_limit=queue_limit, on_overload=on_overload,
                guard_numerics=guard_numerics, fault_plan=fault_plan,
                supervise=supervise, hang_after_s=hang_after_s)

    @property
    def models(self) -> tuple[str, ...]:
        return tuple(self.sessions)

    def _session(self, model: str) -> ServingSession:
        try:
            return self.sessions[model]
        except KeyError:
            raise ValueError(f"unknown model {model!r}: fleet serves "
                             f"{sorted(self.sessions)}") from None

    def submit(self, model: str, x) -> Future:
        """Enqueue one request for ``model``; returns its Future."""
        return self._session(model).submit(x)

    def __call__(self, model: str, x):
        """Synchronous convenience: submit + wait."""
        return self.submit(model, x).result()

    def run_many(self, requests) -> list:
        """``requests``: iterable of ``(model, x)`` pairs. Every request is
        submitted first — so co-tenant models contend for device slots the
        way live traffic would — then gathered in submission order."""
        pairs = [(m, x) for m, x in requests]
        by_model: dict[str, list] = {}
        for m, x in pairs:
            by_model.setdefault(m, []).append(x)
        futs_by_model = {m: iter(self._session(m).submit_many(xs))
                         for m, xs in by_model.items()}
        futs = [next(futs_by_model[m]) for m, _ in pairs]
        return [f.result() for f in futs]

    def stats(self) -> dict[str, SessionStats]:
        """Per-model :class:`SessionStats`, keyed by model name."""
        return {name: s.stats for name, s in self.sessions.items()}

    def close(self):
        for s in self.sessions.values():
            s.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

