"""``repro.api`` — one façade for the paper's full design flow (Fig. 1).

The paper's headline contribution is a *framework*: model + hardware target
in, deployed accelerator out. This module is that framework's user surface:

    from repro import api
    from repro.core import perf_model as pm
    from repro.models import vgg

    specs = vgg.network_specs(img=64, scale=8, n_classes=10)
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=8)
    logits = acc(x)                 # cached, validated, jitted executor
    print(acc.summary())            # per-layer mode/dataflow/latency table

``Accelerator.build`` runs the DSE (Sec. 5) through the unified ``Target``
protocol — any object with ``run_dse(specs, batch)`` works, so ``pm.V5E``
and the ``pm.FPGATarget`` instances dispatch identically — compiles ONE
``Program`` (Sec. 4.1), validates the hazard schedule once, and returns a
callable accelerator whose requests hit the cached jitted executor.

``Accelerator.save_program`` / ``Accelerator.from_program`` persist the
compiled instruction stream (plus specs/plans and the DSE verdict) so a
deployment can skip the DSE; the loader recompiles and verifies the stream
bit-exactly.

``ServingSession`` (via ``Accelerator.serve()``) is the paper's NI-instances
analog on the host mesh: a continuous-batching request queue that coalesces
single-image requests into device batches (admitting late arrivals while the
device pipeline is busy, deadline-capped), pads stragglers up to a fixed set
of bucket sizes (so the jit cache holds one executor per bucket), and
optionally shards full buckets over a device mesh via the shard_map'd
executor variant — with BOTH backends, since each shard is an ordinary
single-device trace. ``Fleet`` stacks several sessions over one process,
one program cache, and one FIFO-fair device-slot pool for multi-model
tenancy.

``backend="xla" | "pallas"`` (on ``build``, ``from_program``, and inherited
by sessions) selects the PE implementation every CONV/FC block lowers
through — the XLA ops (the default) or the Pallas PE kernels
(interpret-mode fallback off-TPU). See ``docs/ARCHITECTURE.md`` for
the plug-in table and ``docs/API.md`` for the full reference.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import warnings
from collections import deque
from contextlib import contextmanager
from concurrent.futures import Future, InvalidStateError
from typing import Any, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as pm
from repro.core.compiler import NO_PLAN, LayerPlan, Program, compile_network
from repro.core.dse import DSEResult, FPGACandidate, TPUCandidate
from repro.core.hybrid_conv import (
    ConvSpec,
    DepthwiseSpec,
    EltwiseSpec,
    FCSpec,
    PoolSpec,
)
from repro.core.runtime import HybridRuntime
from repro.quant import QuantSidecar, quantize_params
from repro.quant import calibrate as quant_calibrate

PROGRAM_FORMAT = "hybriddnn-program/v1"


class ProgramLoadError(ValueError):
    """A saved program/bundle that cannot be loaded: truncated or non-JSON
    file, unknown format version, instruction-stream or quant-sidecar
    digest mismatch. Subclasses ``ValueError`` so pre-existing callers that
    catch the broad class keep working; new callers should catch this."""


@contextmanager
def _expected_donation_noise():
    """ServingSession opts into best-effort input donation: when a bucket's
    input buffer has no same-shape reuse inside the executor (e.g. the
    entry layout transform changes its shape immediately), XLA warns at
    compile time and keeps a copy — expected by design. Suppress exactly
    that message around the session's own compile sites only, so a user's
    own ``jax.jit(..., donate_argnums=...)`` diagnostics stay visible.

    ``warnings.catch_warnings`` mutates process-global filter state and is
    not thread-safe, so this is a no-op off the main thread: a cold bucket
    compiled lazily in the dispatch worker emits the (harmless, one-time)
    note rather than risk corrupting a user thread's filter stack."""
    if threading.current_thread() is not threading.main_thread():
        yield
        return
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable",
            category=UserWarning)
        yield


@runtime_checkable
class Target(Protocol):
    """Anything that can run the paper's DSE for a layer chain.

    ``pm.TPUTarget`` and ``pm.FPGATarget`` both implement this, so callers
    never branch on ``run_tpu_dse`` vs ``run_fpga_dse`` — they hand any
    target instance to ``Accelerator.build``.
    """

    def run_dse(self, specs, batch: int = 1) -> DSEResult: ...


def random_params(specs: Sequence[Any], seed: int = 0) -> list:
    """Random ``[(w, b), ...]`` for every parameterized layer (CONV, FC and
    DEPTHWISE; POOL and ELTWISE carry no params), fan-in scaled — the
    stand-in for trained weights throughout the repo."""
    rng = np.random.default_rng(seed)
    params = []
    for s in specs:
        if isinstance(s, ConvSpec):
            w = jnp.asarray(rng.standard_normal((s.r, s.s, s.c, s.k)),
                            jnp.float32) * (s.r * s.s * s.c) ** -0.5
            params.append((w, jnp.zeros((s.k,), jnp.float32)))
        elif isinstance(s, DepthwiseSpec):
            w = jnp.asarray(rng.standard_normal((s.r, s.s, 1, s.c)),
                            jnp.float32) * (s.r * s.s) ** -0.5
            params.append((w, jnp.zeros((s.c,), jnp.float32)))
        elif isinstance(s, FCSpec):
            w = jnp.asarray(rng.standard_normal((s.d_in, s.d_out)),
                            jnp.float32) * s.d_in ** -0.5
            params.append((w, jnp.zeros((s.d_out,), jnp.float32)))
    return params


def _conv_segments_of(specs) -> list[int]:
    """Consecutive-CONV run lengths between maxpools (VGG16: [2,2,3,3,3]).

    The segmented request glues segments with a host-side maxpool, so the
    chain must be ``(CONV+ POOL)+ FC*`` — anything else (trailing CONVs
    without a pool, a pool before any CONV, CONVs after the FC tail) gets a
    descriptive error instead of an opaque crash downstream."""
    segments, run, seen_fc = [], 0, False
    for s in specs:
        if isinstance(s, (EltwiseSpec, DepthwiseSpec)):
            raise ValueError(
                f"segmented path: {type(s).__name__} {s.name!r} — residual "
                f"adds and depthwise convs need the single-Program path "
                f"(segmented=False); the legacy glue only handles "
                f"(CONV+ POOL)+ FC*")
        if isinstance(s, ConvSpec):
            if s.inp_from is not None:
                raise ValueError(
                    f"segmented path: CONV {s.name!r} reroutes its input "
                    f"(inp_from={s.inp_from}) — skip wiring needs the "
                    f"single-Program path (segmented=False)")
            if seen_fc:
                raise ValueError("segmented path: CONV after the FC tail")
            run += 1
        elif isinstance(s, PoolSpec):
            if seen_fc:
                raise ValueError("segmented path: POOL after the FC tail")
            if run == 0:
                raise ValueError(
                    "segmented path: maxpool without a preceding CONV "
                    "segment — the chain must be (CONV+ POOL)+ FC*")
            segments.append(run)
            run = 0
        else:
            seen_fc = True
    if run:
        raise ValueError(
            "segmented path: trailing CONV segment without a maxpool — "
            "use the single-Program path (segmented=False) for this chain")
    if not segments:
        raise ValueError("segmented path: no CONV+POOL segment in the chain")
    return segments


def build_segmented_request(specs, plans, params, *, strict: bool = False,
                            cache=None, backend: str = "xla",
                            interpret: bool | None = None,
                            opt_level: int = 1):
    """The legacy multi-Program path: one compiled Program per CONV segment,
    host-side 2x2 maxpool glue between segments, and the FC tail outside
    the runtime. Kept as ``Accelerator.build(..., segmented=True)``;
    asserted numerically identical to the single-Program path in
    ``tests/test_integration.py``. ``strict=True`` builds the per-segment
    runtimes on the per-instruction interpreter instead of the cached
    jitted executor; ``cache`` overrides the process-global program cache
    for every segment runtime; ``backend``/``interpret`` select the PE
    implementation for the segment runtimes AND the host-side FC tail;
    ``opt_level`` is the lowering-optimizer level of each segment
    executor."""
    from repro.core.executor import resolve_backend, resolve_opt_level
    from repro.core.hybrid_conv import dense, max_pool2d

    resolve_backend(backend, interpret)   # reject bad combos before building
    resolve_opt_level(opt_level)

    # params align with the non-pool specs, in network order
    nonpool = [s for s in specs if not isinstance(s, PoolSpec)]
    assert len(nonpool) == len(params)
    conv_specs = [s for s in specs if isinstance(s, ConvSpec)]
    conv_plans = [p for s, p in zip(specs, plans) if isinstance(s, ConvSpec)]
    conv_params = [p for s, p in zip(nonpool, params)
                   if isinstance(s, ConvSpec)]
    pool_specs = [s for s in specs if isinstance(s, PoolSpec)]
    fc_specs = [s for s in nonpool if isinstance(s, FCSpec)]
    fc_params = [p for s, p in zip(nonpool, params) if isinstance(s, FCSpec)]

    runtimes, idx, n_instr = [], 0, 0
    for n in _conv_segments_of(specs):
        program = compile_network(conv_specs[idx:idx + n],
                                  conv_plans[idx:idx + n])
        rt = HybridRuntime(program, strict=strict, cache=cache,
                           backend=backend, interpret=interpret,
                           opt_level=opt_level)
        rt.load_params(conv_params[idx:idx + n])
        runtimes.append(rt)
        n_instr += len(program.instructions)
        idx += n

    assert len(pool_specs) == len(runtimes), \
        "segmented path expects one maxpool after each CONV segment"

    def request(x):
        for rt, ps in zip(runtimes, pool_specs):
            x = max_pool2d(rt.run(x), ps.window, ps.stride)
        x = x.reshape(x.shape[0], -1)
        for s, (w, b) in zip(fc_specs, fc_params):
            x = dense(x, w, b, relu=s.relu,
                      use_pallas=backend == "pallas", interpret=interpret)
        return x

    return request, runtimes, n_instr


# ---------------------------------------------------------------------------
# Program (de)serialization helpers
# ---------------------------------------------------------------------------

_SPEC_KINDS = {"conv": ConvSpec, "pool": PoolSpec, "fc": FCSpec,
               "eltwise": EltwiseSpec, "dw": DepthwiseSpec}


def _spec_to_dict(spec) -> dict:
    kind = next(k for k, cls in _SPEC_KINDS.items()
                if type(spec) is cls)
    return {"kind": kind, **dataclasses.asdict(spec)}


def _spec_from_dict(d: dict):
    d = dict(d)
    return _SPEC_KINDS[d.pop("kind")](**d)


def _hw_to_dict(hw) -> dict:
    if isinstance(hw, TPUCandidate):
        return {"type": "tpu", **dataclasses.asdict(hw)}
    if isinstance(hw, FPGACandidate):
        return {"type": "fpga", **dataclasses.asdict(hw)}
    return {"type": "other", "repr": repr(hw)}


def _hw_from_dict(d: dict):
    d = dict(d)
    typ = d.pop("type")
    if typ == "tpu":
        return TPUCandidate(**d)
    if typ == "fpga":
        return FPGACandidate(**d)
    return d.get("repr")


def _fmt_t(seconds: float) -> str:
    if seconds < 1e-3:
        return f"{seconds * 1e6:8.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:8.2f} ms"
    return f"{seconds:8.3f} s "


# ---------------------------------------------------------------------------
# The façade
# ---------------------------------------------------------------------------

class Accelerator:
    """A built accelerator: DSE verdict + ONE compiled Program + the cached,
    validated, jitted executor behind ``__call__``.

    Construct with :meth:`build` (the full flow) or :meth:`from_program`
    (reuse a saved instruction stream, skipping the DSE). ``backend``
    selects the PE implementation the executor lowers each CONV/FC block
    through — ``"xla"`` (default) or ``"pallas"`` (the Pallas TPU kernels,
    interpret-mode on CPU unless overridden) — see ``docs/ARCHITECTURE.md``.

    Instances are callable: ``acc(x)`` runs one inference request through
    the cached executor. :meth:`summary` prints the per-layer DSE verdict,
    :meth:`save_program` / :meth:`from_program` persist/restore the
    compiled stream, and :meth:`serve` opens a batching
    :class:`ServingSession`.
    """

    def __init__(self, *, specs, plans, params, request, target=None,
                 batch: int = 1, program: Program | None = None,
                 runtime: HybridRuntime | None = None,
                 dse: DSEResult | None = None, segmented: bool = False,
                 segment_runtimes: list | None = None,
                 backend: str = "xla", interpret: bool | None = None,
                 opt_level: int = 1, quant=None):
        self.specs = list(specs)
        self.plans = list(plans)
        self.params = params
        self.target = target
        self.batch = batch
        self.program = program
        self.runtime = runtime
        self.dse = dse
        self.segmented = segmented
        self.segment_runtimes = segment_runtimes
        self.backend = backend
        self.interpret = interpret
        self.opt_level = opt_level
        self.quant = quant          # QuantSidecar for int8 accelerators
        self._request = request

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, specs, target: Target = pm.V5E, *, batch: int = 8,
              params: list | None = None, seed: int = 0,
              plans: Sequence[LayerPlan | None] | None = None,
              segmented: bool = False, strict: bool = False,
              cache=None, backend: str = "xla",
              interpret: bool | None = None,
              opt_level: int = 1, dtype: str = "float32",
              calib=None, observer: str = "percentile") -> "Accelerator":
        """DSE -> compile -> validate, in one call.

        ``target`` is any :class:`Target` (``pm.V5E``, ``pm.VU9P``,
        ``pm.PYNQ_Z1``, or a custom instance). ``plans`` overrides the DSE
        (skips it entirely — useful for benchmarks pinning a schedule).
        ``params`` defaults to :func:`random_params`. ``segmented=True``
        builds the legacy multi-Program path instead (one Program per CONV
        segment, host-side glue); ``strict=True`` runs the per-instruction
        interpreter instead of the cached executor.

        ``backend="pallas"`` routes every CONV/FC block through the Pallas
        PE kernels instead of the XLA ops; ``interpret`` overrides the
        Pallas interpret-mode auto-selection (``None`` = interpret mode
        everywhere but real TPU). ``opt_level`` selects the lowering
        optimizer — ``1`` (default) collapses each layer's per-block loop
        into one whole-layer PE dispatch where provably equivalent, ``0``
        keeps the literal per-block lowering (the reference). Backend and
        opt_level both join the program-cache key, so the same Program
        serves every variant side by side.

        ``dtype="int8"`` builds a fully quantized accelerator: the DSE
        plans against the target's int8 variant (Winograd gated off — no
        int8 U-space transform), ``calib`` (an (n, H, W, C) array or list
        of batches; defaults to seeded random data) drives post-training
        calibration into a ``repro.quant.QuantSidecar``, params are
        quantized per-tensor symmetric (int8 weights, int32 bias), and
        every path — cached executor, strict interpreter, Pallas PEs —
        runs int8 GEMMs with a fused requantize+ReLU epilogue. ``observer``
        picks the activation-range estimator (``"percentile"`` default,
        or ``"minmax"``). The accelerator stays float-in/float-out:
        ``__call__`` quantizes inputs by the calibrated input scale and
        dequantizes the int8 logits (a positive per-tensor rescale, so
        top-1 is taken on the same ordering the device computed).
        """
        specs = list(specs)
        if dtype not in ("float32", "int8"):
            raise ValueError(f"unsupported dtype {dtype!r}: expected "
                             f"'float32' or 'int8'")
        if dtype == "int8" and segmented:
            raise ValueError("segmented accelerators are fp32-only — the "
                             "int8 path needs the single-Program runtime "
                             "(the sidecar is keyed to one schedule)")
        dse = None
        if plans is None:
            if not isinstance(target, Target):
                raise TypeError(
                    f"target {target!r} does not implement the Target "
                    f"protocol (needs a run_dse(specs, batch) method) — pass "
                    f"e.g. pm.V5E, pm.VU9P, pm.PYNQ_Z1, or supply plans=")
            # dtype is only passed when quantizing, so custom fp32 targets
            # that predate the dtype parameter keep working unchanged
            dse = (target.run_dse(specs, batch=batch, dtype=dtype)
                   if dtype != "float32"
                   else target.run_dse(specs, batch=batch))
            plans = list(dse.plans)
        else:
            plans = list(plans)
        if params is None:
            params = random_params(specs, seed)

        quant = None
        if dtype == "int8":
            if calib is None:
                # stand-in calibration data, seeded like random_params: real
                # deployments pass a slice of the training set instead
                s0 = specs[0]
                shape = ((8, s0.d_in) if isinstance(s0, FCSpec)
                         else (8, s0.h, s0.w, s0.c))
                calib = np.random.default_rng(seed + 1).standard_normal(
                    shape).astype(np.float32)
            quant = quant_calibrate(specs, params, calib, observer=observer)
            params = quantize_params(specs, params, quant)

        if segmented:
            request, seg_rts, _ = build_segmented_request(
                specs, plans, params, strict=strict, cache=cache,
                backend=backend, interpret=interpret, opt_level=opt_level)
            return cls(specs=specs, plans=plans, params=params,
                       request=request, target=target, batch=batch, dse=dse,
                       segmented=True, segment_runtimes=seg_rts,
                       backend=backend, interpret=interpret,
                       opt_level=opt_level)

        program = compile_network(specs, plans)
        rt = HybridRuntime(program, strict=strict, cache=cache,
                           backend=backend, interpret=interpret,
                           opt_level=opt_level, quant=quant)
        rt.load_params(params)
        if not strict:
            rt.cache.validate(program)   # schedule check once, at build time
        return cls(specs=specs, plans=plans, params=params, request=rt.run,
                   target=target, batch=batch, program=program, runtime=rt,
                   dse=dse, backend=backend, interpret=interpret,
                   opt_level=opt_level, quant=quant)

    # -- inference ----------------------------------------------------------
    def __call__(self, x):
        """One inference request. ``x``: (n, H, W, C) for CONV-first models,
        (n, D) for FC-first. Steady-state calls are cache hits only.
        Quantized accelerators are float-in/float-out: float inputs are
        quantized by the calibrated input scale (already-int8 inputs pass
        through) and the int8 logits are dequantized back to fp32."""
        if self.quant is not None:
            y = self._request(jnp.asarray(x))   # runtime quantizes floats
            return self.quant.dequantize_output(y)
        return self._request(jnp.asarray(x, self.input_dtype))

    @property
    def input_dtype(self):
        if self.params:
            return self.params[0][0].dtype
        return jnp.float32

    @property
    def input_shape(self) -> tuple[int, ...]:
        """Shape of ONE request item (no batch dim)."""
        s0 = self.specs[0]
        if isinstance(s0, FCSpec):
            return (s0.d_in,)
        return (s0.h, s0.w, s0.c)

    @property
    def n_instructions(self) -> int:
        if self.program is not None:
            return len(self.program.instructions)
        return sum(len(rt.program.instructions)
                   for rt in self.segment_runtimes or [])

    def strict_request(self):
        """A per-instruction-interpreter request fn over the same Program(s)
        and params — the hazard-faithful baseline for comparisons. Always
        runs the XLA PE, regardless of this accelerator's ``backend``, so
        it can serve as the numerical oracle for the Pallas path too. For
        quantized accelerators the interpreter carries the same sidecar, so
        its int8 outputs are bitwise-comparable to the raw executor's."""
        if self.segmented:
            return build_segmented_request(
                self.specs, self.plans, self.params, strict=True)[0]
        rt = HybridRuntime(self.program, strict=True, quant=self.quant)
        rt.load_params(self.params)
        return rt.run

    # -- reporting ----------------------------------------------------------
    def _hw_desc(self) -> str:
        if self.dse is None:
            return "plans supplied (no DSE)"
        hw = self.dse.hw
        if isinstance(hw, TPUCandidate):
            return (f"blocks=({hw.bm},{hw.bk},{hw.bn}) m={hw.m} | DSE over "
                    f"{self.dse.candidates_searched} candidates")
        if isinstance(hw, FPGACandidate):
            return (f"PI={hw.pi} PO={hw.po} PT={hw.pt} NI={hw.ni} | DSE over "
                    f"{self.dse.candidates_searched} candidates")
        return str(hw)

    def summary(self) -> str:
        """Per-layer plan/latency table — the DSE verdict, human-readable."""
        # target is an instance with .name, or the bare name string a
        # from_program-restored accelerator carries
        tname = (self.target if isinstance(self.target, str)
                 else getattr(self.target, "name", None)) or "-"
        kind_of = {ConvSpec: "conv", PoolSpec: "pool", FCSpec: "fc",
                   EltwiseSpec: "eltwise", DepthwiseSpec: "dw"}
        head = (f"{len(self.specs)} layers as "
                + (f"{len(self.segment_runtimes)} segment Programs + host "
                   f"glue" if self.segmented else
                   f"ONE Program ({self.n_instructions} instructions)"))
        lines = [f"Accelerator[{tname}]: {head}",
                 f"  {self._hw_desc()}, batch={self.batch}",
                 f"  {'layer':<12}{'kind':<9}{'dtype':<9}{'mode':<6}"
                 f"{'df':<4}{'m':>2}{'g_h':>5}{'g_k':>5}"
                 f"  {'latency':>11}{'share':>8}"]
        lats = self.dse.layer_latencies if self.dse else None
        total = self.dse.total_latency if self.dse else None
        for i, (s, p) in enumerate(zip(self.specs, self.plans)):
            kind = kind_of[type(s)]
            p = p or NO_PLAN
            mode, df, m = (p.mode, p.dataflow, str(p.m)) \
                if kind == "conv" else ("-", "-", "-")
            gh, gk = ((str(p.g_h), str(p.g_k)) if kind == "conv"
                      else ("-", "-"))
            # precision per layer: "int8+rq" = int8 math with the fused
            # requantize epilogue, "int8" = scale-passthrough (pool)
            if self.quant is None:
                dt = "fp32"
            else:
                dt = ("int8+rq" if self.quant.layers[i].requantize
                      else "int8")
            lat = _fmt_t(lats[i]) if lats else "          -"
            share = (f"{100 * lats[i] / total:6.1f}%"
                     if lats and total else "      -")
            lines.append(f"  {s.name:<12}{kind:<9}{dt:<9}{mode:<6}{df:<4}"
                         f"{m:>2}{gh:>5}{gk:>5}  {lat}{share}")
        if total is not None:
            macs = sum(s.macs for s in self.specs)
            scale = self.batch if isinstance(self.dse.hw, TPUCandidate) else 1
            gops = 2.0 * macs * scale / total / 1e9
            lines.append(f"  est. total {_fmt_t(total).strip()} "
                         f"({gops:.1f} effective GOPS)")
        return "\n".join(lines)

    # -- persistence --------------------------------------------------------
    def save_program(self, path: str, *, aot: bool = False,
                     buckets: Sequence[int] | None = None) -> str:
        """Persist the compiled instruction stream + specs/plans + DSE
        verdict as JSON, so :meth:`from_program` can rebuild this
        accelerator without re-running the DSE. Params are NOT saved (they
        are the model's weights — supply them at load time).

        ``aot=True`` writes a **bundle directory** instead of a single
        file: ``program.json`` (the same document) plus ``aot/`` holding
        one serialized XLA executable per warmed entry — every serving
        ``bucket`` with input donation (the :class:`ServingSession` hot
        path; defaults to the session's power-of-two buckets up to
        ``self.batch``) and the direct-call entry at ``self.batch``. A
        bundle loaded by :meth:`from_program` serves its first request
        without tracing OR compiling; see ``repro.core.aot`` for the keying
        and fallback semantics."""
        if self.program is None:
            raise ValueError("segmented accelerators hold multiple Programs; "
                             "save_program supports the single-Program path")
        doc = {
            "format": PROGRAM_FORMAT,
            "target": (self.target if isinstance(self.target, str)
                       else getattr(self.target, "name", None)),
            "batch": self.batch,
            "specs": [_spec_to_dict(s) for s in self.specs],
            "plans": [dataclasses.asdict(cl.plan)
                      for cl in self.program.layers],
            "instructions": self.program.instruction_image().tolist(),
            "dse": None if self.dse is None else {
                "hw": _hw_to_dict(self.dse.hw),
                "layer_latencies": [float(v)
                                    for v in self.dse.layer_latencies],
                "total_latency": float(self.dse.total_latency),
                "candidates_searched": self.dse.candidates_searched,
            },
            # the quant sidecar rides ALONGSIDE the instruction stream (the
            # 128-bit words are untouched — int8 never changes the ISA);
            # its digest is bound to this schedule so a sidecar pasted from
            # a different calibration or program is rejected at load
            "quant": None if self.quant is None else {
                "sidecar": self.quant.to_dict(),
                "digest": self.quant.digest(self.program.schedule_key()),
            },
        }
        if not aot:
            with open(path, "w") as f:
                json.dump(doc, f)
            return path
        rt = self.runtime
        if rt is None or rt.strict:
            raise ValueError("aot=True needs the cached-executor runtime — "
                             "strict-interpreter accelerators have no "
                             "compiled executable to export")
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "program.json"), "w") as f:
            json.dump(doc, f)
        aot_dir = os.path.join(path, "aot")
        if buckets is None:
            buckets, b = [], 1
            while b < self.batch:
                buckets.append(b)
                b *= 2
            buckets.append(self.batch)
        in_shape = tuple(self.input_shape)
        dt = self.input_dtype
        for b in sorted({int(b) for b in buckets}):
            # the serving hot path: per-bucket executors donate their
            # staged input buffer
            rt.export_aot(aot_dir, (b, *in_shape), dt, donate_input=True)
        # the direct acc(x) path: batch-sized, no donation
        rt.export_aot(aot_dir, (self.batch, *in_shape), dt,
                      donate_input=False)
        return path

    @classmethod
    def from_program(cls, path: str, *, params: list | None = None,
                     strict: bool = False, cache=None, backend: str = "xla",
                     interpret: bool | None = None,
                     opt_level: int = 1) -> "Accelerator":
        """Rebuild an accelerator from :meth:`save_program` output — no DSE.

        The layer chain is recompiled from the saved specs/plans and the
        resulting stream is verified bit-exact against the saved instruction
        image; a mismatch (compiler/schedule drift) raises ``ValueError``
        rather than serving from a stream that was never validated.

        ``params`` is required: saved programs carry no weights, and
        silently substituting random ones would make a reloaded deployment
        serve garbage — pass ``api.random_params(specs, seed)`` explicitly
        if stand-in weights are what you want. ``backend``/``interpret``/
        ``opt_level`` select the PE implementation and lowering-optimizer
        level exactly as in :meth:`build` — the saved stream is agnostic to
        both, so one artifact deploys to every variant.

        ``path`` may also be an AOT bundle directory written by
        ``save_program(..., aot=True)``: the instruction image loads from
        its ``program.json`` and the runtime warm-starts executors from the
        serialized executables in ``aot/`` — skipping trace AND compile —
        whenever the full artifact key (including this host's device kind
        and jax version) matches; stale artifacts fall back to a fresh
        compile with the reason logged on ``repro.aot``.

        Malformed input — truncated/non-JSON file, unknown format version,
        instruction-stream mismatch, quant-sidecar digest bound to a
        different schedule — raises :class:`ProgramLoadError`.
        """
        if params is None:
            raise ValueError(
                "saved programs carry no weights — pass params=[...] "
                "(api.random_params(specs, seed) for stand-ins)")
        aot_dir = None
        doc_path = path
        if os.path.isdir(path):
            doc_path = os.path.join(path, "program.json")
            if not os.path.exists(doc_path):
                raise ProgramLoadError(
                    f"{path}: directory is not an AOT bundle — no "
                    f"program.json inside")
            d = os.path.join(path, "aot")
            aot_dir = d if os.path.isdir(d) else None
        try:
            with open(doc_path) as f:
                doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ProgramLoadError(
                f"{doc_path}: truncated or not JSON ({e}) — the save was "
                f"interrupted or the file corrupted in transit") from e
        if doc.get("format") != PROGRAM_FORMAT:
            raise ProgramLoadError(
                f"{doc_path}: not a {PROGRAM_FORMAT} file "
                f"(format={doc.get('format')!r})")
        specs = [_spec_from_dict(d) for d in doc["specs"]]
        plans = [LayerPlan(**d) for d in doc["plans"]]
        program = compile_network(specs, plans)
        image = np.asarray(doc["instructions"], np.uint32).reshape(-1, 4)
        if not np.array_equal(program.instruction_image(), image):
            raise ProgramLoadError(
                f"{doc_path}: saved instruction stream does not match its "
                f"recompilation (compiler or schedule drift) — re-run "
                f"Accelerator.build and save again")
        quant = None
        if doc.get("quant"):
            q = doc["quant"]
            quant = QuantSidecar.from_dict(q["sidecar"])
            if quant.digest(program.schedule_key()) != q.get("digest"):
                raise ProgramLoadError(
                    f"{doc_path}: quant sidecar digest does not match this "
                    f"program's schedule — the sidecar was edited or "
                    f"belongs to a different calibration/program; re-run "
                    f"Accelerator.build(dtype='int8') and save again")
            # accept either fp32 weights (quantized here, deterministically
            # — the sidecar fixes every scale) or pre-quantized int8 ones
            if np.asarray(params[0][0]).dtype != np.int8:
                params = quantize_params(specs, params, quant)
        dse = None
        if doc.get("dse"):
            d = doc["dse"]
            dse = DSEResult(hw=_hw_from_dict(d["hw"]), plans=plans,
                            layer_latencies=d["layer_latencies"],
                            total_latency=d["total_latency"],
                            candidates_searched=d["candidates_searched"])
        rt = HybridRuntime(program, strict=strict, cache=cache,
                           backend=backend, interpret=interpret,
                           opt_level=opt_level, quant=quant,
                           aot_dir=aot_dir)
        rt.load_params(params)
        if not strict:
            rt.cache.validate(program)
        return cls(specs=specs, plans=plans, params=params, request=rt.run,
                   target=doc.get("target"), batch=doc.get("batch", 1),
                   program=program, runtime=rt, dse=dse,
                   backend=backend, interpret=interpret,
                   opt_level=opt_level, quant=quant)

    # -- serving ------------------------------------------------------------
    def serve(self, **kwargs) -> "ServingSession":
        """Open a :class:`ServingSession` over this accelerator — a
        padding-bucketed request-batching queue (see the class docs).
        ``mesh="host"`` shards batches over all local devices."""
        if kwargs.get("mesh") == "host":
            from repro.launch.mesh import make_host_mesh
            kwargs["mesh"] = make_host_mesh()
        return ServingSession(self, **kwargs)


# ---------------------------------------------------------------------------
# Serving: the request-batching queue (NI-instances analog)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionStats:
    requests: int = 0        # requests completed
    batches: int = 0         # executor invocations
    padded_rows: int = 0     # zero rows added to reach a bucket size
    dispatched_rows: int = 0  # real (non-pad) rows sent to the device(s)
    # first-use cost per bucket, split by how the executor came to exist so
    # the AOT warm-start win is measurable: compile_ms counts buckets that
    # traced + XLA-compiled in this process (warmup or first use);
    # warm_load_ms counts buckets whose executable deserialized from an AOT
    # bundle (repro.core.aot) — disk read + load + first dispatch, no
    # compile. One bucket lands in exactly one of the two.
    compile_ms: float = 0.0
    warm_load_ms: float = 0.0
    # device id -> batches dispatched there. A sharded batch counts once on
    # EVERY device it spans; a single-device batch counts on its one device
    # — so the table reads as per-device occupancy of the fleet.
    device_batches: dict = dataclasses.field(default_factory=dict)
    # per-request latency samples (submit -> result ready), most recent
    # window only — enough for steady-state percentiles without unbounded
    # growth on a long-lived session. Appends (drain thread) and percentile
    # reads (any caller) share _lat_lock: sorting a deque the drain thread
    # is appending to would raise "deque mutated during iteration".
    latencies_ms: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))
    # per-request queue-wait samples (submit -> admitted into a dispatched
    # device batch) — the scheduler-health metric: continuous batching keeps
    # this bounded by the batching window even under backpressure
    waits_ms: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=4096))
    _lat_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    def record_latency(self, ms: float):
        with self._lat_lock:
            self.latencies_ms.append(ms)

    def record_latencies(self, ms_list):
        """Batch append — one lock acquisition per device batch, not per
        request (the drain thread calls this on the completion hot path)."""
        with self._lat_lock:
            self.latencies_ms.extend(ms_list)

    def record_waits(self, ms_list):
        with self._lat_lock:
            self.waits_ms.extend(ms_list)

    def _pct(self, xs_deque, q: float) -> float:
        with self._lat_lock:
            xs = sorted(xs_deque)
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def p50_ms(self) -> float:
        """Median request latency over the recent window."""
        return self._pct(self.latencies_ms, 0.50)

    def p95_ms(self) -> float:
        """95th-percentile request latency over the recent window."""
        return self._pct(self.latencies_ms, 0.95)

    def wait_p50_ms(self) -> float:
        """Median queue wait (submit -> dispatch) over the recent window."""
        return self._pct(self.waits_ms, 0.50)

    def wait_p95_ms(self) -> float:
        """95th-percentile queue wait over the recent window."""
        return self._pct(self.waits_ms, 0.95)

    def occupancy(self) -> float:
        """Real-row fraction of all dispatched device rows (1.0 = no
        padding waste). The continuous-batching scheduler's win over fixed
        buckets on bursty traffic shows up here first."""
        total = self.dispatched_rows + self.padded_rows
        return self.dispatched_rows / total if total else 1.0


class _SlotPool:
    """FIFO-fair counting semaphore over device-pipeline slots.

    Each :class:`ServingSession` bounds its outstanding device batches with
    one of these (the classic triple buffer: one syncing, one executing,
    one staged). A :class:`Fleet` shares ONE pool across every tenant
    session, so device time round-robins between models: dispatch workers
    queue FIFO for the next free slot, and a model that just dispatched
    re-queues behind its peers — the paper's NI-instances arbitration,
    host-side.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("slot pool capacity must be >= 1")
        self.capacity = int(capacity)
        self._free = self.capacity
        self._cv = threading.Condition()
        self._waiters: deque = deque()
        self._subscribers: list[threading.Condition] = []

    def subscribe(self, cv: threading.Condition):
        """Register a condition to notify on every release — session
        admitters sleep on their own ``_cv`` while the pipeline is full, so
        a freed slot must wake them there."""
        with self._cv:
            self._subscribers.append(cv)

    def available(self) -> bool:
        """Lock-free hint (admission heuristics only, never correctness)."""
        return self._free > 0

    def busy(self) -> bool:
        """Lock-free hint: any slot taken — the device (pool-wide, across a
        Fleet's tenants) still has dispatched work in flight."""
        return self._free < self.capacity

    def acquire(self):
        token = object()
        with self._cv:
            self._waiters.append(token)
            while self._free <= 0 or self._waiters[0] is not token:
                self._cv.wait()
            self._waiters.popleft()
            self._free -= 1
            if self._free > 0:
                self._cv.notify_all()   # next waiter in line may also go

    def release(self):
        with self._cv:
            self._free += 1
            self._cv.notify_all()
        for cv in self._subscribers:
            with cv:
                cv.notify_all()


class ServingSession:
    """Padding-bucketed request-batching queue over the cached executor,
    with pipelined dispatch.

    Callers ``submit()`` single items (H, W, C) or small batches
    (n, H, W, C) and get a ``Future``; a dispatch worker coalesces pending
    requests into device batches of at most ``max_batch`` items, pads each
    batch up to the nearest size in ``buckets`` (so the jit cache holds one
    executor per bucket instead of one per observed batch size), runs the
    accelerator's cached executor directly (no per-request DRAM dict work),
    and scatters the rows back to the futures in submission order.

    The hot path is **pipelined**, the software analog of the paper's
    LOAD/COMP/SAVE overlap: the dispatch worker launches device batch i+1
    while batch i is still in flight (JAX dispatch is asynchronous), and a
    separate drain thread blocks on completed batches and resolves their
    futures — the host-side numpy staging of one batch overlaps the device
    compute of the previous one. Staging uses two preallocated numpy
    buffers per bucket, reused alternately; a buffer is free for refill as
    soon as its batch is dispatched, because ``jnp.asarray`` copies
    host->device. Outstanding device batches are hard-capped at 3 (one
    being synced by the drain thread, one executing, one freshly staged —
    triple buffering), so the session never runs unboundedly ahead of the
    device. Per-bucket executors donate their input buffer (the staged
    device array is never reused), so steady-state batches allocate no
    fresh activation input.

    The session inherits the accelerator's PE ``backend`` and lowering
    ``opt_level``: per-bucket executors are fetched through
    ``HybridRuntime.executor_entry``, which keys the program cache on
    ``(schedule, bucket, dtype, backend, interpret, opt_level, donate,
    mesh)`` — an ``Accelerator.build(..., backend="pallas")`` session
    serves every request through the Pallas PE kernels.

    ``mesh``: a ``jax.sharding.Mesh`` — device batches whose bucket size is
    a multiple of the device count run through the **shard_map'd executor
    variant** (batch axis split over every mesh axis, weights replicated
    once at session start), the paper's NI-instances analog. Because each
    shard replays the whole per-shard program locally, this works for
    ``backend="pallas"`` too — GSPMD can't split the custom call, but
    inside the mapped region there is nothing left to split. Straggler
    buckets that don't divide by the device count fall back to the
    single-device executor, so both entry families coexist in one cache.

    ``scheduler`` selects the admission policy:

    * ``"continuous"`` (default) — continuous batching: the admitter fills
      the next in-flight device batch straight from the pending queue. The
      batching window (``max_wait_ms``) only caps the wait while a device
      slot is FREE; while the pipeline is full the admitter keeps admitting
      into the open batch instead of cutting it (dispatch is impossible
      anyway), so batches grow to fill devices under backpressure and
      padding collapses on bursty traffic.
    * ``"bucketed"`` — the legacy fixed-window policy: cut the batch when
      the window expires regardless of pipeline state, pad up to the
      bucket. Kept as the reference the scheduler tests compare against.

    ``stats`` records, besides request/batch counts, the trace+compile
    time spent on warmup and first-use buckets (``compile_ms``), recent
    windows of per-request submit-to-result latency (``p50_ms()`` /
    ``p95_ms()``) and queue wait (``wait_p50_ms()``), per-device batch
    counts (``device_batches``) and padding ``occupancy()``.

    ``slot_pool`` shares the device-pipeline slots with other sessions — a
    :class:`Fleet` passes one pool to every tenant model so device slots
    round-robin between them; standalone sessions get a private pool of 3.
    """

    SCHEDULERS = ("continuous", "bucketed")

    def __init__(self, acc: Accelerator, *, max_batch: int = 8,
                 buckets: Sequence[int] | None = None, mesh=None,
                 max_wait_ms: float = 5.0, warmup: bool = False,
                 scheduler: str = "continuous",
                 slot_pool: _SlotPool | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if scheduler not in self.SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}: expected "
                             f"one of {self.SCHEDULERS}")
        self.acc = acc
        self.scheduler = scheduler
        self.max_batch = int(max_batch)
        if buckets is None:
            buckets, b = [], 1
            while b < self.max_batch:
                buckets.append(b)
                b *= 2
            buckets.append(self.max_batch)
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        if self.buckets[-1] < self.max_batch or self.buckets[0] < 1:
            raise ValueError(
                f"buckets {self.buckets} must cover max_batch={max_batch}")
        self.stats = SessionStats()
        # resolve once: input_dtype/input_shape are properties that walk
        # the param tree — too costly to re-derive on every submit()
        self._in_dtype = np.dtype(acc.input_dtype)
        self._in_shape = tuple(acc.input_shape)
        # quantized accelerators keep the session float-in/float-out:
        # floats are quantized host-side at staging (so the device batch is
        # int8 end to end) and int8 logits dequantized at drain
        self._quant = acc.quant
        self._single_rank = len(self._in_shape)
        self._max_wait = max(0.0, max_wait_ms) / 1e3
        self._pending: deque = deque()
        self._cv = threading.Condition()
        self._closed = False

        # hot path: one cached executor entry per bucket (validated once,
        # lowered once per bucket), donating the staged input buffer.
        # Falls back to acc(x) for segmented / strict accelerators.
        self._entries: dict[int, Any] = {}
        self._sharded_entries: dict[int, Any] = {}
        self._params = None
        self._params_sharded = None
        rt = acc.runtime
        if rt is not None and not rt.strict:
            # donation is best-effort (see the module-level warnings filter).
            # With an AOT bundle the deserialize happens HERE, inside
            # executor_entry -> cache.get — count it as warm-load time so
            # the stats line shows where the cold start went
            for b in self.buckets:
                t0 = time.monotonic()
                self._entries[b], self._params = rt.executor_entry(
                    b, acc.input_dtype, donate_input=True)
                if getattr(self._entries[b], "aot_loaded", False):
                    self.stats.warm_load_ms += (time.monotonic() - t0) * 1e3

        self._mesh = mesh
        self._n_devices = 1
        self._fleet_device_ids: tuple[int, ...] = (
            int(jax.devices()[0].id),)      # where unsharded batches land
        self._local_device_ids = self._fleet_device_ids
        if mesh is not None:
            self._n_devices = int(np.prod(mesh.devices.shape))
            if self._n_devices > 1 and self._params is None:
                # refuse rather than silently serve unsharded: sharding
                # needs the direct executor-entry hot path
                raise ValueError(
                    "mesh sharding requires the single-Program cached "
                    "executor path — segmented/strict accelerators can't "
                    "shard over the mesh")
            if self._n_devices > 1:
                # sharded executor variants for every bucket the mesh
                # divides evenly; stragglers keep the single-device entries.
                # Works for backend="pallas" too: each shard runs the whole
                # per-shard program locally under shard_map, so there is no
                # custom call left for GSPMD to split.
                for b in self.buckets:
                    if b % self._n_devices == 0:
                        self._sharded_entries[b], _ = rt.executor_entry(
                            b, acc.input_dtype, donate_input=True, mesh=mesh)
                if not self._sharded_entries:
                    raise ValueError(
                        f"no bucket in {self.buckets} divides evenly over "
                        f"the mesh's {self._n_devices} devices — sharded "
                        f"serving would never engage")
                # weights replicated once at session start; the separate
                # unsharded copy stays for straggler buckets (a replicated
                # array handed to the single-device jit would reshard on
                # every call)
                self._params_sharded = jax.device_put(
                    self._params,
                    jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
                self._fleet_device_ids = tuple(
                    int(d.id) for d in mesh.devices.flat)
                self._local_device_ids = (self._fleet_device_ids[0],)

        # completion pipeline: dispatched-but-unresolved batches, FIFO.
        # The slot pool bounds every outstanding device batch — the one the
        # drain thread is syncing, one executing, and one freshly staged —
        # the classic triple-buffer pipeline. The drainer holds its slot
        # until the host sync completes, so this is a hard device-memory
        # cap, not a soft target. A Fleet passes one shared pool so its
        # tenant models round-robin the same slots.
        self._inflight: deque = deque()
        self._inflight_cv = threading.Condition()
        # serializes staging+dispatch between the worker thread and
        # run_many's inline bulk path (both cycle the staging ring)
        self._dispatch_mutex = threading.Lock()
        self._slots = slot_pool if slot_pool is not None else _SlotPool(3)
        self._slots.subscribe(self._cv)   # full-pipeline admitters sleep
                                          # on _cv; wake them on slot free

        # host staging: a ring of numpy buffers per bucket, one per pipeline
        # slot, cycled per dispatch. The ring size MUST be >= the slot
        # capacity: a buffer is only refilled once its batch's slot has been
        # released (drained), so even if jax's CPU device_put zero-copies an
        # aligned host buffer instead of copying, no refill can race an
        # in-flight execution still reading it. (Two buffers against a
        # 3-deep pipeline let batch i+2 clobber batch i's input mid-run —
        # observed as rare wrong-row outputs under load.)
        self._staging = {
            b: [np.empty((b, *acc.input_shape),
                         np.dtype(acc.input_dtype))
                for _ in range(self._slots.capacity)]
            for b in self.buckets}
        self._staging_flip: dict[int, int] = {b: 0 for b in self.buckets}
        # run_many's inline bulk path gets its OWN ring: the worker and the
        # bulk path each release slots FIFO within themselves but interleave
        # arbitrarily across threads, so a shared ring could refill a buffer
        # whose batch is still in flight on the other path (lazily built —
        # most sessions never bulk-run every bucket)
        self._staging_bulk: dict[int, list] = {}
        self._bulk_flip: dict[int, int] = {}

        self._warm: set[int] = set()
        if warmup:   # pre-trace every bucket so first requests don't stall
            with _expected_donation_noise():
                for b in self.buckets:
                    z = jnp.zeros((b, *acc.input_shape), acc.input_dtype)
                    t0 = time.monotonic()
                    jax.block_until_ready(self._run_bucket(z))
                    self._count_first_use(b, t0)
                    self._warm.add(b)

        self._dispatch_thread = threading.Thread(
            target=self._worker, daemon=True, name="hybriddnn-serving")
        self._drain_thread = threading.Thread(
            target=self._drainer, daemon=True, name="hybriddnn-serving-drain")
        self._dispatch_thread.start()
        self._drain_thread.start()

    # -- client side --------------------------------------------------------
    def _stage(self, x) -> tuple[np.ndarray, bool]:
        """Validate + host-stage one request (no jax dispatch, no locks)."""
        x = np.asarray(x)
        if self._quant is not None and np.issubdtype(x.dtype, np.floating):
            # round-and-clip by the calibrated input scale — a bare dtype
            # cast would TRUNCATE floats toward zero and skip the clip
            x = np.clip(
                np.round(x.astype(np.float32)
                         / np.float32(self._quant.input_scale)),
                -127, 127).astype(self._in_dtype)
        else:
            x = np.asarray(x, self._in_dtype)
        if x.ndim == self._single_rank:
            x, single = x[None], True
        elif x.ndim == self._single_rank + 1:
            single = False
        else:
            raise ValueError(
                f"request rank {x.ndim} does not match input shape "
                f"{self._in_shape} (+ optional batch dim)")
        if not 1 <= x.shape[0] <= self.max_batch:
            raise ValueError(
                f"request batch {x.shape[0]} must be between 1 and "
                f"max_batch={self.max_batch}")
        if tuple(x.shape[1:]) != self._in_shape:
            # reject here, not in the worker: a malformed item would fail
            # the batch assembly and poison every co-batched request
            raise ValueError(
                f"request item shape {tuple(x.shape[1:])} does not match "
                f"the accelerator input shape {self.acc.input_shape}")
        return x, single

    def submit(self, x) -> Future:
        """Enqueue one request; returns a Future of the result (a single
        item's logits for single-item requests, a batch for batched ones).

        The request is staged host-side (numpy): no jax dispatch happens on
        the caller's thread — the dispatch worker launches one device call
        per coalesced bucket."""
        x, single = self._stage(x)
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("ServingSession is closed")
            self._pending.append((x, single, fut, time.monotonic()))
            self._cv.notify()
        return fut

    def submit_many(self, xs) -> list[Future]:
        """Enqueue a whole request list under ONE lock acquisition.

        Per-request ``submit`` wakes the dispatch worker once per call —
        for a burst of hundreds of already-materialized requests that lock
        traffic alone costs more than a device batch. Validation happens
        before anything enqueues, so a malformed request poisons nothing.
        """
        staged = [self._stage(x) for x in xs]
        futs: list[Future] = [Future() for _ in staged]
        now = time.monotonic()
        with self._cv:
            if self._closed:
                raise RuntimeError("ServingSession is closed")
            for (x, single), fut in zip(staged, futs):
                self._pending.append((x, single, fut, now))
            self._cv.notify()
        return futs

    def __call__(self, x):
        """Synchronous convenience: submit + wait."""
        return self.submit(x).result()

    def run_many(self, xs) -> list:
        """Run a whole request list; returns results in request order.

        Bulk traffic takes an inline pipelined path: the calling thread
        stages and dispatches full device batches itself (same executor
        entries, same slot pool, same stats), keeping up to the pool's
        capacity in flight and syncing oldest-first. Skipping the
        worker/drain thread handoff matters on small hosts: two context
        switches per ~5ms batch is a few percent of throughput — the
        difference between beating the caller-batched direct loop and
        trailing it. Concurrent ``submit()`` traffic stays correct (the
        dispatch mutex serializes staging; the shared slot pool keeps
        device arbitration FIFO-fair), it just isn't co-batched with the
        bulk run."""
        staged = [self._stage(x) for x in xs]
        if not staged:
            return []
        with self._cv:
            if self._closed:
                raise RuntimeError("ServingSession is closed")
        # cut [start, end) item groups of <= max_batch rows
        groups, start, n = [], 0, 0
        for i, (x, _) in enumerate(staged):
            k = x.shape[0]
            if n + k > self.max_batch:
                groups.append((start, i, n))
                start, n = i, 0
            n += k
        groups.append((start, len(staged), n))
        out: list = [None] * len(staged)
        inflight: deque = deque()   # (start, end, y)

        def _sync_oldest():
            s0, e0, y = inflight.popleft()
            try:
                y_np = self._to_host(y)          # host sync (+ dequant)
            finally:
                self._slots.release()
            done_t = time.monotonic()
            self.stats.batches += 1
            self.stats.requests += e0 - s0
            self.stats.record_latencies(
                [(done_t - t0) * 1e3] * (e0 - s0))
            off = 0
            for j in range(s0, e0):
                xj, single = staged[j]
                k = xj.shape[0]
                out[j] = y_np[off] if single else y_np[off:off + k]
                off += k

        t0 = time.monotonic()
        try:
            for s0, e0, n in groups:
                if len(inflight) >= self._slots.capacity:
                    _sync_oldest()   # never self-deadlock on the pool
                self._slots.acquire()
                try:
                    with self._dispatch_mutex:
                        y = self._dispatch_group(
                            [(x, single, None, t0)
                             for x, single in staged[s0:e0]], n, bulk=True)
                except BaseException:
                    self._slots.release()
                    raise
                inflight.append((s0, e0, y))
        finally:
            err = None
            while inflight:     # release EVERY held slot even on error
                try:
                    _sync_oldest()
                except Exception as e:  # noqa: BLE001 — keep draining
                    err = err or e
            if err is not None:
                raise err
        return out

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._dispatch_thread.join()     # drains pending, enqueues sentinel
        self._drain_thread.join()        # resolves every in-flight batch

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- dispatch side ------------------------------------------------------
    def _take_group(self):
        """Admit pending requests into one device batch (<= max_batch).

        ``"bucketed"``: the legacy fixed window — cut when ``max_wait_ms``
        expires, whatever the pipeline is doing. ``"continuous"``: the
        window only caps the wait while the device pipeline is IDLE — while
        any batch is still in flight, cutting a partial group early buys
        nothing (it would only queue behind the in-flight work) and wastes
        device time on padding, so the admitter keeps folding arrivals into
        the open batch until the pipeline drains or the batch fills. A hard
        cap (several windows) bounds the hold so a co-tenant model that
        keeps the shared slot pool busy can never starve a straggler —
        past it the group is cut and padded like the legacy path. The
        drainer wakes us (via the slot pool's subscriber hook) the moment a
        slot frees; the short wait below is only a backstop against a
        missed wakeup.
        """
        continuous = self.scheduler == "continuous"
        with self._cv:
            while not self._pending and not self._closed:
                self._cv.wait()
            if not self._pending:
                return None, 0           # closed and drained
            group, n = [], 0
            deadline = time.monotonic() + self._max_wait
            hard_deadline = deadline + 8 * self._max_wait
            while True:
                while (self._pending
                       and n + self._pending[0][0].shape[0] <= self.max_batch):
                    group.append(self._pending.popleft())
                    n += group[-1][0].shape[0]
                if n >= self.max_batch or self._pending or self._closed:
                    break                # full, head won't fit, or draining
                if (continuous and self._slots.busy()
                        and time.monotonic() < hard_deadline):
                    self._cv.wait(0.005)     # device busy: keep admitting
                    continue
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    break                # batching window expired
                self._cv.wait(timeout)
            return group, n

    def _to_host(self, y) -> np.ndarray:
        """Host-sync one device batch; dequantize int8 logits to fp32.

        Dequantization is gated on the ARRAY dtype, not just the session:
        the ``acc(x)`` fallback path (segmented/strict accelerators)
        already returns dequantized fp32, and rescaling it twice would
        corrupt every co-batched result."""
        y_np = np.asarray(y)
        if self._quant is not None and y_np.dtype == np.int8:
            return (y_np.astype(np.float32)
                    * np.float32(self._quant.output_scale))
        return y_np

    def _run_bucket(self, x):
        b = x.shape[0]
        entry = self._sharded_entries.get(b)
        if entry is not None:
            return entry(self._params_sharded, x)
        entry = self._entries.get(b)
        if entry is not None:
            return entry(self._params, x)
        return self.acc(x)

    def _dispatch_group(self, group, n, *, bulk: bool = False):
        """Stage one device batch and launch it — no host sync.

        Assembly is numpy into a preallocated staging ring (one buffer per
        pipeline slot — see ``__init__``): per-op jax dispatch dominates at
        this granularity (8 expand_dims + concat + 8 slices per batch), so
        the queue would otherwise run slower than the direct loop it exists
        to beat. Returns the in-flight device result; the drain thread
        syncs it.
        """
        bucket = next(b for b in self.buckets if b >= n)
        if bulk:
            ring = self._staging_bulk.get(bucket)
            if ring is None:
                ring = self._staging_bulk[bucket] = [
                    np.empty_like(self._staging[bucket][0])
                    for _ in range(self._slots.capacity)]
                self._bulk_flip[bucket] = 0
            flips = self._bulk_flip
        else:
            ring, flips = self._staging[bucket], self._staging_flip
        buf = ring[flips[bucket]]
        flips[bucket] = (flips[bucket] + 1) % len(ring)
        off = 0
        for xi, _, _, _ in group:
            buf[off:off + xi.shape[0]] = xi
            off += xi.shape[0]
        if bucket > n:
            buf[n:] = 0
            self.stats.padded_rows += bucket - n
        self.stats.dispatched_rows += n
        now = time.monotonic()
        self.stats.record_waits([(now - t) * 1e3 for _, _, _, t in group])
        dev_ids = (self._fleet_device_ids
                   if bucket in self._sharded_entries
                   else self._local_device_ids)
        for d in dev_ids:
            self.stats.device_batches[d] = \
                self.stats.device_batches.get(d, 0) + 1
        first_use = bucket not in self._warm
        t0 = time.monotonic()
        # the staging ring guarantees this buffer is not refilled until its
        # slot drains, so jnp.asarray may copy OR zero-copy-alias it safely
        if first_use:
            with _expected_donation_noise():   # compile happens in this call
                y = self._run_bucket(jnp.asarray(buf))
            self._count_first_use(bucket, t0)
            self._warm.add(bucket)
        else:
            y = self._run_bucket(jnp.asarray(buf))
        return y

    def _count_first_use(self, bucket: int, t0: float):
        """Attribute a bucket's first-use stall to ``warm_load_ms`` when its
        executor deserialized from an AOT bundle (no compile happened —
        this is the warm-start cost), to ``compile_ms`` otherwise. Sharded
        entries always compile in-process (AOT binaries would pin one
        host's device ids), so they count as compile."""
        dt = (time.monotonic() - t0) * 1e3
        entry = (None if bucket in self._sharded_entries
                 else self._entries.get(bucket))
        if getattr(entry, "aot_loaded", False):
            self.stats.warm_load_ms += dt
        else:
            self.stats.compile_ms += dt

    def _worker(self):
        """Dispatch loop: batch i+1 is staged and launched while batch i is
        still executing on the device (the drain thread owns completion)."""
        while True:
            group, n = self._take_group()
            if group is None:
                with self._inflight_cv:       # closed: wake the drain thread
                    self._inflight.append(None)
                    self._inflight_cv.notify_all()
                return
            # acquire the pipeline slot BEFORE launching, so at most
            # pool-capacity device batches are ever outstanding — across
            # the whole Fleet when the pool is shared
            self._slots.acquire()
            try:
                with self._dispatch_mutex:
                    y = self._dispatch_group(group, n)
            except Exception as e:  # noqa: BLE001 — surface via the futures
                self._slots.release()         # never entered the pipeline
                self._fail_group(group, e)
                continue
            with self._inflight_cv:
                self._inflight.append((group, y))
                self._inflight_cv.notify_all()

    # -- completion side ----------------------------------------------------
    def _drainer(self):
        """Completion loop: block on the oldest in-flight batch, scatter its
        rows back to the futures in submission order. The batch is PEEKED,
        synced, and only then released — releasing the dispatch slot before
        the host sync would let a third batch launch (and its staging
        buffer be refilled) while this one may still be executing, breaking
        the documented in-flight bound of the slot pool."""
        while True:
            with self._inflight_cv:
                while not self._inflight:
                    self._inflight_cv.wait()
                item = self._inflight[0]         # peek: slot stays occupied
            if item is None:
                return
            group, y = item
            exc = None
            try:
                y_np = self._to_host(y)  # the one host sync per batch
                                         # (+ dequant for int8 sessions)
            except Exception as e:  # noqa: BLE001 — device error surfaces here
                exc = e
            with self._inflight_cv:
                self._inflight.popleft()         # only this thread pops
                self._inflight_cv.notify_all()
            self._slots.release()                # batch done: free the slot
            if exc is not None:
                self._fail_group(group, exc)
                continue
            # count the batch BEFORE resolving futures: callers blocked on
            # result() read stats as soon as the last future fires
            self.stats.batches += 1
            self.stats.requests += len(group)
            done_t = time.monotonic()
            self.stats.record_latencies(
                [(done_t - t) * 1e3 for _, _, _, t in group])
            off = 0
            for xi, single, fut, _ in group:
                k = xi.shape[0]
                try:
                    fut.set_result(y_np[off] if single else y_np[off:off + k])
                except InvalidStateError:
                    pass    # caller cancelled mid-flight; drop only their rows
                off += k

    @staticmethod
    def _fail_group(group, e):
        for _, _, fut, _ in group:
            try:
                if not fut.done():
                    fut.set_exception(e)
            except InvalidStateError:
                pass    # cancelled in the done()/set race


# ---------------------------------------------------------------------------
# Fleet: multi-model tenancy over one process / one device pool
# ---------------------------------------------------------------------------

class Fleet:
    """Several :class:`Accelerator` models served from ONE process over one
    device pool — the paper's NI-instances analog taken to a rack.

    Each model gets its own :class:`ServingSession` (own pending queue, own
    staging buffers, own stats), but every session shares:

    * **one device-slot pool** — the in-flight pipeline slots are a single
      FIFO-fair pool, so device time round-robins between tenant models
      instead of one model's burst starving the rest;
    * **one program cache** — accelerators built against the process-global
      ``core.program_cache.default_cache()`` (the default) land their
      executors side by side in it, keyed by schedule/backend/mesh, so two
      models never recompile each other's entries away by identity;
    * **one mesh** (optional) — full buckets of every model shard over the
      same devices via the shard_map'd executor variant.

    ::

        fleet = api.Fleet({"vgg16": acc_vgg, "resnet18": acc_res},
                          mesh="host", max_batch=8)
        fut = fleet.submit("resnet18", x)       # routed to that model
        y = fleet("vgg16", x)                   # submit + wait

    Per-model outputs are bitwise-stable under tenancy: a model's requests
    run through exactly the cached executor entries its standalone session
    would use — co-tenancy only changes *when* a batch gets a device slot,
    never what it computes (asserted in ``tests/test_fleet_serving.py``).
    """

    def __init__(self, accelerators, *, mesh=None, max_batch: int = 8,
                 buckets: Sequence[int] | None = None,
                 max_wait_ms: float = 5.0, warmup: bool = False,
                 scheduler: str = "continuous", max_inflight: int = 3):
        items = dict(accelerators)
        if not items:
            raise ValueError("Fleet needs at least one named Accelerator")
        if mesh == "host":
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.mesh = mesh
        self._pool = _SlotPool(max_inflight)
        self.sessions: dict[str, ServingSession] = {}
        for name, acc in items.items():
            self.sessions[name] = ServingSession(
                acc, max_batch=max_batch, buckets=buckets, mesh=mesh,
                max_wait_ms=max_wait_ms, warmup=warmup, scheduler=scheduler,
                slot_pool=self._pool)

    @property
    def models(self) -> tuple[str, ...]:
        return tuple(self.sessions)

    def _session(self, model: str) -> ServingSession:
        try:
            return self.sessions[model]
        except KeyError:
            raise ValueError(f"unknown model {model!r}: fleet serves "
                             f"{sorted(self.sessions)}") from None

    def submit(self, model: str, x) -> Future:
        """Enqueue one request for ``model``; returns its Future."""
        return self._session(model).submit(x)

    def __call__(self, model: str, x):
        """Synchronous convenience: submit + wait."""
        return self.submit(model, x).result()

    def run_many(self, requests) -> list:
        """``requests``: iterable of ``(model, x)`` pairs. Every request is
        submitted first — so co-tenant models contend for device slots the
        way live traffic would — then gathered in submission order."""
        pairs = [(m, x) for m, x in requests]
        by_model: dict[str, list] = {}
        for m, x in pairs:
            by_model.setdefault(m, []).append(x)
        futs_by_model = {m: iter(self._session(m).submit_many(xs))
                         for m, xs in by_model.items()}
        futs = [next(futs_by_model[m]) for m, _ in pairs]
        return [f.result() for f in futs]

    def stats(self) -> dict[str, SessionStats]:
        """Per-model :class:`SessionStats`, keyed by model name."""
        return {name: s.stats for name, s in self.sessions.items()}

    def close(self):
        for s in self.sessions.values():
            s.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

