"""The hybrid Spatial/Winograd convolution engine (Sec. 4.2).

One engine, two CONV modes, two dataflows — the paper's PE, as a composable
JAX module. ``use_pallas=True`` routes through the Pallas TPU kernels
(kernels/gemm + kernels/winograd + kernels/spatial_conv); ``use_pallas=False``
uses mathematically identical XLA-partitionable paths so the same layer can
live inside a pjit-sharded model (GSPMD cannot split an opaque custom call —
on real hardware the Pallas path is wrapped in shard_map, see
parallel/sharding.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import winograd as wino
from repro.kernels.spatial_conv import spatial_conv2d
from repro.kernels.winograd import winograd_conv2d

Mode = Literal["spat", "wino"]
Dataflow = Literal["is", "ws"]


def same_pad(size: int, k: int, stride: int) -> tuple[int, int]:
    """XLA/TF "SAME" padding for one spatial dim: ``(pad_lo, pad_hi)``.

    The rule is stride-aware — ``total = (ceil(size/stride) - 1) * stride
    + k - size``, low half rounded DOWN — so for an even input under
    stride 2 the padding is asymmetric (e.g. h=32, r=3, stride=2 gives
    (0, 1), NOT the stride-1 rule's (1, 1)). Every place that re-derives
    the conv halo (executor row slicing, compiler LOAD_INP sizing) must
    use this helper, or strided layers shift by a pixel against the
    ``lax.conv_general_dilated`` numerics.
    """
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return total // 2, total - total // 2


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static description of one CONV layer (the DSE/compiler currency).

    ``inp_from`` reroutes the layer's input: it is the absolute index (in
    the network spec list) of the layer whose OUTPUT this conv reads, or -1
    for the network input; ``None`` (the default) reads the previous layer
    as usual. ResNet projection shortcuts need this — the 1x1 downsample
    conv reads the block INPUT, not the main path's last output.
    """
    name: str
    h: int                  # input spatial height
    w: int
    c: int                  # input channels
    k: int                  # output channels
    r: int = 3              # kernel height
    s: int = 3              # kernel width
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True
    inp_from: int | None = None

    @property
    def out_hw(self) -> tuple[int, int]:
        if self.padding.upper() == "SAME":
            return (-(-self.h // self.stride), -(-self.w // self.stride))
        return ((self.h - self.r) // self.stride + 1,
                (self.w - self.s) // self.stride + 1)

    @property
    def macs(self) -> int:
        ho, wo = self.out_hw
        return self.k * self.c * self.r * self.s * ho * wo

    def wino_eligible(self, m: int = 4) -> bool:
        """Winograd mode requires stride 1 AND an implemented F(m, r)
        transform: the transform set covers m in {2, 4} with r == s == 3
        (paper Sec. 4.2.1/5.1), so a 1x1 projection or 5x5 kernel must take
        the spatial mode in the compiled stack."""
        return (self.stride == 1 and m in wino.SUPPORTED_M
                and self.r == wino.R_WINO and self.s == wino.R_WINO)


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Static description of one max-pooling layer (POOL opcode currency)."""
    name: str
    h: int                  # input spatial height
    w: int
    c: int                  # channels (pooling is depthwise)
    window: int = 2
    stride: int = 2

    @property
    def out_hw(self) -> tuple[int, int]:
        # VALID pooling, the VGG16 convention
        return ((self.h - self.window) // self.stride + 1,
                (self.w - self.window) // self.stride + 1)

    @property
    def macs(self) -> int:
        return 0            # comparisons, not MACs — excluded from GOPS


@dataclasses.dataclass(frozen=True)
class EltwiseSpec:
    """Static description of one residual element-wise add (ELTWISE_ADD).

    ``skip_from`` is the absolute index (in the network spec list) of the
    layer whose OUTPUT is the skip operand, or -1 for the network input.
    The primary operand is — as for every layer — the previous layer's
    output. The compiler's DRAM planner keeps the skip tensor live from its
    producer to this add.
    """
    name: str
    h: int                  # operand spatial height
    w: int
    c: int                  # operand channels (both sources match)
    skip_from: int = -1
    relu: bool = True

    @property
    def out_hw(self) -> tuple[int, int]:
        return (self.h, self.w)

    @property
    def macs(self) -> int:
        return 0            # adds, not MACs — excluded from GOPS


@dataclasses.dataclass(frozen=True)
class DepthwiseSpec:
    """Static description of one depthwise CONV layer (DEPTHWISE_CONV).

    One (r, s) filter per channel — HWIO kernel shaped (r, s, 1, c) with
    ``feature_group_count = c`` — so k == c by construction.
    """
    name: str
    h: int                  # input spatial height
    w: int
    c: int                  # channels (output channels == c)
    r: int = 3
    s: int = 3
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True

    @property
    def out_hw(self) -> tuple[int, int]:
        if self.padding.upper() == "SAME":
            return (-(-self.h // self.stride), -(-self.w // self.stride))
        return ((self.h - self.r) // self.stride + 1,
                (self.w - self.s) // self.stride + 1)

    @property
    def macs(self) -> int:
        ho, wo = self.out_hw
        return self.c * self.r * self.s * ho * wo


@dataclasses.dataclass(frozen=True)
class FCSpec:
    """Static description of one fully-connected layer (FC opcode currency)."""
    name: str
    d_in: int
    d_out: int
    relu: bool = False

    @property
    def macs(self) -> int:
        return self.d_in * self.d_out


def hybrid_conv2d(
    x_nhwc: jax.Array,
    g_rsck: jax.Array,
    bias: jax.Array | None = None,
    *,
    mode: Mode = "spat",
    m: int = 4,
    dataflow: Dataflow = "is",
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    use_pallas: bool = True,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """Run one convolution on the hybrid PE in the requested mode."""
    out_dtype = out_dtype or x_nhwc.dtype
    if not use_pallas:
        # the XLA paths are dataflow-oblivious and never interpret-mode; a
        # non-default value here would be silently ignored (same contract as
        # vgg.forward's interpret= check)
        if dataflow != "is":
            raise ValueError(
                f"dataflow={dataflow!r} has no effect with use_pallas=False "
                f"(the XLA lowering is dataflow-oblivious); pass "
                f"use_pallas=True or drop dataflow=")
        if interpret is not None:
            raise ValueError(
                "interpret= only affects the Pallas kernels; pass "
                "use_pallas=True or drop interpret=")
    if mode == "wino":
        if stride != 1:
            raise ValueError("Winograd mode requires stride 1")
        if use_pallas:
            return winograd_conv2d(
                x_nhwc, g_rsck, bias, m=m, padding=padding, relu=relu,
                dataflow=dataflow, out_dtype=out_dtype, interpret=interpret)
        y = wino.winograd_conv2d_reference(
            x_nhwc, g_rsck, m=m, padding=padding, out_dtype=jnp.float32)
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        return y.astype(out_dtype)
    elif mode == "spat":
        if use_pallas:
            return spatial_conv2d(
                x_nhwc, g_rsck, bias, stride=stride, padding=padding,
                relu=relu, dataflow=dataflow, out_dtype=out_dtype,
                interpret=interpret)
        y = lax.conv_general_dilated(
            x_nhwc.astype(jnp.float32), g_rsck.astype(jnp.float32),
            (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        return y.astype(out_dtype)
    raise ValueError(f"unknown mode {mode!r}")


def depthwise_conv2d(
    x_nhwc: jax.Array,
    g_rs1c: jax.Array,
    bias: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Depthwise convolution: one (r, s) filter per channel.

    Kernel is HWIO shaped (r, s, 1, c) with ``feature_group_count = c``.
    Like POOL, depthwise conv is element-parallel VPU work, not an MXU GEMM
    — it lowers through the same XLA op on both backends rather than the
    Pallas GEMM PE (see docs/ARCHITECTURE.md).
    """
    out_dtype = out_dtype or x_nhwc.dtype
    r, s, one, c = g_rs1c.shape
    if one != 1 or c != x_nhwc.shape[-1]:
        raise ValueError(
            f"depthwise kernel must be (r, s, 1, C={x_nhwc.shape[-1]}), "
            f"got {g_rs1c.shape}")
    y = lax.conv_general_dilated(
        x_nhwc.astype(jnp.float32), g_rs1c.astype(jnp.float32),
        (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(out_dtype)


def max_pool2d(x_nhwc: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    # the init value must be a scalar OF THE OPERAND DTYPE — a raw Python
    # int makes reduce_window raise "inconsistent dtypes" on integer inputs
    init = jnp.asarray(
        -jnp.inf if jnp.issubdtype(x_nhwc.dtype, jnp.floating)
        else jnp.iinfo(x_nhwc.dtype).min, x_nhwc.dtype)
    return lax.reduce_window(
        x_nhwc, init, lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")


def dense(x: jax.Array, w_ck: jax.Array, bias: jax.Array | None = None,
          relu: bool = False, use_pallas: bool = False,
          interpret: bool | None = None) -> jax.Array:
    """FC layer; routes through the shared GEMM PE when use_pallas."""
    if not use_pallas and interpret is not None:
        raise ValueError(
            "interpret= only affects the Pallas GEMM; pass use_pallas=True "
            "or drop interpret=")
    if use_pallas:
        from repro.kernels.gemm import matmul
        y = matmul(x, w_ck, out_dtype=jnp.float32, interpret=interpret)
    else:
        y = jnp.dot(x.astype(jnp.float32), w_ck.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
