"""The hybrid Spatial/Winograd convolution engine (Sec. 4.2).

One engine, two CONV modes, two dataflows — the paper's PE, as a composable
JAX module. ``use_pallas=True`` routes through the Pallas TPU kernels
(kernels/gemm + kernels/winograd + kernels/spatial_conv); ``use_pallas=False``
uses mathematically identical XLA-partitionable paths so the same layer can
live inside a pjit-sharded model (GSPMD cannot split an opaque custom call —
on real hardware the Pallas path is wrapped in shard_map, see
parallel/sharding.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import winograd as wino
from repro.kernels.spatial_conv import spatial_conv2d
from repro.kernels.winograd import winograd_conv2d

Mode = Literal["spat", "wino"]
Dataflow = Literal["is", "ws"]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static description of one CONV layer (the DSE/compiler currency)."""
    name: str
    h: int                  # input spatial height
    w: int
    c: int                  # input channels
    k: int                  # output channels
    r: int = 3              # kernel height
    s: int = 3              # kernel width
    stride: int = 1
    padding: str = "SAME"
    relu: bool = True

    @property
    def out_hw(self) -> tuple[int, int]:
        if self.padding.upper() == "SAME":
            return (-(-self.h // self.stride), -(-self.w // self.stride))
        return ((self.h - self.r) // self.stride + 1,
                (self.w - self.s) // self.stride + 1)

    @property
    def macs(self) -> int:
        ho, wo = self.out_hw
        return self.k * self.c * self.r * self.s * ho * wo

    def wino_eligible(self, m: int = 4) -> bool:
        """Winograd mode requires stride 1 (paper Sec. 4.2.1)."""
        return self.stride == 1 and self.r >= 1 and self.s >= 1


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Static description of one max-pooling layer (POOL opcode currency)."""
    name: str
    h: int                  # input spatial height
    w: int
    c: int                  # channels (pooling is depthwise)
    window: int = 2
    stride: int = 2

    @property
    def out_hw(self) -> tuple[int, int]:
        # VALID pooling, the VGG16 convention
        return ((self.h - self.window) // self.stride + 1,
                (self.w - self.window) // self.stride + 1)

    @property
    def macs(self) -> int:
        return 0            # comparisons, not MACs — excluded from GOPS


@dataclasses.dataclass(frozen=True)
class FCSpec:
    """Static description of one fully-connected layer (FC opcode currency)."""
    name: str
    d_in: int
    d_out: int
    relu: bool = False

    @property
    def macs(self) -> int:
        return self.d_in * self.d_out


def hybrid_conv2d(
    x_nhwc: jax.Array,
    g_rsck: jax.Array,
    bias: jax.Array | None = None,
    *,
    mode: Mode = "spat",
    m: int = 4,
    dataflow: Dataflow = "is",
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    use_pallas: bool = True,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """Run one convolution on the hybrid PE in the requested mode."""
    out_dtype = out_dtype or x_nhwc.dtype
    if mode == "wino":
        if stride != 1:
            raise ValueError("Winograd mode requires stride 1")
        if use_pallas:
            return winograd_conv2d(
                x_nhwc, g_rsck, bias, m=m, padding=padding, relu=relu,
                dataflow=dataflow, out_dtype=out_dtype, interpret=interpret)
        y = wino.winograd_conv2d_reference(
            x_nhwc, g_rsck, m=m, padding=padding, out_dtype=jnp.float32)
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        return y.astype(out_dtype)
    elif mode == "spat":
        if use_pallas:
            return spatial_conv2d(
                x_nhwc, g_rsck, bias, stride=stride, padding=padding,
                relu=relu, dataflow=dataflow, out_dtype=out_dtype,
                interpret=interpret)
        y = lax.conv_general_dilated(
            x_nhwc.astype(jnp.float32), g_rsck.astype(jnp.float32),
            (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        return y.astype(out_dtype)
    raise ValueError(f"unknown mode {mode!r}")


def max_pool2d(x_nhwc: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    init = jnp.array(-jnp.inf, x_nhwc.dtype) if jnp.issubdtype(
        x_nhwc.dtype, jnp.floating) else jnp.iinfo(x_nhwc.dtype).min
    return lax.reduce_window(
        x_nhwc, init, lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")


def dense(x: jax.Array, w_ck: jax.Array, bias: jax.Array | None = None,
          relu: bool = False, use_pallas: bool = False,
          interpret: bool | None = None) -> jax.Array:
    """FC layer; routes through the shared GEMM PE when use_pallas."""
    if use_pallas:
        from repro.kernels.gemm import matmul
        y = matmul(x, w_ck, out_dtype=jnp.float32, interpret=interpret)
    else:
        y = jnp.dot(x.astype(jnp.float32), w_ck.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
