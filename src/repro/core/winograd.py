"""Winograd fast convolution: transforms, GEMM formulation, kernel decomposition.

Implements the paper's Sec. 4.2.1: an ``F(m x m, r x r)`` Winograd algorithm
computes an ``m x m`` output tile from an ``(m+r-1) x (m+r-1)`` input tile as

    Y = A^T [ (G g G^T) .* (B^T d B) ] A                              (Eq. 1)

and, summed over input channels, the element-wise products split into
``PT^2 = (m+r-1)^2`` *independent GEMMs* (Eq. 2):

    M[p, t, k] = sum_c V[p, t, c] * U[p, c, k]       p in [0, PT^2)

which is exactly a batched matmul with leading batch PT^2 — the paper's
PT x PT array of GEMM cores, our ``kernels/gemm`` leading grid axis.

Supported: F(2x2, 3x3) (PT=4) and F(4x4, 3x3) (PT=6), matching the paper's
``PT in {4, 6}`` constraint (Sec. 5.1). Larger kernels are handled by the
paper's kernel-decomposition method (Sec. 4.2.5): an R x S kernel is split
into ceil(R/r) x ceil(S/r) zero-padded r x r kernels whose partial outputs
accumulate at shifted offsets.

Layout conventions: feature maps NHWC, kernels HWIO (R, S, C, K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

R_WINO = 3  # the paper's Winograd algorithms are F(m, 3)


# ---------------------------------------------------------------------------
# Transform matrices (Lavin & Gray, "Fast Algorithms for Convolutional NNs")
# ---------------------------------------------------------------------------

_F2_BT = np.array(
    [[1, 0, -1, 0],
     [0, 1, 1, 0],
     [0, -1, 1, 0],
     [0, 1, 0, -1]], dtype=np.float64)
_F2_G = np.array(
    [[1, 0, 0],
     [0.5, 0.5, 0.5],
     [0.5, -0.5, 0.5],
     [0, 0, 1]], dtype=np.float64)
_F2_AT = np.array(
    [[1, 1, 1, 0],
     [0, 1, -1, -1]], dtype=np.float64)

_F4_BT = np.array(
    [[4, 0, -5, 0, 1, 0],
     [0, -4, -4, 1, 1, 0],
     [0, 4, -4, -1, 1, 0],
     [0, -2, -1, 2, 1, 0],
     [0, 2, -1, -2, 1, 0],
     [0, 4, 0, -5, 0, 1]], dtype=np.float64)
_F4_G = np.array(
    [[1 / 4, 0, 0],
     [-1 / 6, -1 / 6, -1 / 6],
     [-1 / 6, 1 / 6, -1 / 6],
     [1 / 24, 1 / 12, 1 / 6],
     [1 / 24, -1 / 12, 1 / 6],
     [0, 0, 1]], dtype=np.float64)
_F4_AT = np.array(
    [[1, 1, 1, 1, 1, 0],
     [0, 1, -1, 2, -2, 0],
     [0, 1, 1, 4, 4, 0],
     [0, 1, -1, 8, -8, 1]], dtype=np.float64)

_MATRICES = {2: (_F2_BT, _F2_G, _F2_AT), 4: (_F4_BT, _F4_G, _F4_AT)}

# the implemented F(m, 3) transform set — the DSE's eligibility source of
# truth (ConvSpec.wino_eligible)
SUPPORTED_M = tuple(sorted(_MATRICES))


@functools.lru_cache(None)
def transform_matrices(m: int, dtype=jnp.float32):
    """Return (B^T, G, A^T) for F(m x m, 3 x 3). PT = m + r - 1.

    Cached as NUMPY arrays (trace-safe: jnp values created inside a jit
    trace would leak tracers through the lru_cache)."""
    if m not in _MATRICES:
        raise ValueError(f"F({m},{R_WINO}) unsupported; PT must be in {{4, 6}} (m in {{2, 4}})")
    bt, g, at = _MATRICES[m]
    return (np.asarray(bt, dtype), np.asarray(g, dtype), np.asarray(at, dtype))


def pt_for(m: int) -> int:
    """Input tile size PT = m + r - 1."""
    return m + R_WINO - 1


def mult_reduction(m: int, r: int = R_WINO) -> float:
    """Multiplication reduction of F(m,r) vs direct conv: (m*r)^2 / (m+r-1)^2.

    Paper example: F(4x4,3x3) needs 36 mults/tile vs 144 direct -> 4.0x.
    """
    return float((m * r) ** 2) / float((m + r - 1) ** 2)


# ---------------------------------------------------------------------------
# Weight transform (offline, Sec. 4.2.3: "offline transformation from
# pretrained DNN models")
# ---------------------------------------------------------------------------

def transform_weights(g_rsck: jax.Array, m: int) -> jax.Array:
    """U = G g G^T per (c, k): (r, r, C, K) -> (PT, PT, C, K)."""
    r, s, c, k = g_rsck.shape
    assert r == R_WINO and s == R_WINO, f"use decompose_kernel for {r}x{s}"
    _, gm, _ = transform_matrices(m, jnp.float32)
    g32 = g_rsck.astype(jnp.float32)
    u = jnp.einsum("ir,rsck,js->ijck", gm, g32, gm)
    return u.astype(g_rsck.dtype)


def decompose_kernel(g_rsck: jax.Array, m: int):
    """Paper Sec. 4.2.5 kernel decomposition for R, S > r.

    Splits an (R, S, C, K) kernel into ceil(R/r) x ceil(S/r) zero-padded
    (r, r, C, K) sub-kernels. Returns a list of (offset_h, offset_w, subkernel)
    where offsets are the input-shift at which the sub-kernel's partial conv
    output accumulates.
    """
    r = R_WINO
    rr, ss, c, k = g_rsck.shape
    nh, nw = -(-rr // r), -(-ss // r)
    pads = ((0, nh * r - rr), (0, nw * r - ss), (0, 0), (0, 0))
    gp = jnp.pad(g_rsck, pads)
    out = []
    for i in range(nh):
        for j in range(nw):
            sub = gp[i * r:(i + 1) * r, j * r:(j + 1) * r]
            out.append((i * r, j * r, sub))
    return out


# ---------------------------------------------------------------------------
# Input tiling / transform and output transform (pure-jnp reference forms;
# the Pallas fast path lives in kernels/winograd)
# ---------------------------------------------------------------------------

def tile_input(x_nhwc: jax.Array, m: int) -> tuple[jax.Array, tuple[int, int]]:
    """Partition NHWC input into overlapping PT x PT tiles with stride m.

    Input is assumed already padded for the convolution itself (i.e. a VALID
    conv of the padded input yields the desired output). Returns
    ``(tiles, (nh, nw))`` with tiles shaped (N, nh, nw, PT, PT, C); adjacent
    tiles share an (r-1)-pixel overlap, exactly the paper's partitioning.
    """
    pt = pt_for(m)
    n, h, w, c = x_nhwc.shape
    ho, wo = h - R_WINO + 1, w - R_WINO + 1  # VALID conv output size
    nh, nw = -(-ho // m), -(-wo // m)
    # pad so the tile grid covers the full output
    hp, wp = (nh - 1) * m + pt, (nw - 1) * m + pt
    x = jnp.pad(x_nhwc, ((0, 0), (0, hp - h), (0, wp - w), (0, 0)))
    # gather tiles: strided window extraction
    idx_h = (jnp.arange(nh) * m)[:, None] + jnp.arange(pt)[None, :]   # (nh, PT)
    idx_w = (jnp.arange(nw) * m)[:, None] + jnp.arange(pt)[None, :]   # (nw, PT)
    tiles = x[:, idx_h]                # (N, nh, PT, Wp, C)
    tiles = tiles[:, :, :, idx_w]      # (N, nh, PT, nw, PT, C)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5)  # (N, nh, nw, PT, PT, C)
    return tiles, (nh, nw)


def transform_input(tiles: jax.Array, m: int) -> jax.Array:
    """V = B^T d B: (N, nh, nw, PT, PT, C) -> (PT*PT, N*nh*nw, C)."""
    bt, _, _ = transform_matrices(m, jnp.float32)
    n, nh, nw, pt, _, c = tiles.shape
    v = jnp.einsum("ip,xpqc,jq->xijc", bt, tiles.reshape(-1, pt, pt, c).astype(jnp.float32), bt)
    v = v.reshape(n * nh * nw, pt * pt, c).transpose(1, 0, 2)
    return v


def transform_output(m_ptsq: jax.Array, m: int, n: int, nh: int, nw: int,
                     out_dtype=jnp.float32) -> jax.Array:
    """Y = A^T M A: (PT*PT, N*nh*nw, K) -> (N, nh*m, nw*m, K)."""
    _, _, at = transform_matrices(m, jnp.float32)
    pt2, t, k = m_ptsq.shape
    pt = pt_for(m)
    mm = m_ptsq.transpose(1, 0, 2).reshape(t, pt, pt, k).astype(jnp.float32)
    y = jnp.einsum("ip,xpqk,jq->xijk", at, mm, at)  # (t, m, m, K)
    y = y.reshape(n, nh, nw, m, m, k).transpose(0, 1, 3, 2, 4, 5)
    y = y.reshape(n, nh * m, nw * m, k)
    return y.astype(out_dtype)


def winograd_apply_pretransformed(
    x_nhwc: jax.Array,
    u_ptck: jax.Array,      # (PT, PT, C, K) offline-transformed weights
    bias: jax.Array | None,
    m: int,
    relu: bool = False,
    padding: str = "SAME",
    out_dtype=None,
) -> jax.Array:
    """Winograd conv with weights already in U-space (r = 3, stride 1).

    This is the runtime's COMP path: the paper stores *transformed* weights in
    DRAM (Sec. 4.2.3), so the PE consumes U directly.
    """
    out_dtype = out_dtype or x_nhwc.dtype
    n, h, w, c = x_nhwc.shape
    pt, _, _, k = u_ptck.shape
    assert pt == pt_for(m), (pt, m)
    rr = R_WINO
    if padding.upper() == "SAME":
        ph = (rr - 1) // 2
        x = jnp.pad(x_nhwc, ((0, 0), (ph, rr - 1 - ph), (ph, rr - 1 - ph), (0, 0)))
    else:
        x = x_nhwc
    ho, wo = x.shape[1] - rr + 1, x.shape[2] - rr + 1
    tiles, (nh, nw) = tile_input(x, m)
    v = transform_input(tiles, m)                              # (PT^2, T, C)
    u = u_ptck.astype(jnp.float32).reshape(pt * pt, c, k)
    mm = jnp.einsum("ptc,pck->ptk", v, u)
    y = transform_output(mm, m, n, nh, nw)[:, :ho, :wo, :]
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(out_dtype)


def winograd_conv2d_reference(
    x_nhwc: jax.Array,
    g_rsck: jax.Array,
    m: int = 4,
    padding: str | tuple = "SAME",
    out_dtype=None,
) -> jax.Array:
    """End-to-end Winograd convolution (stride 1), pure jnp. Oracle + fallback.

    Handles R, S != 3 via the paper's kernel decomposition.
    """
    out_dtype = out_dtype or x_nhwc.dtype
    n, h, w, c = x_nhwc.shape
    rr, ss, _, k = g_rsck.shape

    if isinstance(padding, str):
        if padding.upper() == "SAME":
            ph, pw = (rr - 1) // 2, (ss - 1) // 2
            pad = ((ph, rr - 1 - ph), (pw, ss - 1 - pw))
        elif padding.upper() == "VALID":
            pad = ((0, 0), (0, 0))
        else:
            raise ValueError(padding)
    else:
        pad = padding
    x = jnp.pad(x_nhwc, ((0, 0), pad[0], pad[1], (0, 0)))
    ho = x.shape[1] - rr + 1
    wo = x.shape[2] - ss + 1

    if (rr, ss) == (R_WINO, R_WINO):
        pieces = [(0, 0, g_rsck)]
    else:
        pieces = decompose_kernel(g_rsck, m)
        # pad input so every shifted sub-conv sees a full window
        extra_h = (-(-rr // R_WINO)) * R_WINO - rr
        extra_w = (-(-ss // R_WINO)) * R_WINO - ss
        x = jnp.pad(x, ((0, 0), (0, extra_h), (0, extra_w), (0, 0)))

    acc = None
    for (oh, ow, sub) in pieces:
        xs = x[:, oh:oh + ho + R_WINO - 1, ow:ow + wo + R_WINO - 1, :]
        tiles, (nh, nw) = tile_input(xs, m)
        v = transform_input(tiles, m)                      # (PT^2, T, C)
        u = transform_weights(sub, m).astype(jnp.float32)  # (PT, PT, C, K)
        pt = pt_for(m)
        u = u.reshape(pt * pt, c, k)
        mm = jnp.einsum("ptc,pck->ptk", v, u)              # the PT^2 GEMMs
        y = transform_output(mm, m, n, nh, nw)             # (N, nh*m, nw*m, K)
        y = y[:, :ho, :wo, :]
        acc = y if acc is None else acc + y
    return acc.astype(out_dtype)
