"""AOT executor artifacts: ship the compiled executable, not the recipe.

``Accelerator.save_program(..., aot=True)`` serializes each warmed
executor's XLA executable (via ``jax.experimental.serialize_executable``)
into a bundle directory next to the instruction image;
``ProgramCache.get(..., aot_dir=...)`` loads it back on a cache miss,
skipping BOTH the jax trace and the XLA compile (~710 ms per bucket on the
dev container -> a few ms of deserialization). This is the software analog
of shipping a synthesized bitstream per design point instead of re-running
synthesis at every deploy.

Keying
------
Artifacts are keyed by the FULL program-cache key — schedule digest, batch,
dtype, per-layer param dtypes, backend, *resolved* Pallas interpret flag,
opt_level, input donation, mesh topology, quant-sidecar digest — PLUS the
environment fingerprint (device kind, platform, jax and jaxlib versions).
A serialized executable is a device-specific binary: drift in ANY dimension
makes it unusable or, worse, silently wrong, so the whole key dict is
hashed into the artifact filename and stored verbatim in a ``manifest.json``
side index.

Fallback semantics
------------------
A lookup that misses NEVER errors and NEVER serves a stale binary: the
caller falls back to the ordinary trace+compile path (bit-exact by
construction — the artifact was produced by compiling the very same lowered
function), and the *reason* — which key dimension went stale, with saved vs
wanted values — is logged on the ``repro.aot`` logger so an operator can
see why a warm start went cold. A manifest whose entry no longer matches
its own digest (hand-edited bundle) and an unreadable/truncated artifact
file fall back the same way.

Mesh-sharded executor variants are not exported (the artifact would pin
device ids of one host); sharded entries always take the fresh-compile
path and the single-device straggler entries still warm-load.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import pickle
import warnings

log = logging.getLogger("repro.aot")

AOT_FORMAT = "hybriddnn-aot/v1"
MANIFEST = "manifest.json"

# Test-only seam: the serving fault harness (repro.serving.faults) installs
# a hook here to exercise the warn-and-recompile path deterministically. The
# hook runs INSIDE load_entry's artifact try-block, so anything it raises is
# indistinguishable from a corrupt artifact on disk.
_fault_hook = None


def set_fault_hook(hook):
    """Install ``hook(digest)`` to run on every artifact read attempt;
    returns the previous hook so callers can restore it."""
    global _fault_hook
    prev, _fault_hook = _fault_hook, hook
    return prev

# the stale-diagnosis report walks these in order, so the most identity-like
# dimensions (schedule, environment) lead the logged reason
KEY_DIMENSIONS = (
    "format", "schedule", "batch", "dtype", "param_dtypes", "backend",
    "interpret", "opt_level", "donate_input", "mesh", "quant_digest",
    "device_kind", "platform", "jax_version", "jaxlib_version",
)


class AOTError(ValueError):
    """A malformed AOT bundle operation (bad save inputs, unwritable dir)."""


def environment_fingerprint() -> dict:
    """The environment dimensions of the artifact key.

    ``device_kind``/``platform`` because the serialized executable is a
    device binary; ``jax_version``/``jaxlib_version`` because the
    serialization format and the compiled calling convention both drift
    across releases. Computed fresh each call (cheap) so tests can
    monkeypatch it to simulate loading on a different device or version.
    """
    import jax
    dev = jax.devices()[0]
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:          # pragma: no cover - jaxlib rides with jax
        jaxlib_version = "unknown"
    return {
        "device_kind": str(dev.device_kind),
        "platform": str(dev.platform),
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
    }


def artifact_key(cache_key: tuple, env: dict | None = None) -> dict:
    """The full artifact key dict for one program-cache key tuple.

    ``cache_key`` is :func:`repro.core.program_cache.cache_key` output; the
    environment fingerprint joins it here. The dict is JSON-normalized
    (tuples become lists) so it digests and round-trips through the
    manifest identically.
    """
    (schedule, batch, dtype, param_dtypes, backend, interpret, opt_level,
     donate_input, mesh, quant_digest) = cache_key
    key = {
        "format": AOT_FORMAT,
        "schedule": schedule,
        "batch": int(batch),
        "dtype": str(dtype),
        "param_dtypes": list(param_dtypes),
        "backend": backend,
        "interpret": interpret,
        "opt_level": int(opt_level),
        "donate_input": bool(donate_input),
        "mesh": mesh,
        "quant_digest": quant_digest,
    }
    key.update(environment_fingerprint() if env is None else dict(env))
    return json.loads(json.dumps(key))


def artifact_digest(key: dict) -> str:
    """Content digest of an artifact key — the artifact's filename stem."""
    return hashlib.sha256(
        json.dumps(key, sort_keys=True).encode()).hexdigest()[:16]


def _artifact_path(aot_dir: str, digest: str) -> str:
    return os.path.join(aot_dir, f"{digest}.aotx")


def read_manifest(aot_dir: str) -> dict:
    """digest -> key dict for every artifact in ``aot_dir`` ({} if none)."""
    path = os.path.join(aot_dir, MANIFEST)
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, json.JSONDecodeError) as e:
        log.warning("aot: manifest %s unreadable (%s) — treating the "
                    "bundle as empty", path, e)
        return {}
    return doc if isinstance(doc, dict) else {}


def _write_manifest(aot_dir: str, manifest: dict):
    # tmp+rename: a crashed save must not leave a half-written index that
    # poisons every later load
    path = os.path.join(aot_dir, MANIFEST)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def _diff_dims(saved: dict, wanted: dict) -> list[tuple[str, object, object]]:
    """(dimension, saved, wanted) for every key dimension that differs."""
    dims = [d for d in KEY_DIMENSIONS if d in saved or d in wanted]
    for extra in sorted(set(saved) | set(wanted)):
        if extra not in dims:
            dims.append(extra)
    return [(d, saved.get(d), wanted.get(d)) for d in dims
            if saved.get(d) != wanted.get(d)]


def save_entry(aot_dir: str, executor, params, x_shape, dtype,
               cache_key: tuple, env: dict | None = None) -> str:
    """Compile ``executor.fn`` ahead-of-time at these shapes and persist the
    serialized executable; returns the artifact digest.

    ``executor`` is a :class:`repro.core.executor.CompiledExecutor`;
    ``params`` only contributes shapes/dtypes (weights are NOT stored — the
    artifact is the executable, the instruction image + params stay in
    their own files). Lowering against ``jax.ShapeDtypeStruct`` stand-ins
    means no device math runs at save time.
    """
    import jax
    import numpy as np
    from jax.experimental import serialize_executable

    if getattr(executor, "mesh_key", None) is not None:
        raise AOTError("mesh-sharded executors are not exportable: the "
                       "serialized binary would pin one host's device ids")
    key = artifact_key(cache_key, env)
    digest = artifact_digest(key)
    os.makedirs(aot_dir, exist_ok=True)
    p_struct = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    x_struct = jax.ShapeDtypeStruct(tuple(x_shape), np.dtype(dtype))
    with warnings.catch_warnings():
        # donated-buffer notes are expected for donate_input executors
        warnings.simplefilter("ignore", UserWarning)
        compiled = executor.fn.lower(p_struct, x_struct).compile()
    payload, in_tree, out_tree = serialize_executable.serialize(compiled)
    blob = pickle.dumps({"format": AOT_FORMAT, "key": key,
                         "payload": payload, "in_tree": in_tree,
                         "out_tree": out_tree})
    with open(_artifact_path(aot_dir, digest), "wb") as f:
        f.write(blob)
    manifest = read_manifest(aot_dir)
    manifest[digest] = key
    _write_manifest(aot_dir, manifest)
    log.info("aot: saved %s (%d KiB, batch=%s dtype=%s backend=%s "
             "opt_level=%s)", digest, len(blob) // 1024, key["batch"],
             key["dtype"], key["backend"], key["opt_level"])
    return digest


def load_entry(aot_dir: str, cache_key: tuple, env: dict | None = None):
    """The deserialized executable for this key, or ``None`` with the stale
    reason logged — the caller then falls back to a fresh trace+compile,
    which is bit-exact by construction."""
    from jax.experimental import serialize_executable

    wanted = artifact_key(cache_key, env)
    digest = artifact_digest(wanted)
    manifest = read_manifest(aot_dir)
    path = _artifact_path(aot_dir, digest)
    saved = manifest.get(digest)
    if saved is not None and os.path.exists(path):
        stale = _diff_dims(saved, wanted)
        if stale:
            # hand-edited manifest: its entry no longer matches the digest
            log.warning(
                "aot: artifact %s manifest entry does not match its own "
                "digest (%s) — falling back to fresh compile", digest,
                _fmt_diffs(stale))
            return None
        try:
            if _fault_hook is not None:
                _fault_hook(digest)
            with open(path, "rb") as f:
                blob = pickle.loads(f.read())
            if blob.get("format") != AOT_FORMAT:
                raise ValueError(f"format={blob.get('format')!r}")
            stale = _diff_dims(blob.get("key", {}), wanted)
            if stale:
                raise ValueError(f"embedded key mismatch: {_fmt_diffs(stale)}")
            fn = serialize_executable.deserialize_and_load(
                blob["payload"], blob["in_tree"], blob["out_tree"])
        except Exception as e:  # noqa: BLE001 — any bad artifact recompiles
            log.warning("aot: artifact %s unreadable (%s: %s) — falling "
                        "back to fresh compile", digest,
                        type(e).__name__, e)
            return None
        log.info("aot: loaded %s (batch=%s dtype=%s backend=%s "
                 "opt_level=%s)", digest, wanted["batch"], wanted["dtype"],
                 wanted["backend"], wanted["opt_level"])
        return fn
    if not manifest:
        log.info("aot: %s holds no artifacts — fresh compile", aot_dir)
        return None
    # diagnose WHICH dimension went stale: report the nearest saved key
    best_digest, best_diffs = None, None
    for d, saved in manifest.items():
        diffs = _diff_dims(saved, wanted)
        if best_diffs is None or len(diffs) < len(best_diffs):
            best_digest, best_diffs = d, diffs
    log.warning(
        "aot: no artifact for key %s — nearest saved artifact %s is stale "
        "on [%s]; falling back to fresh compile", digest, best_digest,
        _fmt_diffs(best_diffs or []))
    return None


def _fmt_diffs(diffs: list[tuple[str, object, object]]) -> str:
    return "; ".join(f"{d}: saved={s!r} wanted={w!r}" for d, s, w in diffs)
