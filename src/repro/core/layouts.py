"""Feature-map data layouts and the SAVE-side reorder transforms (Sec. 4.3).

The paper defines two external-memory layouts (Figure 5):

* ``SPAT`` — plain raster order. Here: NHWC.
* ``WINO`` — tile-position-major order so that the Winograd load manager can
  stream all tiles of one (tile-row, tile-col) position contiguously.
  Here: (N, nh, nw, m, m, C) — output tiles of size m x m laid out tile-major.

The SAVE module supports all four layout transforms (WINO-to-WINO,
WINO-to-SPAT, SPAT-to-SPAT, SPAT-to-WINO) so successive layers may run in
different CONV modes without a standalone reorder pass; the LOAD module only
ever performs identity loads. ``runtime.py`` enforces exactly this contract.

On TPU these transforms are XLA reshape/transposes — "free" when fused into
the neighboring op, which is the same effect the paper achieves by folding the
reorder into SAVE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SPAT = "spat"
WINO = "wino"


def _check_divisible(h: int, w: int, m: int):
    if h % m or w % m:
        raise ValueError(f"feature map {h}x{w} not divisible by tile size m={m}; "
                         "pad before converting to WINO layout")


def spat_to_wino(x_nhwc: jax.Array, m: int) -> jax.Array:
    """NHWC -> (N, H/m, W/m, m, m, C) tile-major WINO layout."""
    n, h, w, c = x_nhwc.shape
    _check_divisible(h, w, m)
    x = x_nhwc.reshape(n, h // m, m, w // m, m, c)
    return x.transpose(0, 1, 3, 2, 4, 5)


def wino_to_spat(x_tiled: jax.Array) -> jax.Array:
    """(N, nh, nw, m, m, C) -> NHWC."""
    n, nh, nw, m, m2, c = x_tiled.shape
    assert m == m2
    x = x_tiled.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(n, nh * m, nw * m, c)


def save_transform(y_nhwc: jax.Array, to_layout: str, m: int) -> jax.Array:
    """SAVE-side reorder: COMP always emits NHWC internally; SAVE writes the
    layout the *next* layer's mode wants (the paper's 4 transform modes)."""
    if to_layout == SPAT:
        return y_nhwc
    if to_layout == WINO:
        n, h, w, c = y_nhwc.shape
        ph, pw = (-h) % m, (-w) % m
        if ph or pw:
            y_nhwc = jnp.pad(y_nhwc, ((0, 0), (0, ph), (0, pw), (0, 0)))
        return spat_to_wino(y_nhwc, m)
    raise ValueError(to_layout)


def load_view(x: jax.Array, layout: str, hw: tuple[int, int] | None = None) -> jax.Array:
    """LOAD-side identity view back to NHWC for COMP.

    ``hw`` crops padding introduced by save_transform for non-divisible maps.
    """
    if layout == SPAT:
        return x
    if layout == WINO:
        y = wino_to_spat(x)
        if hw is not None:
            y = y[:, :hw[0], :hw[1], :]
        return y
    raise ValueError(layout)


def layout_for_mode(mode: str) -> str:
    """The layout a layer's LOAD manager wants given its CONV mode."""
    return WINO if mode == "wino" else SPAT
