"""Compiled-program cache: one jitted executor per
``(Program, batch, dtype, backend, opt_level, donate)``.

Keying rules
------------
The cache key is ``(program.schedule_key(), batch, dtype, param_dtypes,
backend, interpret, opt_level, donate_input)``:

* ``schedule_key()`` (see ``core/compiler.py``) is a content hash over the
  encoded 128-bit instruction stream plus the per-layer geometry (spec, plan,
  row/k groups, layouts). Two ``Program`` objects with identical schedules —
  e.g. recompiled from the same specs/plans — share one cache entry; any
  change to an instruction or a group boundary produces a new key.
* ``batch``, ``dtype`` and (when supplied) the per-layer weight dtypes pin
  the trace: jit would silently retrace on a new input shape/dtype or a
  changed param dtype, so they are part of the key to make (re)compilation
  an observable, counted event rather than a hidden stall.
* ``backend`` ("xla" | "pallas") and the *resolved* Pallas interpret flag
  join the key because they change the lowering itself — the same schedule
  lowered through the XLA ops and through the Pallas PE kernels are two
  different compiled artifacts. ``interpret=None`` is resolved (off-TPU ->
  interpret mode) *before* keying so an auto-selected fallback and an
  explicit ``interpret=True`` share one entry.
* ``opt_level`` (0 = literal per-block lowering, 1 = the lowering
  optimizer's fused/stacked forms — see ``core/executor.py``) joins the key
  for the same reason: the two levels are different compiled artifacts, and
  keeping both keyed lets the reference lowering serve side by side with
  the optimized one (the property tests rely on exactly this).
* ``donate_input`` joins the key because donation is part of the jitted
  function's signature — a donating executor invalidates the caller's
  input buffer, so it must never be handed to a caller that didn't ask.
* ``mesh`` (keyed by topology: shape, axis names and flat device ids — see
  ``executor.mesh_key``) selects the **sharded executor variant**: the
  lowered function wrapped in ``shard_map`` over the batch axis, so the
  Pallas PEs run per-shard inside the mapped region. ``None`` (the default)
  is the single-device executor; sharded and unsharded entries of one
  Program coexist side by side, which is what lets a serving session keep
  straggler buckets on one device while full buckets span the fleet.

Schedule validation runs **once per schedule key** (not per entry): executors
for new batch sizes of an already-validated program reuse the cached
validation stats. Entries are LRU-evicted beyond ``maxsize``; the validation
side table is bounded too — when the last executor entry of a schedule is
evicted its validation stats go with it, and the table itself is LRU-capped
at ``validated_maxsize`` so validate-only callers cannot grow it without
limit.

Full-network Programs (POOL/FC opcodes) need no special keying: the encoded
stream and per-layer geometry already cover the new layer kinds, so the key
rules are unchanged — a whole-model Program is just one more schedule key.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import jax.numpy as jnp

from repro.core.compiler import Program
from repro.core.executor import (
    CompiledExecutor,
    compile_executor,
    mesh_device_count,
    mesh_key,
    resolve_backend,
    resolve_opt_level,
    validate_schedule,
)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    validated_evictions: int = 0    # validation-stat entries dropped
    aot_loads: int = 0              # misses served from a disk artifact
    fallbacks: int = 0              # degraded-backend entry requests (the
                                    # serving layer's pallas->XLA recovery
                                    # path; hits AND misses both count)


def cache_key(program: Program, *, batch: int, dtype,
              param_dtypes: tuple = (), backend: str = "xla",
              interpret: bool | None = None, opt_level: int = 1,
              donate_input: bool = False, mesh=None, quant=None) -> tuple:
    """The cache-key tuple for one executor request, in resolved form.

    Pure and deterministic across processes for equal inputs: every
    component is either a content digest (``schedule_key``, the quant
    digest) or a resolved scalar — this is what lets the AOT artifact
    layer (``core/aot.py``) reuse the exact same identity on disk, and what
    the key-stability property tests pin down.
    """
    backend, interpret = resolve_backend(backend, interpret)
    opt_level = resolve_opt_level(opt_level)
    if mesh is not None and mesh_device_count(mesh) == 1:
        mesh = None
    return (program.schedule_key(), int(batch), jnp.dtype(dtype).name,
            tuple(param_dtypes), backend, interpret, opt_level,
            bool(donate_input), mesh_key(mesh),
            quant.digest() if quant is not None else None)


class ProgramCache:
    """LRU cache of :class:`CompiledExecutor` keyed by (schedule, batch, dtype)."""

    def __init__(self, maxsize: int = 64, validated_maxsize: int | None = None):
        self.maxsize = maxsize
        # the validation side table holds one small counters dict per
        # schedule; 4x the entry budget comfortably covers every schedule
        # with live entries plus validate-only callers, while still bounding
        # a pathological stream of distinct programs
        self.validated_maxsize = (4 * maxsize if validated_maxsize is None
                                  else validated_maxsize)
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, CompiledExecutor] = OrderedDict()
        self._validated: OrderedDict[str, dict[str, int]] = OrderedDict()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def validated_size(self) -> int:
        """Schedules with cached validation stats (bounded, see class docs)."""
        return len(self._validated)

    def validate(self, program: Program) -> dict[str, int]:
        """Hazard-check ``program`` once per schedule key; return counters."""
        key = program.schedule_key()
        with self._lock:
            stats = self._validated.get(key)
            if stats is not None:
                self._validated.move_to_end(key)
        if stats is None:
            stats = validate_schedule(program)   # raises HazardError
            with self._lock:
                self._validated[key] = stats
                self._validated.move_to_end(key)
                self._evict_validated_locked()
        return dict(stats)

    def _evict_validated_locked(self):
        """LRU-bound the validation side table; never drop a schedule that
        still has live executor entries (re-validating it would be wasted
        work and would skew the once-per-schedule contract)."""
        if len(self._validated) <= self.validated_maxsize:
            return
        live = {k[0] for k in self._entries}
        for skey in list(self._validated):
            if len(self._validated) <= self.validated_maxsize:
                break
            if skey in live:
                continue
            del self._validated[skey]
            self.stats.validated_evictions += 1

    def get(self, program: Program, *, batch: int, dtype,
            param_dtypes: tuple = (), backend: str = "xla",
            interpret: bool | None = None, opt_level: int = 1,
            donate_input: bool = False, mesh=None,
            quant=None, aot_dir: str | None = None,
            fallback: bool = False) -> CompiledExecutor:
        """The jitted executor for ``program`` at this
        batch/dtype/backend/opt_level/mesh (compile on miss).

        ``param_dtypes`` (one name per layer's weight) joins the key when
        weights may not share the input dtype — otherwise jit would silently
        retrace on the changed param dtypes behind a counted "hit".
        ``backend``/``interpret`` select the per-block PE lowering,
        ``opt_level`` the lowering-optimizer level, and ``donate_input``
        whether the executor donates the activation buffer (see
        ``core/executor.py``); all join the key in resolved form. ``mesh``
        requests the shard_map'd executor variant (batch axis split over
        every mesh axis, params replicated) keyed by mesh topology — the
        batch must divide evenly by the mesh's device count. ``quant`` (a
        ``repro.quant.QuantSidecar``) lowers through the int8 PE and joins
        the key by content digest — the int8 dtype alone is not enough,
        since two calibrations of one network bake different requantize
        multipliers into the trace.

        ``aot_dir`` names an AOT artifact bundle (``core/aot.py``): on a
        cache miss the serialized executable keyed by this exact request
        (plus the device/version fingerprint) is loaded from disk instead
        of re-traced and re-compiled; any stale or missing artifact falls
        back to the fresh compile with the reason logged on ``repro.aot``.
        Mesh-sharded variants never load from disk — their binaries would
        pin one host's device ids.

        ``fallback`` marks a graceful-degradation request (the serving
        layer re-keying a failed Pallas batch onto the XLA lowering).
        Degraded entries need no special treatment here — ``backend`` is
        already part of the key, so the healthy and fallback executors
        coexist — but the flag is counted (``stats.fallbacks``) so
        operators can see degradation traffic at the cache, not just per
        session.
        """
        if fallback:
            with self._lock:
                self.stats.fallbacks += 1
        backend, interpret = resolve_backend(backend, interpret)
        opt_level = resolve_opt_level(opt_level)
        # a 1-device mesh lowers identically to no mesh — normalize before
        # keying so the two spellings share one entry
        if mesh is not None and mesh_device_count(mesh) == 1:
            mesh = None
        n_dev = mesh_device_count(mesh)
        if n_dev > 1 and batch % n_dev:
            raise ValueError(
                f"sharded executor: batch {batch} does not divide evenly "
                f"over the mesh's {n_dev} devices — pad the batch to a "
                f"multiple (the serving session's bucket fallback) or drop "
                f"the mesh for this batch size")
        key = cache_key(program, batch=batch, dtype=dtype,
                        param_dtypes=param_dtypes, backend=backend,
                        interpret=interpret, opt_level=opt_level,
                        donate_input=donate_input, mesh=mesh, quant=quant)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return entry
        stats = self.validate(program)
        entry = None
        if aot_dir is not None and mesh is None:
            from repro.core import aot
            fn = aot.load_entry(aot_dir, key)
            if fn is not None:
                entry = CompiledExecutor(
                    program=program, stats=dict(stats), fn=fn,
                    _trace_count=[0], backend=backend, interpret=interpret,
                    opt_level=opt_level, donate_input=bool(donate_input),
                    mesh_key=None, aot_loaded=True)
                self.stats.aot_loads += 1
        if entry is None:
            entry = compile_executor(program, stats=stats, backend=backend,
                                     interpret=interpret, opt_level=opt_level,
                                     donate_input=donate_input, mesh=mesh,
                                     quant=quant)
        with self._lock:
            # re-check: a racing thread may have compiled the same key while
            # we were outside the lock — first insert wins so every caller
            # holds the same CompiledExecutor identity
            existing = self._entries.get(key)
            if existing is not None:
                self.stats.hits += 1
                return existing
            self._entries[key] = entry
            self.stats.misses += 1
            while len(self._entries) > self.maxsize:
                old_key, _ = self._entries.popitem(last=False)
                self.stats.evictions += 1
                # evict the schedule's validation stats alongside its last
                # executor entry — a dead schedule must not pin host memory
                skey = old_key[0]
                if (skey in self._validated
                        and not any(k[0] == skey for k in self._entries)):
                    del self._validated[skey]
                    self.stats.validated_evictions += 1
        return entry

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._validated.clear()
            self.stats = CacheStats()


_default = ProgramCache()


def default_cache() -> ProgramCache:
    """The process-wide cache used by ``HybridRuntime`` unless one is passed."""
    return _default
