"""Compiled-program cache: one jitted executor per
``(Program, batch, dtype, backend)``.

Keying rules
------------
The cache key is ``(program.schedule_key(), batch, dtype, param_dtypes,
backend, interpret)``:

* ``schedule_key()`` (see ``core/compiler.py``) is a content hash over the
  encoded 128-bit instruction stream plus the per-layer geometry (spec, plan,
  row/k groups, layouts). Two ``Program`` objects with identical schedules —
  e.g. recompiled from the same specs/plans — share one cache entry; any
  change to an instruction or a group boundary produces a new key.
* ``batch``, ``dtype`` and (when supplied) the per-layer weight dtypes pin
  the trace: jit would silently retrace on a new input shape/dtype or a
  changed param dtype, so they are part of the key to make (re)compilation
  an observable, counted event rather than a hidden stall.
* ``backend`` ("xla" | "pallas") and the *resolved* Pallas interpret flag
  join the key because they change the lowering itself — the same schedule
  lowered through the XLA ops and through the Pallas PE kernels are two
  different compiled artifacts. ``interpret=None`` is resolved (off-TPU ->
  interpret mode) *before* keying so an auto-selected fallback and an
  explicit ``interpret=True`` share one entry.

Schedule validation runs **once per schedule key** (not per entry): executors
for new batch sizes of an already-validated program reuse the cached
validation stats. Entries are LRU-evicted beyond ``maxsize``.

Full-network Programs (POOL/FC opcodes) need no special keying: the encoded
stream and per-layer geometry already cover the new layer kinds, so the key
rules are unchanged — a whole-model Program is just one more schedule key.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import jax.numpy as jnp

from repro.core.compiler import Program
from repro.core.executor import (
    CompiledExecutor,
    compile_executor,
    resolve_backend,
    validate_schedule,
)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0


class ProgramCache:
    """LRU cache of :class:`CompiledExecutor` keyed by (schedule, batch, dtype)."""

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, CompiledExecutor] = OrderedDict()
        self._validated: dict[str, dict[str, int]] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._entries)

    def validate(self, program: Program) -> dict[str, int]:
        """Hazard-check ``program`` once per schedule key; return counters."""
        key = program.schedule_key()
        with self._lock:
            stats = self._validated.get(key)
        if stats is None:
            stats = validate_schedule(program)   # raises HazardError
            with self._lock:
                self._validated[key] = stats
        return dict(stats)

    def get(self, program: Program, *, batch: int, dtype,
            param_dtypes: tuple = (), backend: str = "xla",
            interpret: bool | None = None) -> CompiledExecutor:
        """The jitted executor for ``program`` at this batch/dtype/backend
        (compile on miss).

        ``param_dtypes`` (one name per layer's weight) joins the key when
        weights may not share the input dtype — otherwise jit would silently
        retrace on the changed param dtypes behind a counted "hit".
        ``backend``/``interpret`` select the per-block PE lowering (see
        ``core/executor.py``) and join the key in resolved form.
        """
        backend, interpret = resolve_backend(backend, interpret)
        key = (program.schedule_key(), int(batch), jnp.dtype(dtype).name,
               tuple(param_dtypes), backend, interpret)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return entry
        stats = self.validate(program)
        entry = compile_executor(program, stats=stats, backend=backend,
                                 interpret=interpret)
        with self._lock:
            # re-check: a racing thread may have compiled the same key while
            # we were outside the lock — first insert wins so every caller
            # holds the same CompiledExecutor identity
            existing = self._entries.get(key)
            if existing is not None:
                self.stats.hits += 1
                return existing
            self._entries[key] = entry
            self.stats.misses += 1
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        return entry

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._validated.clear()
            self.stats = CacheStats()


_default = ProgramCache()


def default_cache() -> ProgramCache:
    """The process-wide cache used by ``HybridRuntime`` unless one is passed."""
    return _default
