"""The 128-bit customized instruction set (Sec. 4.1, Figure 2), full-network.

Nine opcodes — LOAD_INP, LOAD_WGT, LOAD_BIAS, COMP, SAVE, POOL, FC,
ELTWISE_ADD, DEPTHWISE_CONV — each encoded in 128 bits (four little-endian
uint32 words). Every instruction carries a WINO_FLAG indicating the current
CONV mode; LOAD/SAVE instructions carry BUFF_BASE / DRAM_BASE so the compiler
fully controls data movement and can realize IS or WS dataflow purely in the
instruction stream (Sec. 4.2.4). POOL and FC extend the CONV ISA so a whole
model — CONVs, interleaved maxpools, and the FC classifier tail — compiles
into ONE instruction stream (one ``Program``), with no host-side glue between
layers. ELTWISE_ADD and DEPTHWISE_CONV extend it beyond straight-line VGG
chains: residual (skip-connection) adds with TWO DRAM source operands kept
live by the compiler's planner, and depthwise convolutions.

Bit layout (word:bit, little-endian within the 128-bit word):

  word0: [ 3:0]  OPCODE        [4] WINO_FLAG      [5] DATAFLOW (0=IS,1=WS)
         [6]    LAYOUT_OUT (SAVE: 0=SPAT,1=WINO)  [7] RELU_FLAG
         [15:8] M_TILE (Winograd m) — POOL reuses this byte as
                [11:8] POOL_WINDOW, [15:12] POOL_STRIDE
         [31:16] LAYER_ID
  word1: BUFF_BASE  (32b on-chip buffer word address / ping-pong slot;
                     ELTWISE_ADD: [0] primary slot, [1] skip slot)
  word2: DRAM_BASE  (32b external-memory word address; ELTWISE_ADD: the
                     skip operand's DRAM base — the second source is named
                     in the compute word so the two-source read is explicit
                     in the stream, not implied by load order)
  word3: SIZE       (32b transfer size in words; COMP: group index;
                     FC: [15:0] D_IN, [31:16] D_OUT — see pack_fc_dims;
                     ELTWISE_ADD: element count of each source operand;
                     DEPTHWISE_CONV: [7:0] R, [15:8] S, [23:16] STRIDE —
                     see pack_dw_geom)

The two LOAD_INPs feeding an ELTWISE_ADD use the ordinary ping-pong slot
tags: the primary operand loads into slot 0 (buff_base bit0 = 0) and the
skip operand into slot 1 (buff_base bit0 = 1), so the hazard discipline that
guards CONV row groups guards residual adds unchanged.

Opcode values 0 and 10..15 are reserved: ``decode`` rejects them with a
``ValueError`` naming the offending word. The encode/decode pair is
bit-exact and round-trip tested (hypothesis).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class Opcode(enum.IntEnum):
    LOAD_INP = 1
    LOAD_WGT = 2
    LOAD_BIAS = 3
    COMP = 4
    SAVE = 5
    POOL = 6
    FC = 7
    ELTWISE_ADD = 8
    DEPTHWISE_CONV = 9


def pack_fc_dims(d_in: int, d_out: int) -> int:
    """FC word3: [15:0] input dim, [31:16] output dim."""
    if not (0 <= d_in < 1 << 16 and 0 <= d_out < 1 << 16):
        raise ValueError(f"FC dims ({d_in}, {d_out}) exceed 16 bits")
    return d_in | (d_out << 16)


def unpack_fc_dims(size: int) -> tuple[int, int]:
    return size & 0xFFFF, (size >> 16) & 0xFFFF


def pack_dw_geom(r: int, s: int, stride: int) -> int:
    """DEPTHWISE_CONV word3: [7:0] R, [15:8] S, [23:16] STRIDE."""
    if not (0 < r < 1 << 8 and 0 < s < 1 << 8 and 0 < stride < 1 << 8):
        raise ValueError(
            f"depthwise geometry ({r}, {s}, stride={stride}) must be "
            f"positive 8-bit values")
    return r | (s << 8) | (stride << 16)


def unpack_dw_geom(size: int) -> tuple[int, int, int]:
    return size & 0xFF, (size >> 8) & 0xFF, (size >> 16) & 0xFF


@dataclasses.dataclass(frozen=True)
class Instruction:
    opcode: Opcode
    wino_flag: bool = False          # current CONV mode
    dataflow_ws: bool = False        # 0 = IS, 1 = WS
    layout_out_wino: bool = False    # SAVE: layout written for the next layer
    relu_flag: bool = False
    m_tile: int = 0                  # Winograd output tile size m (0 for SPAT)
    pool_window: int = 0             # POOL only: window (word0 [11:8])
    pool_stride: int = 0             # POOL only: stride (word0 [15:12])
    layer_id: int = 0
    buff_base: int = 0
    dram_base: int = 0
    size: int = 0

    def encode(self) -> np.ndarray:
        """-> uint32[4] (128 bits)."""
        if not (0 <= self.layer_id < 1 << 16):
            raise ValueError("layer_id out of range")
        if self.opcode == Opcode.POOL:
            # POOL reuses the M_TILE byte for window/stride
            if self.m_tile:
                raise ValueError("POOL carries window/stride, not m_tile")
            if not (0 <= self.pool_window < 1 << 4):
                raise ValueError("pool_window out of range (4 bits)")
            if not (0 <= self.pool_stride < 1 << 4):
                raise ValueError("pool_stride out of range (4 bits)")
            byte = self.pool_window | (self.pool_stride << 4)
        else:
            if self.pool_window or self.pool_stride:
                raise ValueError(
                    f"pool window/stride only valid on POOL, not {self.opcode.name}")
            if not (0 <= self.m_tile < 1 << 8):
                raise ValueError("m_tile out of range")
            byte = self.m_tile
        w0 = (int(self.opcode) & 0xF)
        w0 |= (1 << 4) if self.wino_flag else 0
        w0 |= (1 << 5) if self.dataflow_ws else 0
        w0 |= (1 << 6) if self.layout_out_wino else 0
        w0 |= (1 << 7) if self.relu_flag else 0
        w0 |= (byte & 0xFF) << 8
        w0 |= (self.layer_id & 0xFFFF) << 16
        words = [w0, self.buff_base & 0xFFFFFFFF,
                 self.dram_base & 0xFFFFFFFF, self.size & 0xFFFFFFFF]
        return np.array(words, dtype=np.uint32)


def decode(words: np.ndarray) -> Instruction:
    """uint32[4] -> Instruction.

    Raises ``ValueError`` naming the offending word for reserved /
    out-of-range opcode values (0, 10..15) rather than surfacing the bare
    enum error.
    """
    w0, buff, dram, size = (int(w) for w in np.asarray(words, np.uint32))
    code = w0 & 0xF
    try:
        opcode = Opcode(code)
    except ValueError:
        raise ValueError(
            f"reserved/out-of-range opcode {code} in instruction "
            f"word0=0x{w0:08x} (valid: "
            f"{', '.join(f'{o.name}={int(o)}' for o in Opcode)})") from None
    byte = w0 >> 8 & 0xFF
    is_pool = opcode == Opcode.POOL
    return Instruction(
        opcode=opcode,
        wino_flag=bool(w0 >> 4 & 1),
        dataflow_ws=bool(w0 >> 5 & 1),
        layout_out_wino=bool(w0 >> 6 & 1),
        relu_flag=bool(w0 >> 7 & 1),
        m_tile=0 if is_pool else byte,
        pool_window=byte & 0xF if is_pool else 0,
        pool_stride=byte >> 4 & 0xF if is_pool else 0,
        layer_id=w0 >> 16 & 0xFFFF,
        buff_base=buff,
        dram_base=dram,
        size=size,
    )


def encode_stream(instrs: list[Instruction]) -> np.ndarray:
    """-> uint32[n, 4] instruction memory image."""
    if not instrs:
        return np.zeros((0, 4), np.uint32)
    return np.stack([i.encode() for i in instrs])


def decode_stream(image: np.ndarray) -> list[Instruction]:
    return [decode(row) for row in np.asarray(image, np.uint32).reshape(-1, 4)]
