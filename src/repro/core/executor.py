"""Two-phase program execution: validate once, trace many.

The ``HybridRuntime`` interpreter replays the 128-bit ISA stream one Python
dispatch at a time — one hazard check and one ``staging.at[].set()`` per
instruction — which is faithful to the hardware handshake FIFOs (Sec. 4.1)
but caps end-to-end inference at Python speed. This module splits that job
into the two phases the paper's accelerator actually has:

* **Phase 1 — schedule validation** (:func:`validate_schedule`): replay the
  instruction stream against *symbolic* buffer state only (slot tags, block
  sets — no tensors). This enforces the identical handshake-FIFO discipline
  as the interpreter — LOAD over a live slot, COMP before its LOADs, SAVE
  before COMP, a missing final SAVE all raise :class:`HazardError` — and
  produces the same pipeline-statistics counters. It runs once per
  ``Program``; the hardware analog is the one-time bitstream/schedule check
  before the stream is burned into instruction memory.

* **Phase 2 — lowering** (:func:`lower_program`): turn the validated
  schedule into a pure function ``execute(params, x) -> y`` made only of
  ``lax``/``jnp`` ops with static Python control flow — per-layer blocked
  compute (the same row-group/k-group blocks the COMP instructions name)
  assembled with ``concatenate`` instead of per-instruction dict staging.
  The result is ``jax.jit``-compatible and is cached per
  ``(Program, batch, dtype)`` by :mod:`repro.core.program_cache`.

Both phases cover the full-network ISA: POOL and FC blocks validate under
the same slot-tag discipline as COMP (input slot for POOL; input slot,
weight slot and bias buffer for FC) and lower through the shared
:func:`pool_forward` / :func:`fc_forward` helpers the interpreter also
calls, so an entire model — CONVs, maxpools, FC tail — executes as one
jitted function.

Numerical contract: for a stream that passes validation, the lowered
function computes block-for-block the same math as the interpreter (same
halo slicing, same horizontal padding, same U-space weight pre-transform,
same dtype casts), so outputs agree to float-associativity tolerance.

Backends: lowering emits each block's compute through one of two PE
implementations, selected by ``backend=``:

* ``"xla"`` (default) — plain ``lax``/``jnp`` ops. GSPMD-partitionable, so
  the lowered function can live inside a pjit-sharded model.
* ``"pallas"`` — the Pallas PE kernels (``kernels/spatial_conv`` for
  Spatial CONV, ``kernels/winograd`` + ``kernels/gemm`` for Winograd CONV,
  ``kernels/gemm`` for FC). ``interpret=None`` auto-selects interpret mode
  off-TPU (``kernels.common.INTERPRET``) so the same Program runs on the
  CPU test container; pass ``interpret=False`` to force compiled lowering.

Both backends lower the identical blocked schedule — only the per-block PE
changes — and are asserted equal (to tolerance) over full reduced VGG16 in
``tests/test_backend_pallas.py``. POOL blocks always lower through
``lax.reduce_window``: pooling is comparisons, not PE MACs, in the paper's
architecture (Sec. 4.2). See ``docs/ARCHITECTURE.md``.

Lowering optimizer (``opt_level``): the literal per-block lowering above is
faithful to the COMP stream but wasteful as a *software* dataflow — every
block re-materializes its vertical halo (``jnp.pad`` + slice) and the
per-(row, k) blocks reassemble through fusion-blocking ``concatenate``
chains, so XLA sees G_H x G_K small convolutions per layer instead of one.
``opt_level=1`` (the default) runs :func:`analyze_program` before tracing:
a CONV layer whose blocks are *provably equivalent* to one whole-layer
dispatch — every COMP block carries the same RELU bit, the k-groups
contiguously tile [0, K), the row groups contiguously tile the output
height (halos are always spec-derived, see :func:`slice_input_rows`) —
collapses to a single PE call over the full weight image. A layer whose
RELU bits differ between blocks cannot fuse (the stream is authoritative);
when its k-groups are equal-sized it lowers to a stacked-weight batched
form (one vmapped PE call + a static per-block RELU mask) instead of the
concat chain, and anything else falls back to the literal blocked lowering.
``opt_level=0`` keeps the literal lowering everywhere — the reference the
optimizer is tested against. The chosen level joins the program-cache key,
so fused and blocked executors of one Program coexist. On this container's
CPU backend the fused lowering is bitwise-equal to the blocked one (and to
the strict interpreter) — asserted in ``tests/test_opt_lowering.py`` and
measured in the ``runtime/fused_vs_blocked`` bench row.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layouts
from repro.core.compiler import CompiledLayer, Program
from repro.core.hybrid_conv import (
    dense,
    depthwise_conv2d,
    hybrid_conv2d,
    max_pool2d,
    same_pad,
)
from repro.core.isa import Opcode, unpack_dw_geom, unpack_fc_dims
from repro.core.winograd import transform_weights, winograd_apply_pretransformed
from repro.quant.execute import qconv2d, qdense, qdepthwise, qeltwise
from repro.quant.sidecar import LayerQuant, QuantSidecar


class HazardError(RuntimeError):
    """Instruction-stream hazard: the handshake FIFO discipline was violated.

    Shared by the interpreter and the validation pass (``runtime.py``
    re-exports this class so existing ``except HazardError`` sites keep
    working).
    """


BACKENDS = ("xla", "pallas")
OPT_LEVELS = (0, 1)


def resolve_opt_level(opt_level: int) -> int:
    """Validate the lowering-optimizer level (0 = literal per-block
    lowering, 1 = fused whole-layer lowering where provably equivalent)."""
    if opt_level not in OPT_LEVELS:
        raise ValueError(
            f"unknown opt_level {opt_level!r}: expected one of {OPT_LEVELS}")
    return int(opt_level)


def resolve_backend(backend: str, interpret: bool | None
                    ) -> tuple[str, bool | None]:
    """Normalize a ``(backend, interpret)`` pair to its effective value.

    ``interpret`` only means something on the Pallas backend; ``None`` there
    resolves to ``kernels.common.INTERPRET`` (interpret mode everywhere but
    real TPU). Passing a non-None ``interpret`` with ``backend="xla"`` is a
    contradiction — the XLA lowering would silently ignore it and the
    caller would believe the Pallas interpret path was exercised — so it
    raises instead. The resolved pair is what joins the program-cache key,
    so an auto-selected fallback and an explicit one share a cache entry.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}: expected one of {BACKENDS}")
    if backend == "xla":
        if interpret is not None:
            raise ValueError(
                f"interpret={interpret!r} has no effect with backend='xla' "
                f"— pass backend='pallas' or drop interpret")
        return "xla", None
    if interpret is None:
        from repro.kernels.common import INTERPRET
        return "pallas", INTERPRET
    return "pallas", bool(interpret)


def _fresh_stats() -> dict[str, int]:
    return {"load_inp": 0, "load_wgt": 0, "load_bias": 0,
            "comp": 0, "pool": 0, "fc": 0, "eltwise": 0, "dw": 0,
            "save": 0, "inp_words": 0, "wgt_words": 0}


# ---------------------------------------------------------------------------
# Phase 1: schedule validation (symbolic replay, no tensors)
# ---------------------------------------------------------------------------

def validate_schedule(program: Program) -> dict[str, int]:
    """Replay the hazard/FIFO discipline once, without any compute.

    Mirrors ``HybridRuntime``'s checks exactly — the tags that the
    interpreter attaches to tensor payloads are tracked here on their own.
    Returns the pipeline statistics counters (same keys as
    ``HybridRuntime.stats``); raises :class:`HazardError` on the first
    violation.
    """
    stats = _fresh_stats()
    inp_tags: list[tuple | None] = [None, None]
    wgt_tags: list[tuple | None] = [None, None]
    bias_tag: tuple | None = None
    out_blocks: set[tuple[int, int]] = set()
    saved_any = False
    cur_layer = -1

    def flush(layer_id: int):
        if out_blocks:
            raise HazardError(
                f"layer {layer_id}: {len(out_blocks)} COMP blocks never SAVEd")
        if not saved_any:
            raise HazardError(f"layer {layer_id}: no SAVE executed")

    for ins in program.instructions:
        cl = program.layers[ins.layer_id]
        if ins.layer_id != cur_layer:
            if cur_layer >= 0:
                flush(cur_layer)
            cur_layer = ins.layer_id
            out_blocks = set()
            saved_any = False

        op = ins.opcode
        if op == Opcode.LOAD_BIAS:
            bias_tag = (ins.layer_id,)
            stats["load_bias"] += 1
        elif op == Opcode.LOAD_INP:
            ih, slot = ins.buff_base >> 1, ins.buff_base & 1
            inp_tags[slot] = (ins.layer_id, ih)
            stats["load_inp"] += 1
            stats["inp_words"] += ins.size
        elif op == Opcode.LOAD_WGT:
            kg, slot = ins.buff_base >> 1, ins.buff_base & 1
            wgt_tags[slot] = (ins.layer_id, kg)
            stats["load_wgt"] += 1
            stats["wgt_words"] += ins.size
        elif op == Opcode.COMP:
            ih = ins.size & 0xFFF
            kg = (ins.size >> 12) & 0xFFF
            islot = (ins.size >> 24) & 1
            wslot = (ins.size >> 25) & 1
            if inp_tags[islot] != (ins.layer_id, ih):
                raise HazardError(
                    f"COMP L{ins.layer_id} row-group {ih}: input slot "
                    f"{islot} holds {inp_tags[islot]}")
            if wgt_tags[wslot] != (ins.layer_id, kg):
                raise HazardError(
                    f"COMP L{ins.layer_id} k-group {kg}: weight slot "
                    f"{wslot} holds {wgt_tags[wslot]}")
            if bias_tag != (ins.layer_id,):
                raise HazardError(f"COMP L{ins.layer_id}: stale bias buffer")
            out_blocks.add((ih, kg))
            stats["comp"] += 1
        elif op == Opcode.POOL:
            islot = ins.buff_base & 1
            cfg = (ins.pool_window, ins.pool_stride)
            if cfg != (cl.spec.window, cl.spec.stride):
                raise HazardError(
                    f"POOL L{ins.layer_id}: word0 window/stride {cfg} "
                    f"disagree with compiled spec "
                    f"({cl.spec.window}, {cl.spec.stride})")
            if inp_tags[islot] != (ins.layer_id, 0):
                raise HazardError(
                    f"POOL L{ins.layer_id}: input slot {islot} holds "
                    f"{inp_tags[islot]}")
            out_blocks.add((0, 0))
            stats["pool"] += 1
        elif op == Opcode.FC:
            islot = ins.buff_base & 1
            wslot = (ins.buff_base >> 1) & 1
            dims = unpack_fc_dims(ins.size)
            if dims != (cl.spec.d_in, cl.spec.d_out):
                raise HazardError(
                    f"FC L{ins.layer_id}: word3 dims {dims} disagree with "
                    f"compiled spec ({cl.spec.d_in}, {cl.spec.d_out})")
            if inp_tags[islot] != (ins.layer_id, 0):
                raise HazardError(
                    f"FC L{ins.layer_id}: input slot {islot} holds "
                    f"{inp_tags[islot]}")
            if wgt_tags[wslot] != (ins.layer_id, 0):
                raise HazardError(
                    f"FC L{ins.layer_id}: weight slot {wslot} holds "
                    f"{wgt_tags[wslot]}")
            if bias_tag != (ins.layer_id,):
                raise HazardError(f"FC L{ins.layer_id}: stale bias buffer")
            out_blocks.add((0, 0))
            stats["fc"] += 1
        elif op == Opcode.ELTWISE_ADD:
            pslot = ins.buff_base & 1
            sslot = (ins.buff_base >> 1) & 1
            n_el = cl.spec.h * cl.spec.w * cl.spec.c
            if ins.size != n_el:
                raise HazardError(
                    f"ELTWISE L{ins.layer_id}: word3 element count "
                    f"{ins.size} disagrees with compiled spec ({n_el})")
            if ins.dram_base != cl.skip_addr:
                raise HazardError(
                    f"ELTWISE L{ins.layer_id}: word2 skip base "
                    f"{ins.dram_base} disagrees with compiled skip operand "
                    f"({cl.skip_addr})")
            if inp_tags[pslot] != (ins.layer_id, 0):
                raise HazardError(
                    f"ELTWISE L{ins.layer_id}: primary input slot {pslot} "
                    f"holds {inp_tags[pslot]}")
            if inp_tags[sslot] != (ins.layer_id, 1):
                raise HazardError(
                    f"ELTWISE L{ins.layer_id}: skip input slot {sslot} "
                    f"holds {inp_tags[sslot]}")
            out_blocks.add((0, 0))
            stats["eltwise"] += 1
        elif op == Opcode.DEPTHWISE_CONV:
            islot = ins.buff_base & 1
            wslot = (ins.buff_base >> 1) & 1
            geom = unpack_dw_geom(ins.size)
            if geom != (cl.spec.r, cl.spec.s, cl.spec.stride):
                raise HazardError(
                    f"DEPTHWISE L{ins.layer_id}: word3 geometry {geom} "
                    f"disagrees with compiled spec "
                    f"({cl.spec.r}, {cl.spec.s}, {cl.spec.stride})")
            if inp_tags[islot] != (ins.layer_id, 0):
                raise HazardError(
                    f"DEPTHWISE L{ins.layer_id}: input slot {islot} holds "
                    f"{inp_tags[islot]}")
            if wgt_tags[wslot] != (ins.layer_id, 0):
                raise HazardError(
                    f"DEPTHWISE L{ins.layer_id}: weight slot {wslot} holds "
                    f"{wgt_tags[wslot]}")
            if bias_tag != (ins.layer_id,):
                raise HazardError(
                    f"DEPTHWISE L{ins.layer_id}: stale bias buffer")
            out_blocks.add((0, 0))
            stats["dw"] += 1
        elif op == Opcode.SAVE:
            ih = ins.size & 0xFFF
            kg = (ins.size >> 12) & 0xFFF
            if cl.kind != "conv":
                need = [(0, 0)]
            elif cl.plan.dataflow == "is":
                need = [(ih, g) for g in range(len(cl.k_groups))]
            else:
                need = [(ih, kg)]
            for key in need:
                if key not in out_blocks:
                    raise HazardError(
                        f"SAVE L{ins.layer_id} block {key} not computed")
                out_blocks.discard(key)
            saved_any = True
            stats["save"] += 1
        else:
            raise ValueError(op)

    if cur_layer >= 0:
        flush(cur_layer)
    else:
        raise HazardError("empty instruction stream")
    return stats


# ---------------------------------------------------------------------------
# Phase 2: lowering to a pure, traceable function
# ---------------------------------------------------------------------------

def slice_input_rows(cl: CompiledLayer, x_nhwc: jax.Array, ih: int) -> jax.Array:
    """Static-slice the input rows (plus halo) for output row group ``ih``.

    Shared with the interpreter (``HybridRuntime._load_input_group``
    delegates here) so the two paths can never drift. Everything is
    Python-int static, so the slice lowers to a plain XLA slice.
    """
    r0, r1 = cl.row_groups[ih]
    return slice_input_span(cl, x_nhwc, r0, r1)


def slice_input_span(cl: CompiledLayer, x_nhwc: jax.Array,
                     r0: int, r1: int) -> jax.Array:
    """Input rows (plus spec-derived halo) for output rows ``[r0, r1)``.

    The fused lowering calls this with the whole output height — the same
    arithmetic a single-row-group plan would produce, which is what makes
    whole-layer fusion provably equivalent to the blocked assembly.
    """
    spec = cl.spec
    pad = (same_pad(spec.h, spec.r, spec.stride)[0]
           if spec.padding.upper() == "SAME" else 0)
    in_lo = r0 * spec.stride - pad
    in_hi = (r1 - 1) * spec.stride + spec.r - pad
    pad_top = max(0, -in_lo)
    pad_bot = max(0, in_hi - spec.h)
    sl = x_nhwc[:, max(0, in_lo):min(spec.h, in_hi)]
    if pad_top or pad_bot:
        sl = jnp.pad(sl, ((0, 0), (pad_top, pad_bot), (0, 0), (0, 0)))
    return sl


def width_pad(cl: CompiledLayer) -> tuple[int, int]:
    """Horizontal conv padding (vertical halo is materialized by the slice)."""
    if cl.spec.padding.upper() == "SAME":
        return same_pad(cl.spec.w, cl.spec.s, cl.spec.stride)
    return (0, 0)


def conv_block_forward(cl: CompiledLayer, x_slab: jax.Array,
                       w_grp: jax.Array, b_grp: jax.Array, relu: bool,
                       *, backend: str = "xla",
                       interpret: bool | None = None,
                       quant: LayerQuant | None = None,
                       k_range: tuple[int, int] | None = None) -> jax.Array:
    """One COMP block on the selected PE backend.

    ``x_slab`` is the row-group slice (halo included, vertical padding
    materialized); ``w_grp`` the k-group slice of the DRAM weight image
    (U-space for Winograd). Shared by the lowered executor and the strict
    interpreter's COMP handler so the two paths route through one PE
    implementation per backend. ``quant`` switches the block to the int8
    PE (``repro.quant.execute``): int8 in/weights, int32 accumulate, fused
    requantize(+ReLU) epilogue — spatial mode only (the DSE keeps Winograd
    plans off quantized builds). When ``w_grp``/``b_grp`` are a k-group
    slice of the layer, ``k_range=(lo, hi)`` slices a per-channel
    multiplier to match (a per-tensor scalar is slice-invariant).
    """
    spec, plan = cl.spec, cl.plan
    dtype = x_slab.dtype
    wpad = width_pad(cl)
    if quant is not None:
        if plan.mode == "wino":
            raise ValueError(
                f"layer {cl.layer_id}: Winograd plans cannot execute int8 "
                f"(the U-space transform is fp-only) — rebuild with "
                f"dtype='int8' so the DSE falls back to spatial")
        mult = quant.multiplier
        if k_range is not None and np.ndim(mult):
            mult = mult[k_range[0]:k_range[1]]
        return qconv2d(x_slab, w_grp, b_grp, mult=mult,
                       stride=spec.stride, padding=((0, 0), wpad),
                       relu=relu, use_pallas=backend == "pallas",
                       interpret=interpret)
    if plan.mode == "wino":
        x_p = jnp.pad(x_slab, ((0, 0), (0, 0), wpad, (0, 0)))
        if backend == "pallas":
            from repro.kernels.winograd import (
                winograd_apply_pretransformed_pallas,
            )
            return winograd_apply_pretransformed_pallas(
                x_p, w_grp, b_grp, m=plan.m, relu=relu, padding="VALID",
                dataflow=plan.dataflow, out_dtype=dtype, interpret=interpret)
        return winograd_apply_pretransformed(
            x_p, w_grp, b_grp, plan.m, relu=relu,
            padding="VALID", out_dtype=dtype)
    # the XLA lowering is dataflow-oblivious (and hybrid_conv2d now rejects
    # a dataflow/interpret that cannot take effect), so only forward the
    # plan's dataflow to the Pallas PE
    pallas = backend == "pallas"
    return hybrid_conv2d(
        x_slab, w_grp, b_grp, mode="spat",
        dataflow=plan.dataflow if pallas else "is", stride=spec.stride,
        relu=relu, padding=((0, 0), wpad),
        use_pallas=pallas, interpret=interpret,
        out_dtype=dtype)


# ---------------------------------------------------------------------------
# Lowering optimizer: per-layer block-structure analysis (opt_level=1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerLowering:
    """The optimizer's verdict for one CONV layer.

    ``kind``:

    * ``"fused"``  — one whole-layer PE dispatch (uniform RELU bit across
      every COMP block, k-groups contiguously tile [0, K), row groups
      contiguously tile the output height). ``relu`` holds the uniform bit.
    * ``"stacked"`` — RELU bits differ between blocks but the groups still
      tile contiguously and the k-groups are equal-sized: one vmapped PE
      call over stacked weight groups, RELU applied through a static
      per-block mask (``relu_blocks[kg][ih]``) — no concat chain.
    * ``"block"``  — not provably reducible (non-contiguous groups from a
      hand-built stream, unequal k-group sizes with mixed RELU bits, or the
      Pallas backend where vmapping the PE kernel is not supported): keep
      the literal per-block lowering. ``reason`` says why.
    * ``"single"`` — the opcode is already one dispatch by construction
      (ELTWISE_ADD two-source add, DEPTHWISE_CONV grouped conv): nothing to
      fuse, the verdict is explicit so the optimizer's coverage is total.
    """
    kind: str
    relu: bool | None = None
    relu_blocks: tuple[tuple[bool, ...], ...] | None = None
    reason: str = ""


def _tiles_contiguously(groups, total: int) -> bool:
    lo = 0
    for a, b in groups:
        if a != lo or b <= a:
            return False
        lo = b
    return lo == total


def _stream_overrides(program: Program):
    """Per-block RELU bits and POOL configs, read off the instruction
    stream — the stream is authoritative over the compiled specs."""
    relu_bits: dict[tuple[int, int, int], bool] = {}
    pool_cfg: dict[int, tuple[int, int]] = {}
    for ins in program.instructions:
        if ins.opcode == Opcode.COMP:
            ih = ins.size & 0xFFF
            kg = (ins.size >> 12) & 0xFFF
            relu_bits[(ins.layer_id, ih, kg)] = ins.relu_flag
        elif ins.opcode in (Opcode.FC, Opcode.ELTWISE_ADD,
                            Opcode.DEPTHWISE_CONV):
            relu_bits[(ins.layer_id, 0, 0)] = ins.relu_flag
        elif ins.opcode == Opcode.POOL:
            pool_cfg[ins.layer_id] = (ins.pool_window, ins.pool_stride)
    return relu_bits, pool_cfg


def analyze_layer(cl: CompiledLayer, relu_of, *,
                  backend: str = "xla") -> LayerLowering:
    """Decide how one CONV layer may lower under ``opt_level=1``.

    ``relu_of(ih, kg)`` is the effective RELU bit of that COMP block (the
    stream's bit, falling back to the spec for blocks the stream omits).
    Fusion is claimed only when the whole-layer dispatch is provably the
    same math as the blocked assembly; anything unprovable keeps the
    literal lowering.
    """
    ho, _ = cl.spec.out_hw
    if not _tiles_contiguously(cl.row_groups, ho):
        return LayerLowering("block", reason="row groups do not tile H")
    if not _tiles_contiguously(cl.k_groups, cl.spec.k):
        return LayerLowering("block", reason="k-groups do not tile K")
    bits = {(ih, kg): bool(relu_of(ih, kg))
            for ih in range(len(cl.row_groups))
            for kg in range(len(cl.k_groups))}
    uniq = set(bits.values())
    if len(uniq) == 1:
        return LayerLowering("fused", relu=uniq.pop())
    if backend == "pallas":
        return LayerLowering(
            "block", reason="mixed RELU bits: Pallas PE is not vmapped")
    sizes = {hi - lo for lo, hi in cl.k_groups}
    if len(sizes) != 1:
        return LayerLowering(
            "block", reason="mixed RELU bits over unequal k-group sizes")
    relu_blocks = tuple(
        tuple(bits[(ih, kg)] for ih in range(len(cl.row_groups)))
        for kg in range(len(cl.k_groups)))
    return LayerLowering("stacked", relu_blocks=relu_blocks,
                         reason="mixed RELU bits")


def analyze_program(program: Program, *, backend: str = "xla",
                    relu_bits: dict | None = None
                    ) -> dict[int, LayerLowering]:
    """The optimizer pass: one :class:`LayerLowering` verdict per layer
    that lowers through the PE — CONV layers get the fused/stacked/block
    analysis; ELTWISE and DEPTHWISE layers get an explicit ``"single"``
    verdict (one dispatch by construction; POOL and FC likewise but
    predate the verdict table and stay implicit). Pure static analysis
    over the instruction stream + compiled geometry — runs once per
    lowering, before any tracing. ``relu_bits`` lets a caller that already
    decoded the stream (``lower_program``) share the one walk."""
    if relu_bits is None:
        relu_bits, _ = _stream_overrides(program)
    out = {}
    for cl in program.layers:
        if cl.kind == "eltwise":
            out[cl.layer_id] = LayerLowering(
                "single", reason="ELTWISE_ADD is one two-source dispatch")
            continue
        if cl.kind == "dw":
            out[cl.layer_id] = LayerLowering(
                "single", reason="DEPTHWISE_CONV is one grouped-conv "
                                 "dispatch")
            continue
        if cl.kind != "conv":
            continue
        out[cl.layer_id] = analyze_layer(
            cl,
            lambda ih, kg, cl=cl: relu_bits.get((cl.layer_id, ih, kg),
                                                cl.spec.relu),
            backend=backend)
    return out


def _layer_forward_fused(cl: CompiledLayer, w_eff: jax.Array,
                         bias: jax.Array, x: jax.Array, relu: bool, *,
                         backend: str, interpret: bool | None,
                         quant: LayerQuant | None = None) -> jax.Array:
    """One whole-layer PE dispatch — the blocked assembly collapsed to a
    single virtual block covering all rows and the full weight image.
    Valid under ``quant`` too: integer accumulation is exact, so the fused
    int32 sums equal the per-block sums bit for bit and the elementwise
    requantize epilogue commutes with the block partition."""
    ho, _ = cl.spec.out_hw
    x_slab = slice_input_span(cl, x, 0, ho)
    blk = conv_block_forward(cl, x_slab, w_eff, bias, relu,
                             backend=backend, interpret=interpret,
                             quant=quant)
    return blk[:, :ho]


def _layer_forward_stacked(cl: CompiledLayer, w_eff: jax.Array,
                           bias: jax.Array, x: jax.Array,
                           lowering: LayerLowering, *, backend: str,
                           interpret: bool | None) -> jax.Array:
    """Stacked-weight batched form: one vmapped PE call over the k-groups
    plus a static per-block RELU mask — replaces the concat chain for
    layers whose RELU bits differ between blocks."""
    ho, _ = cl.spec.out_hw
    n_kg = len(cl.k_groups)
    kg_sz = cl.k_groups[0][1] - cl.k_groups[0][0]
    x_slab = slice_input_span(cl, x, 0, ho)
    # (..., K) -> (G_K, ..., kg_sz): contiguous k-groups become the vmap axis
    w_st = jnp.moveaxis(w_eff.reshape(*w_eff.shape[:-1], n_kg, kg_sz), -2, 0)
    b_st = bias.reshape(n_kg, kg_sz)
    blks = jax.vmap(lambda w, b: conv_block_forward(
        cl, x_slab, w, b, False, backend=backend, interpret=interpret)
    )(w_st, b_st)                                   # (G_K, N, H', W, kg_sz)
    blks = blks[:, :, :ho]
    mask = np.zeros((n_kg, ho), bool)               # static: trace constant
    for kg in range(n_kg):
        for ih, (r0, r1) in enumerate(cl.row_groups):
            mask[kg, r0:r1] = lowering.relu_blocks[kg][ih]
    blks = jnp.where(jnp.asarray(mask)[:, None, :, None, None],
                     jnp.maximum(blks, 0), blks)
    y = jnp.moveaxis(blks, 0, -2)                   # (N, ho, W, G_K, kg_sz)
    return y.reshape(*y.shape[:-2], n_kg * kg_sz)


def _layer_forward(cl: CompiledLayer, w_eff: jax.Array, bias: jax.Array,
                   x_stored: jax.Array, relu_of, *, backend: str = "xla",
                   interpret: bool | None = None,
                   lowering: LayerLowering | None = None,
                   quant: LayerQuant | None = None) -> jax.Array:
    """One layer as blocked compute over the compiled (row, k) groups.

    ``w_eff`` is the DRAM-resident weight image: U-space ``(PT, PT, C, K)``
    for Winograd layers, raw ``(R, S, C, K)`` for Spatial — exactly what
    ``HybridRuntime.load_params`` stores. ``relu_of(ih, kg)`` is the COMP
    instruction's RELU bit for that block (the stream is authoritative, not
    the spec — the interpreter obeys ``ins.relu_flag`` and so must we).
    ``lowering`` is the optimizer's verdict (``None`` = the literal blocked
    lowering, the ``opt_level=0`` reference).
    """
    spec = cl.spec
    x = layouts.load_view(x_stored, cl.inp_layout, hw=(spec.h, spec.w))
    dtype = x_stored.dtype

    # the stacked form masks ReLU AFTER the PE call — wrong under quant,
    # where ReLU must precede the requantize epilogue; keep the literal
    # blocked lowering for those (rare mixed-RELU) layers instead
    if quant is not None and lowering is not None \
            and lowering.kind == "stacked":
        lowering = None

    if lowering is not None and lowering.kind == "fused":
        y = _layer_forward_fused(cl, w_eff, bias, x, lowering.relu,
                                 backend=backend, interpret=interpret,
                                 quant=quant).astype(dtype)
    elif lowering is not None and lowering.kind == "stacked":
        y = _layer_forward_stacked(cl, w_eff, bias, x, lowering,
                                   backend=backend,
                                   interpret=interpret).astype(dtype)
    else:
        row_slabs = []
        for ih, (r0, r1) in enumerate(cl.row_groups):
            x_slab = slice_input_rows(cl, x, ih)
            k_blocks = []
            for kg, (lo, hi) in enumerate(cl.k_groups):
                blk = conv_block_forward(
                    cl, x_slab, w_eff[..., lo:hi], bias[lo:hi],
                    relu_of(ih, kg), backend=backend, interpret=interpret,
                    quant=quant, k_range=(lo, hi))
                k_blocks.append(blk[:, :r1 - r0].astype(dtype))
            row_slabs.append(k_blocks[0] if len(k_blocks) == 1
                             else jnp.concatenate(k_blocks, axis=-1))
        y = (row_slabs[0] if len(row_slabs) == 1
             else jnp.concatenate(row_slabs, 1))
    if cl.out_layout == "wino":
        y = layouts.save_transform(y, "wino", cl.out_m)
    return y


def pool_forward(cl: CompiledLayer, x_stored: jax.Array,
                 window: int, stride: int) -> jax.Array:
    """One POOL block: identity LOAD view -> max pool, NHWC out.

    The SAVE-side layout reorder (``out_layout == "wino"``) is applied by
    the caller — the interpreter's layer flush or the lowered executor —
    exactly as for CONV layers. Shared by both paths so they can never
    drift.
    """
    x = layouts.load_view(x_stored, cl.inp_layout, hw=(cl.spec.h, cl.spec.w))
    return max_pool2d(x, window=window, stride=stride)


def fc_forward(cl: CompiledLayer, w: jax.Array, bias: jax.Array,
               x_stored: jax.Array, relu: bool, *, backend: str = "xla",
               interpret: bool | None = None,
               quant: LayerQuant | None = None) -> jax.Array:
    """One FC layer: identity LOAD view, flatten, run the dense PE.

    ``load_view`` honors ``inp_layout`` so a hand-built stream whose
    previous layer stored tile-major WINO still flattens in NHWC order
    (compiler-emitted programs always store SPAT before FC). Shared by the
    interpreter and the lowered executor; ``backend="pallas"`` routes the
    matmul through the shared ``kernels/gemm`` PE (the int8 GEMM variant
    when ``quant`` is set).
    """
    x = layouts.load_view(x_stored, cl.inp_layout)
    x = x.reshape(x.shape[0], -1)
    if quant is not None:
        return qdense(x, w, bias, mult=quant.multiplier, relu=relu,
                      use_pallas=backend == "pallas", interpret=interpret)
    return dense(x, w, bias, relu=relu, use_pallas=backend == "pallas",
                 interpret=interpret)


def eltwise_forward(cl: CompiledLayer, x_stored: jax.Array,
                    skip_stored: jax.Array, relu: bool,
                    quant: LayerQuant | None = None) -> jax.Array:
    """One ELTWISE_ADD block: two identity LOAD views -> add (+ ReLU).

    ``x_stored``/``skip_stored`` are the producers' STORED tensors (the
    compiler records each operand's layout on the CompiledLayer); like POOL,
    the add is element-parallel VPU work on both backends. Shared by the
    interpreter and the lowered executor so the residual-add math can never
    drift between paths. Under ``quant`` the two int8 operands carry
    different scales, so the add runs through ``qeltwise`` (dequantize into
    output units, add, ReLU, requantize).
    """
    hw = (cl.spec.h, cl.spec.w)
    a = layouts.load_view(x_stored, cl.inp_layout, hw=hw)
    b = layouts.load_view(skip_stored, cl.skip_layout, hw=hw)
    if quant is not None:
        return qeltwise(a, b, quant, relu)
    y = a.astype(jnp.float32) + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x_stored.dtype)


def depthwise_forward(cl: CompiledLayer, w: jax.Array, bias: jax.Array,
                      x_stored: jax.Array, relu: bool,
                      quant: LayerQuant | None = None) -> jax.Array:
    """One DEPTHWISE_CONV block: identity LOAD view -> per-channel conv.

    Depthwise conv is VPU work, not an MXU GEMM — like POOL it lowers
    through the same XLA grouped-conv op on both backends (see
    docs/ARCHITECTURE.md); ``quant`` swaps in the int32-accumulating
    grouped conv + requantize epilogue. Shared by the interpreter and the
    lowered executor.
    """
    x = layouts.load_view(x_stored, cl.inp_layout, hw=(cl.spec.h, cl.spec.w))
    if quant is not None:
        return qdepthwise(x, w, bias, mult=quant.multiplier,
                          stride=cl.spec.stride, padding=cl.spec.padding,
                          relu=relu)
    return depthwise_conv2d(
        x, w, bias, stride=cl.spec.stride, padding=cl.spec.padding,
        relu=relu, out_dtype=x_stored.dtype)


def n_param_layers(program: Program) -> int:
    """Layers that carry (w, bias) params — CONV, FC and DEPTHWISE; POOL
    and ELTWISE have none."""
    return sum(cl.kind not in ("pool", "eltwise") for cl in program.layers)


def check_param_count(program: Program, params: list):
    if len(params) != n_param_layers(program):
        raise ValueError(
            f"expected {n_param_layers(program)} (w, bias) entries — one per "
            f"CONV/FC/DEPTHWISE layer in network order, POOL and ELTWISE "
            f"layers carry no params — got {len(params)}")


def to_dram_params(program: Program, params: list) -> list:
    """Raw ``[(w, bias), ...]`` (one entry per *parameterized* layer — CONV
    and FC; POOL layers carry no params) -> the DRAM weight image the
    executor consumes: U-space ``(PT, PT, C, K)`` for Winograd CONV layers,
    raw for Spatial CONV and FC — identical to what
    ``HybridRuntime.load_params`` stores. Pure jax, so it is differentiable
    and may run host-side (once, the paper's offline transform) or inside a
    caller's own trace.
    """
    check_param_count(program, params)
    out = []
    it = iter(params)
    for cl in program.layers:
        if cl.kind in ("pool", "eltwise"):
            continue
        w, b = next(it)
        if cl.kind == "conv" and cl.plan.mode == "wino":
            assert cl.spec.r == 3 and cl.spec.s == 3, \
                "runtime pre-transform supports r=s=3 (VGG family)"
            w = transform_weights(w, cl.plan.m)
        out.append((w, b))
    return out


def lower_program(program: Program, *, backend: str = "xla",
                  interpret: bool | None = None, opt_level: int = 1,
                  quant: QuantSidecar | None = None
                  ) -> Callable[[list, jax.Array], jax.Array]:
    """Lower a validated schedule to ``execute(params, x_nhwc) -> y_nhwc``.

    ``params`` is the per-layer **DRAM weight image** — pre-transformed to
    U-space for Winograd layers (see :func:`to_dram_params`). Keeping the
    transform out of the traced function means steady-state calls never
    redo weight work: jit treats params as arguments, so anything computed
    from them inside the trace would re-execute every call.

    ``backend`` selects the per-block PE ("xla" or "pallas", see the module
    docstring); ``interpret`` is the Pallas interpret-mode override
    (``None`` = auto off-TPU). ``opt_level=1`` (default) runs the lowering
    optimizer (:func:`analyze_program`) and emits the fused / stacked forms
    for layers where they are provably equivalent; ``opt_level=0`` keeps
    the literal per-block lowering everywhere.

    ``quant`` (a :class:`repro.quant.QuantSidecar`) lowers every
    parameterized block through the int8 PE instead — params must then be
    the quantized image (``repro.quant.quantize_params``) and ``x_nhwc``
    int8 at the sidecar's input scale. The schedule, blocking, and
    liveness walk are untouched: quantization changes each block's
    arithmetic, never the program.
    """
    backend, interpret = resolve_backend(backend, interpret)
    opt_level = resolve_opt_level(opt_level)
    for cl in program.layers:
        if cl.kind == "conv" and cl.plan.mode == "wino":
            if quant is not None:
                raise ValueError(
                    f"layer {cl.layer_id}: Winograd plans cannot execute "
                    f"int8 — plan with the dtype='int8' DSE (wino falls "
                    f"back to spatial)")
            assert cl.spec.r == 3 and cl.spec.s == 3, \
                "runtime pre-transform supports r=s=3 (VGG family)"

    # the stream's COMP/FC RELU bits and POOL window/stride are the
    # authority (the compiler sets them from the spec, but hand-built or
    # decoded streams may differ per block)
    relu_bits, pool_cfg = _stream_overrides(program)
    lowerings = (analyze_program(program, backend=backend,
                                 relu_bits=relu_bits)
                 if opt_level >= 1 else {})

    # dataflow wiring, resolved statically: which producer each layer reads
    # (the stash below holds every tensor a not-yet-executed consumer still
    # needs — a skip tensor stays live across its residual block exactly as
    # the compiler's DRAM planner keeps it live) and when each producer's
    # entry retires (so the traced stash mirrors the planner's liveness
    # instead of pinning every activation to the end of the network)
    last_use: dict[int, int] = {}
    for cl in program.layers:
        srcs = {cl.primary_src()}
        if cl.kind == "eltwise":
            srcs.add(cl.skip_src)
        for src in srcs:
            last_use[src] = cl.layer_id

    def execute(params: list, x_nhwc: jax.Array) -> jax.Array:
        cl0 = program.layers[0]
        x = x_nhwc
        if cl0.inp_layout == "wino":
            x = layouts.save_transform(x, "wino", cl0.plan.m)
        stash: dict[int, jax.Array] = {-1: x}   # produced, still-live fmaps
        pi = 0
        y = x
        for cl in program.layers:
            x_in = stash[cl.primary_src()]
            lq = quant.layers[cl.layer_id] if quant is not None else None
            relu00 = relu_bits.get((cl.layer_id, 0, 0), cl.spec.relu) \
                if cl.kind != "pool" else False
            if cl.kind == "pool":
                window, stride = pool_cfg.get(
                    cl.layer_id, (cl.spec.window, cl.spec.stride))
                y = pool_forward(cl, x_in, window, stride)
            elif cl.kind == "eltwise":
                y = eltwise_forward(cl, x_in, stash[cl.skip_src], relu00,
                                    quant=lq)
            elif cl.kind == "fc":
                w_eff, b = params[pi]
                pi += 1
                y = fc_forward(cl, w_eff, b, x_in, relu00,
                               backend=backend, interpret=interpret,
                               quant=lq)
            elif cl.kind == "dw":
                w_eff, b = params[pi]
                pi += 1
                y = depthwise_forward(cl, w_eff, b, x_in, relu00, quant=lq)
            else:
                w_eff, b = params[pi]
                pi += 1
                y = _layer_forward(
                    cl, w_eff, b, x_in,
                    lambda ih, kg, cl=cl: relu_bits.get((cl.layer_id, ih, kg),
                                                        cl.spec.relu),
                    backend=backend, interpret=interpret,
                    lowering=lowerings.get(cl.layer_id), quant=lq)
            # _layer_forward applies the SAVE-side layout reorder itself;
            # the single-dispatch kinds store what the consumer's LOAD wants
            if cl.kind != "conv" and cl.out_layout == "wino":
                y = layouts.save_transform(y, "wino", cl.out_m)
            stash[cl.layer_id] = y
            for src in list(stash):
                if last_use.get(src, -2) <= cl.layer_id and src != cl.layer_id:
                    del stash[src]
        return y

    return execute


# ---------------------------------------------------------------------------
# Compiled executor: validation + lowering + jit, with trace accounting
# ---------------------------------------------------------------------------

def mesh_key(mesh) -> tuple | None:
    """Hashable topology key for a device mesh (``None`` = unmapped).

    Shape, axis names AND the flat device ids all join the key: two meshes
    over the same shape but different devices (or the same devices in a
    different order) lower to different per-shard programs, so they must not
    share a cache entry. This is what lets sharded and single-device
    executors of one Program coexist in :mod:`repro.core.program_cache`.
    """
    if mesh is None:
        return None
    return (tuple(mesh.devices.shape), tuple(mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def mesh_device_count(mesh) -> int:
    """Total devices spanned by ``mesh`` (1 for ``None``)."""
    if mesh is None:
        return 1
    return int(np.prod(mesh.devices.shape))


@dataclasses.dataclass
class CompiledExecutor:
    """A jitted executor for one ``(Program, batch, dtype, backend,
    opt_level, donate_input, mesh)`` entry."""
    program: Program
    stats: dict[str, int]          # schedule-validation pipeline counters
    fn: Callable                   # jitted execute(params, x)
    _trace_count: list
    backend: str = "xla"           # resolved PE backend ("xla" | "pallas")
    interpret: bool | None = None  # resolved Pallas interpret mode
    opt_level: int = 1             # lowering-optimizer level (0 = literal)
    donate_input: bool = False     # x buffer donated through jax.jit
    mesh_key: tuple | None = None  # shard_map topology (None = single-device)
    aot_loaded: bool = False       # fn is a deserialized AOT executable
                                   # (core/aot.py): already compiled, never
                                   # traces — trace_count stays 0

    @property
    def trace_count(self) -> int:
        """How many times the underlying function was traced (retrace probe)."""
        return self._trace_count[0]

    def __call__(self, params: list, x_nhwc: jax.Array) -> jax.Array:
        """``params`` is the DRAM weight image (see :func:`to_dram_params`)."""
        return self.fn(params, x_nhwc)


def compile_executor(program: Program,
                     stats: dict[str, int] | None = None, *,
                     backend: str = "xla",
                     interpret: bool | None = None,
                     opt_level: int = 1,
                     donate_input: bool = False,
                     mesh=None,
                     quant: QuantSidecar | None = None) -> CompiledExecutor:
    """Validate (unless pre-validated stats are supplied), lower, and jit.

    ``backend``/``interpret`` select the per-block PE and ``opt_level`` the
    lowering-optimizer level (see :func:`lower_program`); the resolved
    values are recorded on the returned executor so cache introspection can
    tell the paths apart. ``donate_input=True`` donates the activation
    buffer (``x``) through ``jax.jit`` — only safe when the caller never
    reuses the array it passed in (the pipelined ``ServingSession`` stages
    a fresh device array per batch, so it opts in; the general ``run`` path
    must not, since callers commonly re-invoke with the same input).

    ``mesh`` builds the **sharded executor variant**: the lowered function
    is wrapped in ``shard_map`` (via ``repro.compat``) over the batch axis,
    split across every mesh axis — params replicated, ``x``/``y`` sharded
    on dim 0. Each device runs the *whole per-shard program locally*, so
    the Pallas PE kernels work under sharding (GSPMD cannot partition an
    opaque Pallas custom call, but inside the mapped region there is
    nothing left to partition — every shard is an ordinary single-device
    trace). The batch must divide evenly by the mesh's device count; the
    program cache enforces this at ``get`` time where the batch is known.
    """
    if stats is None:
        stats = validate_schedule(program)
    backend, interpret = resolve_backend(backend, interpret)
    opt_level = resolve_opt_level(opt_level)
    execute = lower_program(program, backend=backend, interpret=interpret,
                            opt_level=opt_level, quant=quant)
    if mesh is not None and mesh_device_count(mesh) > 1:
        from jax.sharding import PartitionSpec

        from repro.compat import shard_map
        batch_spec = PartitionSpec(tuple(mesh.axis_names))
        # check_vma=False: pallas_call outputs carry no varying-manual-axes
        # annotation, and the xla lowering needs no replication check either
        execute = shard_map(execute, mesh=mesh,
                            in_specs=(PartitionSpec(), batch_spec),
                            out_specs=batch_spec, check_vma=False)
    trace_count = [0]

    def traced(params, x):
        trace_count[0] += 1     # Python side effect: fires at trace time only
        return execute(params, x)

    return CompiledExecutor(
        program=program, stats=dict(stats),
        fn=jax.jit(traced, donate_argnums=(1,) if donate_input else ()),
        _trace_count=trace_count, backend=backend, interpret=interpret,
        opt_level=opt_level, donate_input=bool(donate_input),
        mesh_key=mesh_key(mesh))
