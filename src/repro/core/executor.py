"""Two-phase program execution: validate once, trace many.

The ``HybridRuntime`` interpreter replays the 128-bit ISA stream one Python
dispatch at a time — one hazard check and one ``staging.at[].set()`` per
instruction — which is faithful to the hardware handshake FIFOs (Sec. 4.1)
but caps end-to-end inference at Python speed. This module splits that job
into the two phases the paper's accelerator actually has:

* **Phase 1 — schedule validation** (:func:`validate_schedule`): replay the
  instruction stream against *symbolic* buffer state only (slot tags, block
  sets — no tensors). This enforces the identical handshake-FIFO discipline
  as the interpreter — LOAD over a live slot, COMP before its LOADs, SAVE
  before COMP, a missing final SAVE all raise :class:`HazardError` — and
  produces the same pipeline-statistics counters. It runs once per
  ``Program``; the hardware analog is the one-time bitstream/schedule check
  before the stream is burned into instruction memory.

* **Phase 2 — lowering** (:func:`lower_program`): turn the validated
  schedule into a pure function ``execute(params, x) -> y`` made only of
  ``lax``/``jnp`` ops with static Python control flow — per-layer blocked
  compute (the same row-group/k-group blocks the COMP instructions name)
  assembled with ``concatenate`` instead of per-instruction dict staging.
  The result is ``jax.jit``-compatible and is cached per
  ``(Program, batch, dtype)`` by :mod:`repro.core.program_cache`.

Both phases cover the full-network ISA: POOL and FC blocks validate under
the same slot-tag discipline as COMP (input slot for POOL; input slot,
weight slot and bias buffer for FC) and lower through the shared
:func:`pool_forward` / :func:`fc_forward` helpers the interpreter also
calls, so an entire model — CONVs, maxpools, FC tail — executes as one
jitted function.

Numerical contract: for a stream that passes validation, the lowered
function computes block-for-block the same math as the interpreter (same
halo slicing, same horizontal padding, same U-space weight pre-transform,
same dtype casts), so outputs agree to float-associativity tolerance.

Backends: lowering emits each block's compute through one of two PE
implementations, selected by ``backend=``:

* ``"xla"`` (default) — plain ``lax``/``jnp`` ops. GSPMD-partitionable, so
  the lowered function can live inside a pjit-sharded model.
* ``"pallas"`` — the Pallas PE kernels (``kernels/spatial_conv`` for
  Spatial CONV, ``kernels/winograd`` + ``kernels/gemm`` for Winograd CONV,
  ``kernels/gemm`` for FC). ``interpret=None`` auto-selects interpret mode
  off-TPU (``kernels.common.INTERPRET``) so the same Program runs on the
  CPU test container; pass ``interpret=False`` to force compiled lowering.

Both backends lower the identical blocked schedule — only the per-block PE
changes — and are asserted equal (to tolerance) over full reduced VGG16 in
``tests/test_backend_pallas.py``. POOL blocks always lower through
``lax.reduce_window``: pooling is comparisons, not PE MACs, in the paper's
architecture (Sec. 4.2). See ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import layouts
from repro.core.compiler import CompiledLayer, Program
from repro.core.hybrid_conv import dense, hybrid_conv2d, max_pool2d
from repro.core.isa import Opcode, unpack_fc_dims
from repro.core.winograd import transform_weights, winograd_apply_pretransformed


class HazardError(RuntimeError):
    """Instruction-stream hazard: the handshake FIFO discipline was violated.

    Shared by the interpreter and the validation pass (``runtime.py``
    re-exports this class so existing ``except HazardError`` sites keep
    working).
    """


BACKENDS = ("xla", "pallas")


def resolve_backend(backend: str, interpret: bool | None
                    ) -> tuple[str, bool | None]:
    """Normalize a ``(backend, interpret)`` pair to its effective value.

    ``interpret`` only means something on the Pallas backend; ``None`` there
    resolves to ``kernels.common.INTERPRET`` (interpret mode everywhere but
    real TPU). Passing a non-None ``interpret`` with ``backend="xla"`` is a
    contradiction — the XLA lowering would silently ignore it and the
    caller would believe the Pallas interpret path was exercised — so it
    raises instead. The resolved pair is what joins the program-cache key,
    so an auto-selected fallback and an explicit one share a cache entry.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}: expected one of {BACKENDS}")
    if backend == "xla":
        if interpret is not None:
            raise ValueError(
                f"interpret={interpret!r} has no effect with backend='xla' "
                f"— pass backend='pallas' or drop interpret")
        return "xla", None
    if interpret is None:
        from repro.kernels.common import INTERPRET
        return "pallas", INTERPRET
    return "pallas", bool(interpret)


def _fresh_stats() -> dict[str, int]:
    return {"load_inp": 0, "load_wgt": 0, "load_bias": 0,
            "comp": 0, "pool": 0, "fc": 0, "save": 0,
            "inp_words": 0, "wgt_words": 0}


# ---------------------------------------------------------------------------
# Phase 1: schedule validation (symbolic replay, no tensors)
# ---------------------------------------------------------------------------

def validate_schedule(program: Program) -> dict[str, int]:
    """Replay the hazard/FIFO discipline once, without any compute.

    Mirrors ``HybridRuntime``'s checks exactly — the tags that the
    interpreter attaches to tensor payloads are tracked here on their own.
    Returns the pipeline statistics counters (same keys as
    ``HybridRuntime.stats``); raises :class:`HazardError` on the first
    violation.
    """
    stats = _fresh_stats()
    inp_tags: list[tuple | None] = [None, None]
    wgt_tags: list[tuple | None] = [None, None]
    bias_tag: tuple | None = None
    out_blocks: set[tuple[int, int]] = set()
    saved_any = False
    cur_layer = -1

    def flush(layer_id: int):
        if out_blocks:
            raise HazardError(
                f"layer {layer_id}: {len(out_blocks)} COMP blocks never SAVEd")
        if not saved_any:
            raise HazardError(f"layer {layer_id}: no SAVE executed")

    for ins in program.instructions:
        cl = program.layers[ins.layer_id]
        if ins.layer_id != cur_layer:
            if cur_layer >= 0:
                flush(cur_layer)
            cur_layer = ins.layer_id
            out_blocks = set()
            saved_any = False

        op = ins.opcode
        if op == Opcode.LOAD_BIAS:
            bias_tag = (ins.layer_id,)
            stats["load_bias"] += 1
        elif op == Opcode.LOAD_INP:
            ih, slot = ins.buff_base >> 1, ins.buff_base & 1
            inp_tags[slot] = (ins.layer_id, ih)
            stats["load_inp"] += 1
            stats["inp_words"] += ins.size
        elif op == Opcode.LOAD_WGT:
            kg, slot = ins.buff_base >> 1, ins.buff_base & 1
            wgt_tags[slot] = (ins.layer_id, kg)
            stats["load_wgt"] += 1
            stats["wgt_words"] += ins.size
        elif op == Opcode.COMP:
            ih = ins.size & 0xFFF
            kg = (ins.size >> 12) & 0xFFF
            islot = (ins.size >> 24) & 1
            wslot = (ins.size >> 25) & 1
            if inp_tags[islot] != (ins.layer_id, ih):
                raise HazardError(
                    f"COMP L{ins.layer_id} row-group {ih}: input slot "
                    f"{islot} holds {inp_tags[islot]}")
            if wgt_tags[wslot] != (ins.layer_id, kg):
                raise HazardError(
                    f"COMP L{ins.layer_id} k-group {kg}: weight slot "
                    f"{wslot} holds {wgt_tags[wslot]}")
            if bias_tag != (ins.layer_id,):
                raise HazardError(f"COMP L{ins.layer_id}: stale bias buffer")
            out_blocks.add((ih, kg))
            stats["comp"] += 1
        elif op == Opcode.POOL:
            islot = ins.buff_base & 1
            cfg = (ins.pool_window, ins.pool_stride)
            if cfg != (cl.spec.window, cl.spec.stride):
                raise HazardError(
                    f"POOL L{ins.layer_id}: word0 window/stride {cfg} "
                    f"disagree with compiled spec "
                    f"({cl.spec.window}, {cl.spec.stride})")
            if inp_tags[islot] != (ins.layer_id, 0):
                raise HazardError(
                    f"POOL L{ins.layer_id}: input slot {islot} holds "
                    f"{inp_tags[islot]}")
            out_blocks.add((0, 0))
            stats["pool"] += 1
        elif op == Opcode.FC:
            islot = ins.buff_base & 1
            wslot = (ins.buff_base >> 1) & 1
            dims = unpack_fc_dims(ins.size)
            if dims != (cl.spec.d_in, cl.spec.d_out):
                raise HazardError(
                    f"FC L{ins.layer_id}: word3 dims {dims} disagree with "
                    f"compiled spec ({cl.spec.d_in}, {cl.spec.d_out})")
            if inp_tags[islot] != (ins.layer_id, 0):
                raise HazardError(
                    f"FC L{ins.layer_id}: input slot {islot} holds "
                    f"{inp_tags[islot]}")
            if wgt_tags[wslot] != (ins.layer_id, 0):
                raise HazardError(
                    f"FC L{ins.layer_id}: weight slot {wslot} holds "
                    f"{wgt_tags[wslot]}")
            if bias_tag != (ins.layer_id,):
                raise HazardError(f"FC L{ins.layer_id}: stale bias buffer")
            out_blocks.add((0, 0))
            stats["fc"] += 1
        elif op == Opcode.SAVE:
            ih = ins.size & 0xFFF
            kg = (ins.size >> 12) & 0xFFF
            if cl.kind != "conv":
                need = [(0, 0)]
            elif cl.plan.dataflow == "is":
                need = [(ih, g) for g in range(len(cl.k_groups))]
            else:
                need = [(ih, kg)]
            for key in need:
                if key not in out_blocks:
                    raise HazardError(
                        f"SAVE L{ins.layer_id} block {key} not computed")
                out_blocks.discard(key)
            saved_any = True
            stats["save"] += 1
        else:
            raise ValueError(op)

    if cur_layer >= 0:
        flush(cur_layer)
    else:
        raise HazardError("empty instruction stream")
    return stats


# ---------------------------------------------------------------------------
# Phase 2: lowering to a pure, traceable function
# ---------------------------------------------------------------------------

def slice_input_rows(cl: CompiledLayer, x_nhwc: jax.Array, ih: int) -> jax.Array:
    """Static-slice the input rows (plus halo) for output row group ``ih``.

    Shared with the interpreter (``HybridRuntime._load_input_group``
    delegates here) so the two paths can never drift. Everything is
    Python-int static, so the slice lowers to a plain XLA slice.
    """
    spec = cl.spec
    r0, r1 = cl.row_groups[ih]
    pad = (spec.r - 1) // 2 if spec.padding.upper() == "SAME" else 0
    in_lo = r0 * spec.stride - pad
    in_hi = (r1 - 1) * spec.stride + spec.r - pad
    pad_top = max(0, -in_lo)
    pad_bot = max(0, in_hi - spec.h)
    sl = x_nhwc[:, max(0, in_lo):min(spec.h, in_hi)]
    if pad_top or pad_bot:
        sl = jnp.pad(sl, ((0, 0), (pad_top, pad_bot), (0, 0), (0, 0)))
    return sl


def width_pad(cl: CompiledLayer) -> tuple[int, int]:
    """Horizontal conv padding (vertical halo is materialized by the slice)."""
    if cl.spec.padding.upper() == "SAME":
        pad_w = (cl.spec.s - 1) // 2
        return (pad_w, cl.spec.s - 1 - pad_w)
    return (0, 0)


def conv_block_forward(cl: CompiledLayer, x_slab: jax.Array,
                       w_grp: jax.Array, b_grp: jax.Array, relu: bool,
                       *, backend: str = "xla",
                       interpret: bool | None = None) -> jax.Array:
    """One COMP block on the selected PE backend.

    ``x_slab`` is the row-group slice (halo included, vertical padding
    materialized); ``w_grp`` the k-group slice of the DRAM weight image
    (U-space for Winograd). Shared by the lowered executor and the strict
    interpreter's COMP handler so the two paths route through one PE
    implementation per backend.
    """
    spec, plan = cl.spec, cl.plan
    dtype = x_slab.dtype
    wpad = width_pad(cl)
    if plan.mode == "wino":
        x_p = jnp.pad(x_slab, ((0, 0), (0, 0), wpad, (0, 0)))
        if backend == "pallas":
            from repro.kernels.winograd import (
                winograd_apply_pretransformed_pallas,
            )
            return winograd_apply_pretransformed_pallas(
                x_p, w_grp, b_grp, m=plan.m, relu=relu, padding="VALID",
                dataflow=plan.dataflow, out_dtype=dtype, interpret=interpret)
        return winograd_apply_pretransformed(
            x_p, w_grp, b_grp, plan.m, relu=relu,
            padding="VALID", out_dtype=dtype)
    return hybrid_conv2d(
        x_slab, w_grp, b_grp, mode="spat",
        dataflow=plan.dataflow, stride=spec.stride,
        relu=relu, padding=((0, 0), wpad),
        use_pallas=backend == "pallas", interpret=interpret,
        out_dtype=dtype)


def _layer_forward(cl: CompiledLayer, w_eff: jax.Array, bias: jax.Array,
                   x_stored: jax.Array, relu_of, *, backend: str = "xla",
                   interpret: bool | None = None) -> jax.Array:
    """One layer as blocked compute over the compiled (row, k) groups.

    ``w_eff`` is the DRAM-resident weight image: U-space ``(PT, PT, C, K)``
    for Winograd layers, raw ``(R, S, C, K)`` for Spatial — exactly what
    ``HybridRuntime.load_params`` stores. ``relu_of(ih, kg)`` is the COMP
    instruction's RELU bit for that block (the stream is authoritative, not
    the spec — the interpreter obeys ``ins.relu_flag`` and so must we).
    """
    spec = cl.spec
    x = layouts.load_view(x_stored, cl.inp_layout, hw=(spec.h, spec.w))
    dtype = x_stored.dtype

    row_slabs = []
    for ih, (r0, r1) in enumerate(cl.row_groups):
        x_slab = slice_input_rows(cl, x, ih)
        k_blocks = []
        for kg, (lo, hi) in enumerate(cl.k_groups):
            blk = conv_block_forward(
                cl, x_slab, w_eff[..., lo:hi], bias[lo:hi], relu_of(ih, kg),
                backend=backend, interpret=interpret)
            k_blocks.append(blk[:, :r1 - r0].astype(dtype))
        row_slabs.append(k_blocks[0] if len(k_blocks) == 1
                         else jnp.concatenate(k_blocks, axis=-1))
    y = row_slabs[0] if len(row_slabs) == 1 else jnp.concatenate(row_slabs, 1)
    if cl.out_layout == "wino":
        y = layouts.save_transform(y, "wino", cl.out_m)
    return y


def pool_forward(cl: CompiledLayer, x_stored: jax.Array,
                 window: int, stride: int) -> jax.Array:
    """One POOL block: identity LOAD view -> max pool, NHWC out.

    The SAVE-side layout reorder (``out_layout == "wino"``) is applied by
    the caller — the interpreter's layer flush or the lowered executor —
    exactly as for CONV layers. Shared by both paths so they can never
    drift.
    """
    x = layouts.load_view(x_stored, cl.inp_layout, hw=(cl.spec.h, cl.spec.w))
    return max_pool2d(x, window=window, stride=stride)


def fc_forward(cl: CompiledLayer, w: jax.Array, bias: jax.Array,
               x_stored: jax.Array, relu: bool, *, backend: str = "xla",
               interpret: bool | None = None) -> jax.Array:
    """One FC layer: identity LOAD view, flatten, run the dense PE.

    ``load_view`` honors ``inp_layout`` so a hand-built stream whose
    previous layer stored tile-major WINO still flattens in NHWC order
    (compiler-emitted programs always store SPAT before FC). Shared by the
    interpreter and the lowered executor; ``backend="pallas"`` routes the
    matmul through the shared ``kernels/gemm`` PE.
    """
    x = layouts.load_view(x_stored, cl.inp_layout)
    x = x.reshape(x.shape[0], -1)
    return dense(x, w, bias, relu=relu, use_pallas=backend == "pallas",
                 interpret=interpret)


def n_param_layers(program: Program) -> int:
    """Layers that carry (w, bias) params — CONV and FC; POOL has none."""
    return sum(cl.kind != "pool" for cl in program.layers)


def check_param_count(program: Program, params: list):
    if len(params) != n_param_layers(program):
        raise ValueError(
            f"expected {n_param_layers(program)} (w, bias) entries — one per "
            f"CONV/FC layer in network order, POOL layers carry no params — "
            f"got {len(params)}")


def to_dram_params(program: Program, params: list) -> list:
    """Raw ``[(w, bias), ...]`` (one entry per *parameterized* layer — CONV
    and FC; POOL layers carry no params) -> the DRAM weight image the
    executor consumes: U-space ``(PT, PT, C, K)`` for Winograd CONV layers,
    raw for Spatial CONV and FC — identical to what
    ``HybridRuntime.load_params`` stores. Pure jax, so it is differentiable
    and may run host-side (once, the paper's offline transform) or inside a
    caller's own trace.
    """
    check_param_count(program, params)
    out = []
    it = iter(params)
    for cl in program.layers:
        if cl.kind == "pool":
            continue
        w, b = next(it)
        if cl.kind == "conv" and cl.plan.mode == "wino":
            assert cl.spec.r == 3 and cl.spec.s == 3, \
                "runtime pre-transform supports r=s=3 (VGG family)"
            w = transform_weights(w, cl.plan.m)
        out.append((w, b))
    return out


def lower_program(program: Program, *, backend: str = "xla",
                  interpret: bool | None = None
                  ) -> Callable[[list, jax.Array], jax.Array]:
    """Lower a validated schedule to ``execute(params, x_nhwc) -> y_nhwc``.

    ``params`` is the per-layer **DRAM weight image** — pre-transformed to
    U-space for Winograd layers (see :func:`to_dram_params`). Keeping the
    transform out of the traced function means steady-state calls never
    redo weight work: jit treats params as arguments, so anything computed
    from them inside the trace would re-execute every call.

    ``backend`` selects the per-block PE ("xla" or "pallas", see the module
    docstring); ``interpret`` is the Pallas interpret-mode override
    (``None`` = auto off-TPU).
    """
    backend, interpret = resolve_backend(backend, interpret)
    for cl in program.layers:
        if cl.kind == "conv" and cl.plan.mode == "wino":
            assert cl.spec.r == 3 and cl.spec.s == 3, \
                "runtime pre-transform supports r=s=3 (VGG family)"

    # the stream's COMP/FC RELU bits and POOL window/stride are the
    # authority (the compiler sets them from the spec, but hand-built or
    # decoded streams may differ per block)
    relu_bits: dict[tuple[int, int, int], bool] = {}
    pool_cfg: dict[int, tuple[int, int]] = {}
    for ins in program.instructions:
        if ins.opcode == Opcode.COMP:
            ih = ins.size & 0xFFF
            kg = (ins.size >> 12) & 0xFFF
            relu_bits[(ins.layer_id, ih, kg)] = ins.relu_flag
        elif ins.opcode == Opcode.FC:
            relu_bits[(ins.layer_id, 0, 0)] = ins.relu_flag
        elif ins.opcode == Opcode.POOL:
            pool_cfg[ins.layer_id] = (ins.pool_window, ins.pool_stride)

    def execute(params: list, x_nhwc: jax.Array) -> jax.Array:
        cl0 = program.layers[0]
        x = x_nhwc
        if cl0.inp_layout == "wino":
            x = layouts.save_transform(x, "wino", cl0.plan.m)
        pi = 0
        for cl in program.layers:
            if cl.kind == "pool":
                window, stride = pool_cfg.get(
                    cl.layer_id, (cl.spec.window, cl.spec.stride))
                x = pool_forward(cl, x, window, stride)
                if cl.out_layout == "wino":
                    x = layouts.save_transform(x, "wino", cl.out_m)
                continue
            w_eff, b = params[pi]
            pi += 1
            if cl.kind == "fc":
                x = fc_forward(cl, w_eff, b, x,
                               relu_bits.get((cl.layer_id, 0, 0),
                                             cl.spec.relu),
                               backend=backend, interpret=interpret)
            else:
                x = _layer_forward(
                    cl, w_eff, b, x,
                    lambda ih, kg, cl=cl: relu_bits.get((cl.layer_id, ih, kg),
                                                        cl.spec.relu),
                    backend=backend, interpret=interpret)
        return x

    return execute


# ---------------------------------------------------------------------------
# Compiled executor: validation + lowering + jit, with trace accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledExecutor:
    """A jitted executor for one ``(Program, batch, dtype, backend)`` entry."""
    program: Program
    stats: dict[str, int]          # schedule-validation pipeline counters
    fn: Callable                   # jitted execute(params, x)
    _trace_count: list
    backend: str = "xla"           # resolved PE backend ("xla" | "pallas")
    interpret: bool | None = None  # resolved Pallas interpret mode

    @property
    def trace_count(self) -> int:
        """How many times the underlying function was traced (retrace probe)."""
        return self._trace_count[0]

    def __call__(self, params: list, x_nhwc: jax.Array) -> jax.Array:
        """``params`` is the DRAM weight image (see :func:`to_dram_params`)."""
        return self.fn(params, x_nhwc)


def compile_executor(program: Program,
                     stats: dict[str, int] | None = None, *,
                     backend: str = "xla",
                     interpret: bool | None = None) -> CompiledExecutor:
    """Validate (unless pre-validated stats are supplied), lower, and jit.

    ``backend``/``interpret`` select the per-block PE (see
    :func:`lower_program`); the resolved pair is recorded on the returned
    executor so cache introspection can tell the paths apart.
    """
    if stats is None:
        stats = validate_schedule(program)
    backend, interpret = resolve_backend(backend, interpret)
    execute = lower_program(program, backend=backend, interpret=interpret)
    trace_count = [0]

    def traced(params, x):
        trace_count[0] += 1     # Python side effect: fires at trace time only
        return execute(params, x)

    return CompiledExecutor(program=program, stats=dict(stats),
                            fn=jax.jit(traced), _trace_count=trace_count,
                            backend=backend, interpret=interpret)
