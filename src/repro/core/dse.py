"""Design Space Exploration (paper Sec. 5.3, Table 2).

The 3-step algorithm:

  Step (1)  enumerate hardware-parameter candidates. FPGA: for each
            PT in {4, 6}, grow PI, PO, NI until a resource constraint
            (Eq. 3-5) breaks, keeping PI >= PO >= 1. TPU: enumerate GEMM
            block shapes (bm, bk, bn) and Winograd m under the VMEM
            footprint constraint — the BRAM/DSP analog.
  Step (2)  for each candidate, pick per-layer SW parameters
            (mode_l in {spat, wino}, dataflow_l in {is, ws}) by evaluating
            the latency model (Eq. 12-15) — O(N*L).
  Step (3)  select argmin_candidates sum_l T_l — O(N).

Returns the winning HW candidate plus per-layer ``LayerPlan``s directly
consumable by ``core/compiler.compile_network``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import perf_model as pm
from repro.core.compiler import NO_PLAN, LayerPlan
from repro.core.hybrid_conv import (ConvSpec, DepthwiseSpec, EltwiseSpec,
                                    FCSpec, PoolSpec)
from repro.core.winograd import pt_for


class DSEError(ValueError):
    """No feasible hardware candidate (or nothing to plan).

    Raised instead of silently returning ``None`` when Step (1) produces an
    empty candidate list — e.g. a resource budget too small for even the
    minimum PE, or a ``vmem_bytes`` below the smallest block working set.
    """


# ---------------------------------------------------------------------------
# FPGA DSE (paper-faithful)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGACandidate:
    pi: int
    po: int
    pt: int
    ni: int

    @property
    def m(self) -> int:
        return self.pt - 2


@dataclasses.dataclass
class DSEResult:
    hw: object
    plans: list[LayerPlan]
    layer_latencies: list[float]
    total_latency: float
    candidates_searched: int


def enumerate_fpga_candidates(t: pm.FPGATarget,
                              max_factor: int = 64) -> list[FPGACandidate]:
    """Step (1): grow PI, PO, NI for each PT until resources break."""
    cands = []
    for pt in (4, 6):
        m = pt - 2
        for ni in (1, 2, 3, 4, 6, 8):
            best = None
            pi = po = 1
            while True:
                grown = False
                # take turns increasing PI then PO (keeping PI >= PO)
                for attr in ("pi", "po"):
                    np_, nq = (pi * 2, po) if attr == "pi" else (pi, po * 2)
                    if np_ >= nq and np_ <= max_factor and nq <= max_factor \
                            and pm.fpga_fits(t, np_, nq, pt, m, ni):
                        pi, po = np_, nq
                        grown = True
                if not grown:
                    break
            if pm.fpga_fits(t, pi, po, pt, m, ni):
                best = FPGACandidate(pi, po, pt, ni)
            if best:
                cands.append(best)
    # canonicalize: the candidate stream must be duplicate-free however the
    # grow strategy evolves (today it appends at most one candidate per
    # (PT, NI) pair, so this is a guarded invariant, not a repair)
    return list(dict.fromkeys(cands))


def _fpga_layer_best(t: pm.FPGATarget, cand: FPGACandidate,
                     spec: ConvSpec,
                     allow_wino: bool = True) -> tuple[LayerPlan, float]:
    """Step (2): best (mode, dataflow) for one layer under one candidate.
    ``allow_wino=False`` restricts the search to spatial plans — the
    quantized PE has no int8 U-space transform, so int8 DSE must not rank
    (let alone pick) Winograd candidates it cannot execute."""
    best = None
    for mode in ("spat", "wino"):
        if mode == "wino" and not (allow_wino and spec.wino_eligible(cand.m)):
            continue
        for dataflow in ("is", "ws"):
            lat = pm.fpga_layer_latency(t, spec, cand.pi, cand.po, cand.pt,
                                        cand.m, mode, dataflow)
            if best is None or lat < best[1]:
                best = (LayerPlan(mode=mode, dataflow=dataflow, m=cand.m), lat)
    return best


LayerSpec = ConvSpec | PoolSpec | FCSpec | EltwiseSpec | DepthwiseSpec


def run_fpga_dse(t: pm.FPGATarget,
                 specs: Sequence[LayerSpec],
                 quantized: bool = False) -> DSEResult:
    if not specs:
        raise DSEError("FPGA DSE: empty layer list — nothing to plan")
    cands = enumerate_fpga_candidates(t)
    if not cands:
        raise DSEError(
            f"FPGA DSE: no hardware candidate fits {t.name} "
            f"(LUT={t.luts}, DSP={t.dsps}, BRAM18K={t.bram_18k}, "
            f"dies={t.n_dies}) — even the minimum PE (PI=PO=1, PT=4, NI=1) "
            f"exceeds the Eq. 3-5 resource budget")
    best_result = None
    for cand in cands:
        # NI instances process different images but SHARE the DRAM port
        t_inst = dataclasses.replace(t, bw=t.bw / cand.ni)
        plans, lats = [], []
        for spec in specs:
            # POOL/FC have no DSE-searchable software parameters; they
            # still contribute latency so candidates rank on the FULL net
            if isinstance(spec, PoolSpec):
                plan, lat = NO_PLAN, pm.fpga_pool_latency(
                    t_inst, spec, cand.pi, cand.pt)
            elif isinstance(spec, FCSpec):
                plan, lat = NO_PLAN, pm.fpga_fc_latency(
                    t_inst, spec, cand.pi, cand.po, cand.pt)
            elif isinstance(spec, EltwiseSpec):
                plan, lat = NO_PLAN, pm.fpga_eltwise_latency(
                    t_inst, spec, cand.pi, cand.pt)
            elif isinstance(spec, DepthwiseSpec):
                plan, lat = NO_PLAN, pm.fpga_dw_latency(
                    t_inst, spec, cand.pi, cand.pt)
            else:
                plan, lat = _fpga_layer_best(t_inst, cand, spec,
                                             allow_wino=not quantized)
            plans.append(plan)
            lats.append(lat / cand.ni)  # throughput: NI images in flight
        total = sum(lats)
        if best_result is None or total < best_result.total_latency:
            best_result = DSEResult(cand, plans, lats, total, len(cands))
    return best_result


# ---------------------------------------------------------------------------
# TPU DSE (hardware-adapted)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUCandidate:
    bm: int            # GEMM block shapes — the PI/PO/PT analog
    bk: int
    bn: int
    m: int             # Winograd output tile (PT = m + 2)


def enumerate_tpu_candidates(t: pm.TPUTarget = pm.V5E) -> list[TPUCandidate]:
    """Step (1): block shapes growing by 2x until the VMEM working set
    (bm*bk + bk*bn + bm*bn fp32 words, x2 double-buffered) no longer fits."""
    cands = []
    for m in (2, 4):
        for bm in (128, 256, 512, 1024):
            for bk in (128, 256, 512, 1024):
                for bn in (128, 256, 512, 1024):
                    working = 4 * 2 * (bm * bk + bk * bn + bm * bn)
                    if working <= t.vmem_bytes // 2:  # margin for transforms
                        cands.append(TPUCandidate(bm, bk, bn, m))
    return cands


def _tpu_groups(spec: ConvSpec, mode: str, m: int, batch: int,
                t: pm.TPUTarget) -> tuple[int, int]:
    """Smallest (g_h, g_k) whose working set fits VMEM (Eq. 4 analog)."""
    ho, _ = spec.out_hw
    for g_h in (1, 2, 4, 8, 16):
        for g_k in (1, 2, 4, 8):
            if g_h > ho or g_k > spec.k:
                continue
            if pm.tpu_vmem_footprint(spec, mode, m, g_h, g_k, batch, t) \
                    <= t.vmem_bytes:
                return g_h, g_k
    return 16, 8


def _tpu_layer_best(t: pm.TPUTarget, cand: TPUCandidate, spec: ConvSpec,
                    batch: int,
                    allow_wino: bool = True) -> tuple[LayerPlan, float]:
    best = None
    for mode in ("spat", "wino"):
        if mode == "wino" and not (allow_wino and spec.wino_eligible(cand.m)):
            continue
        g_h, g_k = _tpu_groups(spec, mode, cand.m, batch, t)
        for dataflow in ("is", "ws"):
            lat = pm.tpu_layer_latency(t, spec, mode, dataflow, cand.m,
                                       g_h, g_k, batch,
                                       blocks=(cand.bm, cand.bk, cand.bn))
            if best is None or lat < best[1]:
                best = (LayerPlan(mode=mode, dataflow=dataflow, m=cand.m,
                                  g_h=g_h, g_k=g_k), lat)
    return best


def run_tpu_dse(specs: Sequence[LayerSpec], batch: int = 1,
                t: pm.TPUTarget = pm.V5E,
                quantized: bool = False) -> DSEResult:
    if not specs:
        raise DSEError("TPU DSE: empty layer list — nothing to plan")
    cands = enumerate_tpu_candidates(t)
    if not cands:
        raise DSEError(
            f"TPU DSE: no (bm, bk, bn) block shape fits {t.name}'s VMEM "
            f"budget ({t.vmem_bytes} bytes) — the smallest double-buffered "
            f"working set (bm=bk=bn=128) needs "
            f"{2 * 4 * 2 * (3 * 128 * 128)} bytes")
    best_result = None
    for cand in cands:
        plans, lats = [], []
        for spec in specs:
            if isinstance(spec, PoolSpec):
                plan, lat = NO_PLAN, pm.tpu_pool_latency(t, spec, batch)
            elif isinstance(spec, FCSpec):
                plan, lat = NO_PLAN, pm.tpu_fc_latency(
                    t, spec, batch, blocks=(cand.bm, cand.bk, cand.bn))
            elif isinstance(spec, EltwiseSpec):
                plan, lat = NO_PLAN, pm.tpu_eltwise_latency(t, spec, batch)
            elif isinstance(spec, DepthwiseSpec):
                plan, lat = NO_PLAN, pm.tpu_dw_latency(t, spec, batch)
            else:
                plan, lat = _tpu_layer_best(t, cand, spec, batch,
                                            allow_wino=not quantized)
            plans.append(plan)
            lats.append(lat)
        total = sum(lats)
        if best_result is None or total < best_result.total_latency:
            best_result = DSEResult(cand, plans, lats, total, len(cands))
    return best_result
