"""HybridDNN compiler: DNN graph + DSE plan -> 128-bit instruction stream.

``compile_network`` accepts the FULL layer sequence of a model — ``ConvSpec``
CONV layers, ``PoolSpec`` maxpools, ``FCSpec`` fully-connected layers,
``EltwiseSpec`` residual adds, and ``DepthwiseSpec`` depthwise convolutions —
and lowers it into ONE instruction stream (one ``Program``). The compiler
fully controls data movement (Sec. 4.1): DRAM buffer planning runs across
what used to be per-CONV-segment boundaries, POOL layers are a
LOAD_INP/POOL/SAVE block, FC layers a LOAD_BIAS/LOAD_INP/LOAD_WGT/FC/SAVE
block, ELTWISE layers a two-source LOAD_INP/LOAD_INP/ELTWISE_ADD/SAVE block,
and DEPTHWISE layers a LOAD_BIAS/LOAD_INP/LOAD_WGT/DEPTHWISE_CONV/SAVE
block, all under the same handshake-FIFO hazard discipline as CONV.

The network is no longer a straight line: a ``ConvSpec`` may reroute its
input (``inp_from`` — ResNet projection shortcuts read the block input) and
an ``EltwiseSpec`` names a second source (``skip_from``). DRAM activation
planning is therefore liveness-driven: every activation buffer lives until
its LAST consumer (which keeps a skip tensor live across the whole residual
block) and is then recycled through an exact-fit free list, so the
high-water mark stays close to the straight-line bump allocator's. Weights
and biases are written once by ``load_params`` before execution and are
never recycled — an activation may not alias them.

For CONV layers it implements the operation partition of Sec. 4.2.4 and the
IS/WS loop orders of Figure 4:

* feature maps are partitioned into ``G_H`` row groups (``H`` for Spatial,
  ``H/m`` for Winograd — we use a configurable group height that defaults to
  the largest on-chip-fitting slab, the paper's per-row case being the
  finest),
* weights are partitioned into ``G_K`` groups along output channels,
* IS: for each input group, stream all weight groups; WS: for each weight
  group, stream all input groups.

DRAM addresses come from a bump allocator (words); BUFF_BASE alternates
between ping-pong slots 0/1 so that LOAD(i+1) can overlap COMP(i) — the
runtime checks the resulting hazard discipline with handshake tokens.

Winograd-mode weights are written to DRAM *pre-transformed* (Sec. 4.2.3
offline transform), so LOAD_WGT sizes reproduce Eq. 8 vs Eq. 9's bandwidth
asymmetry exactly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

from repro.core.hybrid_conv import (
    ConvSpec,
    DepthwiseSpec,
    EltwiseSpec,
    FCSpec,
    PoolSpec,
    same_pad,
)
from repro.core.isa import (
    Instruction,
    Opcode,
    encode_stream,
    pack_dw_geom,
    pack_fc_dims,
)
from repro.core.layouts import layout_for_mode
from repro.core.winograd import R_WINO, pt_for


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Per-layer software parameters chosen by the DSE (Table 2)."""
    mode: str = "spat"          # "spat" | "wino"
    dataflow: str = "is"        # "is" | "ws"
    m: int = 4                  # Winograd output tile size (PT = m + 2)
    g_k: int = 1                # weight groups along output channels
    g_h: int = 1                # input-row groups


@dataclasses.dataclass(frozen=True)
class CompiledLayer:
    spec: ConvSpec | PoolSpec | FCSpec | EltwiseSpec | DepthwiseSpec
    plan: LayerPlan
    layer_id: int
    inp_addr: int               # DRAM base of this layer's input fmap
    wgt_addr: int               # DRAM base of (possibly transformed) weights
    bias_addr: int              # (-1 for layers without weights/bias)
    out_addr: int
    inp_layout: str             # layout the input is stored in ("spat"/"wino")
    out_layout: str             # layout SAVE writes for the next layer
    out_m: int                  # tile size of the WINO out layout (next layer's m)
    # derived group geometry
    row_groups: tuple[tuple[int, int], ...]   # output-row ranges per group
    k_groups: tuple[tuple[int, int], ...]     # output-channel ranges
    kind: str = "conv"          # "conv" | "pool" | "fc" | "eltwise" | "dw"
    # dataflow wiring (skip connections / rerouted inputs)
    inp_src: int = -2           # producer layer id of the primary input
    #                             (-1 = network input; -2 = "previous layer",
    #                             the legacy sentinel for layers built
    #                             without explicit wiring)
    skip_src: int = -2          # ELTWISE only: producer of the skip operand
    skip_addr: int = -1         # ELTWISE only: DRAM base of the skip operand
    skip_layout: str = "spat"   # layout the skip operand is stored in

    def primary_src(self) -> int:
        """Producer layer id of the primary input (-1 = network input)."""
        return self.layer_id - 1 if self.inp_src == -2 else self.inp_src


@dataclasses.dataclass
class Program:
    instructions: list[Instruction]
    layers: list[CompiledLayer]
    dram_size_words: int
    _schedule_key: str | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def instruction_image(self) -> np.ndarray:
        """The encoded uint32[n, 4] instruction-memory image — the on-disk /
        on-device representation (``Accelerator.save_program`` persists it
        and verifies a recompilation reproduces it bit-exactly)."""
        return encode_stream(self.instructions)

    def schedule_key(self) -> str:
        """Content hash of the schedule — the program-cache identity.

        Covers the encoded 128-bit instruction image plus every static
        field the executor lowers against (spec, plan, group geometry,
        layouts); DRAM addresses are deliberately included via the encoded
        stream so two programs only alias if their streams are bit-equal.
        """
        if self._schedule_key is None:
            h = hashlib.sha256()
            h.update(encode_stream(self.instructions).tobytes())
            for cl in self.layers:
                h.update(repr((cl.kind, cl.spec, cl.plan, cl.row_groups,
                               cl.k_groups, cl.inp_layout, cl.out_layout,
                               cl.out_m, cl.inp_src, cl.skip_src,
                               cl.skip_layout)).encode())
            self._schedule_key = h.hexdigest()
        return self._schedule_key


def _split(total: int, groups: int, align: int = 1) -> list[tuple[int, int]]:
    """Split [0, total) into ~equal ranges aligned to ``align``."""
    groups = max(1, min(groups, math.ceil(total / align)))
    base = math.ceil(total / groups / align) * align
    out = []
    lo = 0
    while lo < total:
        hi = min(total, lo + base)
        out.append((lo, hi))
        lo = hi
    return out


def _wgt_words(spec: ConvSpec, plan: LayerPlan, k_lo: int, k_hi: int) -> int:
    """Weight transfer size in words; Winograd weights are pre-transformed
    (ceil(R/r)*ceil(S/r)*PT^2 words per (c,k) — Eq. 9's numerator)."""
    kk = k_hi - k_lo
    if plan.mode == "wino":
        pt = pt_for(plan.m)
        nr = math.ceil(spec.r / R_WINO) * math.ceil(spec.s / R_WINO)
        return kk * spec.c * nr * pt * pt
    return kk * spec.c * spec.r * spec.s


def _inp_words(spec: ConvSpec, row_lo: int, row_hi: int) -> int:
    """Input rows needed for output rows [row_lo, row_hi) incl. halo."""
    pad = (same_pad(spec.h, spec.r, spec.stride)[0]
           if spec.padding.upper() == "SAME" else 0)
    in_lo = max(0, row_lo * spec.stride - pad)
    in_hi = min(spec.h, (row_hi - 1) * spec.stride + spec.r - pad)
    return (in_hi - in_lo) * spec.w * spec.c


def _kind(spec) -> str:
    if isinstance(spec, PoolSpec):
        return "pool"
    if isinstance(spec, FCSpec):
        return "fc"
    if isinstance(spec, EltwiseSpec):
        return "eltwise"
    if isinstance(spec, DepthwiseSpec):
        return "dw"
    return "conv"


def _sources(lid: int, spec) -> list[int]:
    """Producer layer ids layer ``lid`` reads (-1 = network input).

    The first entry is always the primary input; an ``EltwiseSpec``
    additionally reads its ``skip_from`` operand.
    """
    if isinstance(spec, ConvSpec) and spec.inp_from is not None:
        srcs = [spec.inp_from]
    else:
        srcs = [lid - 1]
    if isinstance(spec, EltwiseSpec):
        srcs.append(spec.skip_from)
    return srcs


def _out_shape(spec) -> tuple[int, int, int] | None:
    """(ho, wo, channels) of a layer's output fmap; None for FC (a vector
    output cannot feed a skip connection or a rerouted conv)."""
    if isinstance(spec, FCSpec):
        return None
    ho, wo = spec.out_hw
    ch = spec.k if isinstance(spec, ConvSpec) else spec.c
    return (ho, wo, ch)


# fixed plan for layers the DSE does not parameterize (pool/fc); the DSE
# emits the same sentinel so DSE-produced and compiler-normalized
# CompiledLayer.plan (and thus schedule keys) can never drift
NO_PLAN = LayerPlan("spat", "is")


def compile_network(
    specs: list[ConvSpec | PoolSpec | FCSpec | EltwiseSpec | DepthwiseSpec],
    plans: list[LayerPlan | None],
    *,
    input_layout: str | None = None,
) -> Program:
    """Compile a full layer chain (CONV / POOL / FC / ELTWISE / DEPTHWISE)
    into ONE instruction stream.

    ``plans`` aligns with ``specs``; entries for non-CONV layers are ignored
    (``None`` is accepted). The LOAD module only performs identity loads
    (Sec. 4.3), so the network input must be stored in the layout of layer
    0's mode — the runtime's ``write_input`` does that host-side conversion.
    SAVE always writes the layout the *next consumer* wants: tile-major WINO
    only when the sole consumer is the sequential next CONV in Winograd
    mode; outputs with a skip/rerouted consumer (or a POOL/FC/ELTWISE/DW
    successor) store SPAT.

    DRAM activation buffers are liveness-planned: each fmap lives until its
    LAST consumer (an ``EltwiseSpec.skip_from`` or ``ConvSpec.inp_from``
    reference extends the producer's lifetime across the residual block),
    then its address range is recycled through an exact-fit free list.
    """
    assert len(specs) == len(plans)
    plans = [NO_PLAN if _kind(s) != "conv" else p
             for s, p in zip(specs, plans)]
    if input_layout is None:
        input_layout = (layout_for_mode(plans[0].mode)
                        if _kind(specs[0]) == "conv" else "spat")

    # -- dataflow graph: sources, consumers, liveness -------------------
    consumers: dict[int, list[int]] = {}
    for lid, spec in enumerate(specs):
        srcs = _sources(lid, spec)
        # the primary source is explicitly wired only via ConvSpec.inp_from;
        # every extra source (an EltwiseSpec skip) is explicit by definition
        explicit = [isinstance(spec, ConvSpec) and spec.inp_from is not None]
        explicit += [True] * (len(srcs) - 1)
        for src, exp in zip(srcs, explicit):
            if not -1 <= src < lid:
                raise ValueError(
                    f"layer {lid} ({spec.name!r}) reads layer {src}: "
                    f"sources must be earlier layers (-1 = network input)")
            if exp and src >= 0 and _out_shape(specs[src]) is None:
                raise ValueError(
                    f"layer {lid} ({spec.name!r}) reads FC layer {src} "
                    f"({specs[src].name!r}): an FC output cannot feed a "
                    f"skip/rerouted fmap consumer")
            consumers.setdefault(src, []).append(lid)
    last_use = {src: max(lids) for src, lids in consumers.items()}

    def src_shape(src: int) -> tuple[int, int, int] | None:
        if src == -1:
            s0 = specs[0]
            return None if _kind(s0) == "fc" else (s0.h, s0.w, s0.c)
        return _out_shape(specs[src])

    def check_operand(lid: int, spec, src: int, operand: str):
        have = src_shape(src)
        want = (spec.h, spec.w, spec.c)
        if have != want:
            raise ValueError(
                f"layer {lid} ({spec.name!r}) {operand} reads layer {src} "
                f"shaped {have}, expected {want}")

    instrs: list[Instruction] = []
    layers: list[CompiledLayer] = []
    alloc = 0
    free: list[tuple[int, int]] = []    # recycled activation (addr, words)

    def bump(words: int) -> int:
        nonlocal alloc
        base = alloc
        alloc += words
        return base

    def alloc_act(words: int) -> int:
        # exact-fit reuse of DEAD activation buffers only. Weights/biases
        # always bump: load_params writes them once before execution, so a
        # run-time activation write may never alias them.
        for i, (addr, w) in enumerate(free):
            if w == words:
                free.pop(i)
                return addr
        return bump(words)

    def out_layout_for(lid: int) -> tuple[str, int]:
        """Layout SAVE(lid) writes = what the consumer's LOAD wants."""
        cons = consumers.get(lid, [])
        if (cons == [lid + 1] and _kind(specs[lid + 1]) == "conv"
                and specs[lid + 1].inp_from is None):
            nxt = plans[lid + 1]
            layout = layout_for_mode(nxt.mode)
            return layout, (nxt.m if layout == "wino" else 0)
        return "spat", 0

    # allocate DRAM: input of layer 0, then per layer (weights, bias, output)
    s0 = specs[0]
    in_words = s0.d_in if _kind(s0) == "fc" else s0.h * s0.w * s0.c
    # produced[src] = (addr, words, stored layout) of every fmap a
    # not-yet-executed consumer may still read; entries are popped when
    # their last consumer retires, so a stale read is a loud KeyError
    produced: dict[int, tuple[int, int, str]] = {
        -1: (bump(in_words), in_words, input_layout)}

    for lid, (spec, plan) in enumerate(zip(specs, plans)):
        kind = _kind(spec)
        out_layout, out_m = out_layout_for(lid)
        psrc = _sources(lid, spec)[0]
        if kind == "conv" and spec.inp_from is not None:
            check_operand(lid, spec, psrc, "input (inp_from)")
        inp_addr, _, inp_layout = produced[psrc]

        def finish(cl: CompiledLayer, words: int):
            """Register the layer + its output fmap, retire dead sources."""
            layers.append(cl)
            produced[lid] = (cl.out_addr, words, cl.out_layout)
            for src in set(_sources(lid, spec)):
                if last_use.get(src) == lid:
                    addr, w, _ = produced.pop(src)
                    free.append((addr, w))

        if kind == "pool":
            ho, wo = spec.out_hw
            out_addr = alloc_act(ho * wo * spec.c)
            cl = CompiledLayer(
                spec=spec, plan=plan, layer_id=lid, kind="pool",
                inp_addr=inp_addr, wgt_addr=-1, bias_addr=-1,
                out_addr=out_addr, inp_layout=inp_layout,
                out_layout=out_layout, out_m=out_m, inp_src=psrc,
                row_groups=((0, ho),), k_groups=((0, spec.c),))
            instrs.append(Instruction(
                Opcode.LOAD_INP, buff_base=0, dram_base=inp_addr,
                size=spec.h * spec.w * spec.c, layer_id=lid))
            instrs.append(Instruction(
                Opcode.POOL, pool_window=spec.window,
                pool_stride=spec.stride, buff_base=0, layer_id=lid))
            instrs.append(Instruction(
                Opcode.SAVE, buff_base=0, dram_base=out_addr,
                layout_out_wino=(out_layout == "wino"), layer_id=lid))
            finish(cl, ho * wo * spec.c)
            continue

        if kind == "fc":
            wgt_addr = bump(spec.d_in * spec.d_out)
            bias_addr = bump(spec.d_out)
            out_addr = alloc_act(spec.d_out)
            cl = CompiledLayer(
                spec=spec, plan=plan, layer_id=lid, kind="fc",
                inp_addr=inp_addr, wgt_addr=wgt_addr, bias_addr=bias_addr,
                out_addr=out_addr, inp_layout=inp_layout,
                out_layout="spat", out_m=0, inp_src=psrc,
                row_groups=((0, 1),), k_groups=((0, spec.d_out),))
            instrs.append(Instruction(
                Opcode.LOAD_BIAS, buff_base=0, dram_base=bias_addr,
                size=spec.d_out, layer_id=lid))
            instrs.append(Instruction(
                Opcode.LOAD_INP, buff_base=0, dram_base=inp_addr,
                size=spec.d_in, layer_id=lid))
            instrs.append(Instruction(
                Opcode.LOAD_WGT, buff_base=0, dram_base=wgt_addr,
                size=spec.d_in * spec.d_out, layer_id=lid))
            instrs.append(Instruction(
                Opcode.FC, buff_base=0, relu_flag=spec.relu,
                size=pack_fc_dims(spec.d_in, spec.d_out), layer_id=lid))
            instrs.append(Instruction(
                Opcode.SAVE, buff_base=0, dram_base=out_addr,
                relu_flag=spec.relu, layer_id=lid))
            finish(cl, spec.d_out)
            continue

        if kind == "eltwise":
            ssrc = spec.skip_from
            check_operand(lid, spec, psrc, "primary operand")
            check_operand(lid, spec, ssrc, "skip operand")
            skip_addr, _, skip_layout = produced[ssrc]
            n_el = spec.h * spec.w * spec.c
            out_addr = alloc_act(n_el)
            cl = CompiledLayer(
                spec=spec, plan=plan, layer_id=lid, kind="eltwise",
                inp_addr=inp_addr, wgt_addr=-1, bias_addr=-1,
                out_addr=out_addr, inp_layout=inp_layout,
                out_layout=out_layout, out_m=out_m,
                inp_src=psrc, skip_src=ssrc, skip_addr=skip_addr,
                skip_layout=skip_layout,
                row_groups=((0, spec.h),), k_groups=((0, spec.c),))
            # two-source block: primary in input slot 0 (tag (lid, 0)),
            # skip in input slot 1 (tag (lid, 1)); the ELTWISE word names
            # both slots in BUFF_BASE and the skip DRAM base in word2 so
            # the stream is a self-checking two-operand read
            instrs.append(Instruction(
                Opcode.LOAD_INP, buff_base=(0 << 1) | 0,
                dram_base=inp_addr, size=n_el, layer_id=lid))
            instrs.append(Instruction(
                Opcode.LOAD_INP, buff_base=(1 << 1) | 1,
                dram_base=skip_addr, size=n_el, layer_id=lid))
            instrs.append(Instruction(
                Opcode.ELTWISE_ADD, buff_base=0 | (1 << 1),
                dram_base=skip_addr, size=n_el,
                relu_flag=spec.relu, layer_id=lid))
            instrs.append(Instruction(
                Opcode.SAVE, buff_base=0, dram_base=out_addr,
                layout_out_wino=(out_layout == "wino"),
                relu_flag=spec.relu, layer_id=lid))
            finish(cl, n_el)
            continue

        if kind == "dw":
            ho, wo = spec.out_hw
            wgt_addr = bump(spec.r * spec.s * spec.c)
            bias_addr = bump(spec.c)
            out_addr = alloc_act(ho * wo * spec.c)
            cl = CompiledLayer(
                spec=spec, plan=plan, layer_id=lid, kind="dw",
                inp_addr=inp_addr, wgt_addr=wgt_addr, bias_addr=bias_addr,
                out_addr=out_addr, inp_layout=inp_layout,
                out_layout=out_layout, out_m=out_m, inp_src=psrc,
                row_groups=((0, ho),), k_groups=((0, spec.c),))
            instrs.append(Instruction(
                Opcode.LOAD_BIAS, buff_base=0, dram_base=bias_addr,
                size=spec.c, layer_id=lid))
            instrs.append(Instruction(
                Opcode.LOAD_INP, buff_base=0, dram_base=inp_addr,
                size=spec.h * spec.w * spec.c, layer_id=lid))
            instrs.append(Instruction(
                Opcode.LOAD_WGT, buff_base=0, dram_base=wgt_addr,
                size=spec.r * spec.s * spec.c, layer_id=lid))
            instrs.append(Instruction(
                Opcode.DEPTHWISE_CONV, buff_base=0,
                size=pack_dw_geom(spec.r, spec.s, spec.stride),
                relu_flag=spec.relu, layer_id=lid))
            instrs.append(Instruction(
                Opcode.SAVE, buff_base=0, dram_base=out_addr,
                layout_out_wino=(out_layout == "wino"),
                relu_flag=spec.relu, layer_id=lid))
            finish(cl, ho * wo * spec.c)
            continue

        ho, wo = spec.out_hw
        wgt_addr = bump(_wgt_words(spec, plan, 0, spec.k))
        bias_addr = bump(spec.k)
        out_addr = alloc_act(ho * wo * spec.k)

        align = plan.m if plan.mode == "wino" else 1
        row_groups = tuple(_split(ho, plan.g_h, align))
        k_groups = tuple(_split(spec.k, plan.g_k))

        cl = CompiledLayer(
            spec=spec, plan=plan, layer_id=lid,
            inp_addr=inp_addr, wgt_addr=wgt_addr, bias_addr=bias_addr,
            out_addr=out_addr, inp_layout=inp_layout, out_layout=out_layout,
            out_m=out_m, inp_src=psrc,
            row_groups=row_groups, k_groups=k_groups)

        wino_f = plan.mode == "wino"
        ws = plan.dataflow == "ws"
        common = dict(wino_flag=wino_f, dataflow_ws=ws, m_tile=plan.m if wino_f else 0,
                      layer_id=lid)

        instrs.append(Instruction(Opcode.LOAD_BIAS, buff_base=0,
                                  dram_base=bias_addr, size=spec.k, **common))

        def li(ih, slot):
            lo, hi = row_groups[ih]
            return Instruction(Opcode.LOAD_INP, buff_base=(ih << 1) | slot,
                               dram_base=inp_addr, size=_inp_words(spec, lo, hi),
                               **common)

        def lw(kg, slot):
            lo, hi = k_groups[kg]
            return Instruction(Opcode.LOAD_WGT, buff_base=(kg << 1) | slot,
                               dram_base=wgt_addr,
                               size=_wgt_words(spec, plan, lo, hi), **common)

        def comp(ih, kg, islot, wslot):
            # SIZE packs (row-group, k-group, buffer slots) for the runtime
            packed = ih | (kg << 12) | (islot << 24) | (wslot << 25)
            return Instruction(Opcode.COMP, buff_base=islot, size=packed,
                               relu_flag=spec.relu, **common)

        def save(ih, kg):
            packed = ih | (kg << 12)
            return Instruction(
                Opcode.SAVE, buff_base=0, dram_base=out_addr, size=packed,
                layout_out_wino=(out_layout == "wino"), relu_flag=spec.relu,
                **common)

        if not ws:  # Input Stationary (Fig. 4 left): inputs outer
            for ih in range(len(row_groups)):
                instrs.append(li(ih, ih % 2))
                for kg in range(len(k_groups)):
                    instrs.append(lw(kg, kg % 2))
                    instrs.append(comp(ih, kg, ih % 2, kg % 2))
                instrs.append(save(ih, 0))   # full-K row slab
        else:       # Weight Stationary: weights outer, inputs re-streamed
            for kg in range(len(k_groups)):
                instrs.append(lw(kg, kg % 2))
                for ih in range(len(row_groups)):
                    instrs.append(li(ih, ih % 2))
                    instrs.append(comp(ih, kg, ih % 2, kg % 2))
                    instrs.append(save(ih, kg))  # (row, K-group) block

        finish(cl, ho * wo * spec.k)

    return Program(instructions=instrs, layers=layers, dram_size_words=alloc)
