"""Analytical performance & resource models (paper Sec. 5.1-5.2).

Two backends:

* ``FPGATarget`` — Eq. 3-15 verbatim. This is the *paper-faithful* model; the
  profiling constants (alpha, beta, gamma, delta — "pre-defined through
  profiling" in Sec. 5.1) are calibrated against Table 3/4 so the benchmark
  suite can reproduce the paper's own VU9P / PYNQ-Z1 numbers and the DSE can
  re-derive the paper's chosen configurations (PI=4, PO=4, PT=6, NI=6 on
  VU9P).

* ``TPUTarget`` — the hardware-adapted model. BRAM -> VMEM footprint,
  DSP count -> MXU peak with an alignment-efficiency factor, DDR BW -> HBM BW,
  NI instances -> data-parallel shards. The latency equations keep the
  paper's exact max(compute, load_inp, load_wgt, save) + penalty structure
  (Eq. 12-15); only the rate constants change.

All latencies are in seconds, sizes in bytes unless suffixed ``_words``.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hybrid_conv import (
    ConvSpec,
    DepthwiseSpec,
    EltwiseSpec,
    FCSpec,
    PoolSpec,
)
from repro.core.winograd import R_WINO, pt_for


# ---------------------------------------------------------------------------
# Hardware targets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGATarget:
    """An FPGA device for the verbatim Eq. 3-15 model."""
    name: str
    luts: int
    dsps: int
    bram_18k: int
    freq: float                 # Hz
    bw: float                   # external memory words/s (DATA_WIDTH words)
    data_width: int = 12        # bits (paper: 12-bit fixed)
    bram_width: int = 18        # bits per BRAM instance port
    # profiling constants (Sec. 5.1), calibrated against Table 3
    alpha: float = 4.0          # quantization correction (per-PO m^2 DSPs)
    beta: float = 24.0          # address-generation DSPs
    gamma: float = 124.7        # LUTs per MAC unit (solved from Table 3's
                                # two published LUT points)
    delta: float = 0.04         # LUT correction for the m^2 transform adders
    dsp_per_mac: float = 1.0    # <1 when packing two low-bit MACs per DSP
    n_dies: int = 1             # SLRs: one accelerator instance must fit a
                                # single die (cross-die routing breaks timing,
                                # Sec. 1 — the reason VU9P runs 6 instances)

    def int8_variant(self) -> "FPGATarget":
        """This device's constants under int8 arithmetic: 8-bit words
        (narrower BRAM partitions and 1.5x more words/s through the same
        byte bandwidth) and two packed MACs per DSP slice (the paper's
        Sec. 5.1 low-precision packing, one step further down from 12-bit)
        — so the DSE both *fits bigger PE arrays* and *streams more words*
        when ranking int8 candidates."""
        return dataclasses.replace(
            self, name=f"{self.name}-int8", data_width=8,
            dsp_per_mac=self.dsp_per_mac / 2,
            bw=self.bw * self.data_width / 8)

    def run_dse(self, specs, batch: int = 1, dtype: str = "float32"):
        """Unified ``Target`` entry point (see ``repro.api``): Step 1-3 of
        the paper's DSE for this device. ``batch`` is accepted for signature
        parity with the TPU target — the FPGA latency model is per-image
        (batch parallelism comes from the NI instances). ``dtype="int8"``
        plans against :meth:`int8_variant` with Winograd gated off (the
        U-space transform is fp-only, mirroring the paper's per-layer
        hybrid-mode choice)."""
        from repro.core.dse import run_fpga_dse
        if dtype == "int8":
            return run_fpga_dse(self.int8_variant(), specs, quantized=True)
        if dtype != "float32":
            raise ValueError(f"unsupported DSE dtype {dtype!r}")
        return run_fpga_dse(self, specs)


# bw calibrated against Table 4 (the paper does not publish its DDR4/DDR3
# bandwidths): VU9P 50e9 12-bit words/s ~= 75 GB/s (NSA.241 multi-channel
# DDR4); PYNQ-Z1 0.95e9 ~= 1.4 GB/s (DDR3-1050, 16-bit). With these the DSE
# re-derives the paper's exact configurations and GOPS within 0.2% / 8%.
VU9P = FPGATarget(
    name="VU9P", luts=1182240, dsps=6840, bram_18k=4320,
    freq=167e6, bw=50e9, dsp_per_mac=1.0, n_dies=3)
PYNQ_Z1 = FPGATarget(
    name="PYNQ-Z1", luts=53200, dsps=220, bram_18k=280,
    freq=100e6, bw=0.95e9, dsp_per_mac=0.5)


@dataclasses.dataclass(frozen=True)
class TPUTarget:
    """TPU v5e chip constants (the dry-run/roofline hardware)."""
    name: str = "v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    vmem_bytes: int = 128 * 2 ** 20
    bytes_per_word: int = 2             # bf16
    mxu_dim: int = 128                  # systolic edge; alignment unit
    sublane: int = 8
    vpu_flops: float = 4 * 985e9        # VPU lanes for the Winograd transforms

    def int8_variant(self) -> "TPUTarget":
        """This chip's constants under int8 arithmetic: 1-byte words through
        the memory system and double the MXU MAC rate (int8 ops run at 2x
        the bf16 peak on v5e-class parts) — halves every bandwidth-bound
        term and the compute-bound term alike when ranking int8 plans."""
        return dataclasses.replace(
            self, name=f"{self.name}-int8", bytes_per_word=1,
            peak_flops=2 * self.peak_flops)

    def run_dse(self, specs, batch: int = 1, dtype: str = "float32"):
        """Unified ``Target`` entry point (see ``repro.api``): enumerate GEMM
        block candidates under this chip's VMEM budget and plan per-layer
        (mode, dataflow, m, g_h, g_k) at the given serving batch.
        ``dtype="int8"`` plans against :meth:`int8_variant` with Winograd
        gated off (no int8 U-space transform)."""
        from repro.core.dse import run_tpu_dse
        if dtype == "int8":
            return run_tpu_dse(specs, batch=batch, t=self.int8_variant(),
                               quantized=True)
        if dtype != "float32":
            raise ValueError(f"unsupported DSE dtype {dtype!r}")
        return run_tpu_dse(specs, batch=batch, t=self)


V5E = TPUTarget()


# ---------------------------------------------------------------------------
# FPGA resource model — Eq. 3, 4, 5 verbatim
# ---------------------------------------------------------------------------

def fpga_dsp(t: FPGATarget, pi: int, po: int, pt: int, m: int) -> float:
    """Eq. 3: N_DSP = PI*PO*PT^2 + alpha*PO*m^2 + PO + beta."""
    return (pi * po * pt * pt) * t.dsp_per_mac + t.alpha * po * m * m + po + t.beta


def fpga_bram(t: FPGATarget, pi: int, po: int, pt: int, m: int) -> float:
    """Eq. 4."""
    return (t.data_width / t.bram_width) * (
        pi * pt * pt + pi * po * pt * pt + (1 + t.alpha) * po * m * m)


def fpga_lut(t: FPGATarget, pi: int, po: int, pt: int, m: int) -> float:
    """Eq. 5: N_LUT = gamma * (PI*PO*PT^2) * (1 + delta*m^2)."""
    return t.gamma * (pi * po * pt * pt) * (1 + t.delta * m * m)


def fpga_fits(t: FPGATarget, pi: int, po: int, pt: int, m: int, ni: int) -> bool:
    # one instance must fit within a single die (no cross-die PE routing)
    die = (fpga_dsp(t, pi, po, pt, m) <= t.dsps / t.n_dies
           and fpga_bram(t, pi, po, pt, m) <= t.bram_18k / t.n_dies
           and fpga_lut(t, pi, po, pt, m) <= t.luts / t.n_dies)
    total = (ni * fpga_dsp(t, pi, po, pt, m) <= t.dsps
             and ni * fpga_bram(t, pi, po, pt, m) <= t.bram_18k
             and ni * fpga_lut(t, pi, po, pt, m) <= t.luts)
    return die and total


# ---------------------------------------------------------------------------
# FPGA latency model — Eq. 6-15 verbatim
# ---------------------------------------------------------------------------

def _kernel_groups(spec: ConvSpec) -> int:
    return math.ceil(spec.r / R_WINO) * math.ceil(spec.s / R_WINO)


def fpga_t_cp(t: FPGATarget, s: ConvSpec, pi, po, pt, m, mode: str) -> float:
    ho, wo = s.out_hw
    if mode == "spat":
        # Eq. 6
        return (s.k * s.c * s.r * s.s * ho * wo) / (t.freq * pi * po * pt * pt)
    # Eq. 7
    return (s.k * s.c * _kernel_groups(s) * pt * pt * ho * wo) / (
        t.freq * pi * po * pt * pt * m * m)


def fpga_t_ldw(t: FPGATarget, s: ConvSpec, pi, po, pt, m, mode: str) -> float:
    rate = min(t.bw, t.freq * pi * po * pt)
    if mode == "spat":
        return (s.k * s.c * s.r * s.s) / rate                      # Eq. 8
    return (s.k * s.c * _kernel_groups(s) * pt * pt) / rate        # Eq. 9


def fpga_t_ldi(t: FPGATarget, s: ConvSpec, pi, pt) -> float:
    return (s.c * s.h * s.w) / min(t.bw, t.freq * pi * pt)         # Eq. 10


def fpga_t_sv(t: FPGATarget, s: ConvSpec, po, pt) -> float:
    ho, wo = s.out_hw
    return (s.k * ho * wo) / min(t.bw, t.freq * po * pt)           # Eq. 11


def fpga_pool_latency(t: FPGATarget, s: PoolSpec, pi: int, pt: int) -> float:
    """POOL streams through the LOAD path at the input rate (Eq. 10 analog):
    the comparison tree keeps up with the stream, so the layer is bound by
    reading the input map and writing the decimated output."""
    ho, wo = s.out_hw
    words = s.c * s.h * s.w + s.c * ho * wo
    return words / min(t.bw, t.freq * pi * pt)


def fpga_eltwise_latency(t: FPGATarget, s: EltwiseSpec,
                         pi: int, pt: int) -> float:
    """ELTWISE_ADD streams TWO source fmaps in and one out through the
    LOAD/SAVE datapath (Eq. 10/11 analog); the adder array keeps up with
    the stream, so the layer is pure external-memory traffic."""
    words = 3 * s.h * s.w * s.c
    return words / min(t.bw, t.freq * pi * pt)


def fpga_dw_latency(t: FPGATarget, s: DepthwiseSpec,
                    pi: int, pt: int) -> float:
    """DEPTHWISE_CONV has no output-channel reuse (one filter per channel),
    so only the PI*PT input-parallel lanes apply — the PO dimension of the
    MAC array idles. Latency is max(compute on PI*PT MACs, streaming the
    input + decimated output maps)."""
    ho, wo = s.out_hw
    t_cp = s.macs / (t.freq * pi * pt)
    words = s.h * s.w * s.c + s.r * s.s * s.c + ho * wo * s.c
    t_mem = words / min(t.bw, t.freq * pi * pt)
    return max(t_cp, t_mem)


def fpga_fc_latency(t: FPGATarget, s: FCSpec, pi, po, pt) -> float:
    """FC is a GEMV on the PE's MAC array: every weight word is used once,
    so the layer is the max of compute (Eq. 6 analog with HO*WO = 1) and
    streaming the weight matrix from external memory."""
    t_cp = s.d_in * s.d_out / (t.freq * pi * po * pt)
    t_ldw = s.d_in * s.d_out / t.bw
    return max(t_cp, t_ldw)


def fpga_layer_latency(t: FPGATarget, s: ConvSpec, pi, po, pt, m,
                       mode: str, dataflow: str,
                       g_h: int | None = None, g_k: int | None = None) -> float:
    """Eq. 12-15. g_h defaults to the paper's H (spat) or H/m (wino) groups."""
    ho, _ = s.out_hw
    if g_h is None:
        g_h = ho if mode == "spat" else math.ceil(ho / m)
    if g_k is None:
        g_k = max(1, s.k // po)
    t_cp = fpga_t_cp(t, s, pi, po, pt, m, mode)
    t_ldw = fpga_t_ldw(t, s, pi, po, pt, m, mode)
    t_ldi = fpga_t_ldi(t, s, pi, pt)
    t_sv = fpga_t_sv(t, s, po, pt)
    if dataflow == "is":
        body = max(t_ldi, g_h * t_ldw, t_cp, t_sv)                 # Eq. 12/14
        penalty = t_ldw / max(1, g_k) + t_ldi / max(1, g_h)
    else:
        body = max(g_k * t_ldi, t_ldw, t_cp, t_sv)                 # Eq. 13/15
        penalty = t_ldi / max(1, g_h) + t_ldw / max(1, g_k)
    return body + penalty


# ---------------------------------------------------------------------------
# TPU-adapted model (BRAM->VMEM, DSP->MXU, DDR->HBM)
# ---------------------------------------------------------------------------

def _align_eff(size: int, unit: int) -> float:
    """Fraction of useful work when ``size`` pads up to a multiple of ``unit``."""
    if size <= 0:
        return 1.0
    return size / (math.ceil(size / unit) * unit)


def tpu_mxu_eff(mdim: int, kdim: int, ndim: int, t: TPUTarget = V5E) -> float:
    """MXU alignment efficiency — the Eq. 3 'DSP utilization' analog."""
    return (_align_eff(mdim, t.sublane)
            * _align_eff(kdim, t.mxu_dim)
            * _align_eff(ndim, t.mxu_dim))


def tpu_gemm_dims(s: ConvSpec, mode: str, m: int, batch: int = 1):
    """(G, M, K, N) of the GEMM the PE executes for this layer."""
    ho, wo = s.out_hw
    if mode == "spat":
        return (1, batch * ho * wo, s.c * s.r * s.s, s.k)
    pt = pt_for(m)
    nt = batch * math.ceil(ho / m) * math.ceil(wo / m)
    return (_kernel_groups(s) * pt * pt, nt, s.c, s.k)


def _block_eff(size: int, block: int) -> float:
    """Useful fraction when size pads to a whole number of blocks."""
    if size <= 0:
        return 1.0
    return size / (math.ceil(size / block) * block)


def tpu_t_cp(t: TPUTarget, s: ConvSpec, mode: str, m: int,
             batch: int = 1,
             blocks: tuple[int, int, int] | None = None) -> float:
    """Transformed-domain MACs / (peak * alignment-eff) + VPU transform time.

    ``blocks=(bm, bk, bn)`` folds GEMM block-padding waste into the
    efficiency (a 130-tile M dim on bm=512 runs at 130/512 MXU efficiency) —
    the Eq. 3 'PE size vs layer size' mismatch, TPU-style.
    """
    g, md, kd, nd = tpu_gemm_dims(s, mode, m, batch)
    eff = tpu_mxu_eff(md, kd, nd)
    if blocks is not None:
        bm, bk, bn = blocks
        eff *= (_block_eff(md, bm) * _block_eff(kd, bk) * _block_eff(nd, bn))
    flops = 2.0 * g * md * kd * nd
    t_mxu = flops / (t.peak_flops * eff)
    if mode == "wino":
        pt = pt_for(m)
        # B^T d B + A^T M A: ~2*PT^3*2 flops per tile-channel on the VPU
        ho, wo = s.out_hw
        nt = batch * math.ceil(ho / m) * math.ceil(wo / m)
        t_vpu = (4.0 * pt ** 3 * nt * (s.c + s.k)) / t.vpu_flops
        return max(t_mxu, t_vpu)  # transforms overlap the MXU pipeline
    return t_mxu


def tpu_t_ldw(t: TPUTarget, s: ConvSpec, mode: str, m: int) -> float:
    if mode == "spat":
        words = s.k * s.c * s.r * s.s
    else:
        pt = pt_for(m)
        words = s.k * s.c * _kernel_groups(s) * pt * pt
    return words * t.bytes_per_word / t.hbm_bw


def tpu_t_ldi(t: TPUTarget, s: ConvSpec, batch: int = 1) -> float:
    return batch * s.c * s.h * s.w * t.bytes_per_word / t.hbm_bw


def tpu_t_sv(t: TPUTarget, s: ConvSpec, batch: int = 1) -> float:
    ho, wo = s.out_hw
    return batch * s.k * ho * wo * t.bytes_per_word / t.hbm_bw


def tpu_vmem_footprint(s: ConvSpec, mode: str, m: int,
                       g_h: int, g_k: int, batch: int = 1,
                       t: TPUTarget = V5E) -> int:
    """Bytes of on-chip working set (x2 for ping-pong) — the Eq. 4 analog."""
    ho, wo = s.out_hw
    rows = math.ceil(ho / g_h) + s.r - 1
    inp = batch * rows * s.w * s.c
    if mode == "wino":
        pt = pt_for(m)
        wgt = (s.k // g_k) * s.c * _kernel_groups(s) * pt * pt
    else:
        wgt = (s.k // g_k) * s.c * s.r * s.s
    out = batch * math.ceil(ho / g_h) * wo * (s.k // g_k)
    return 2 * (inp + wgt + out) * t.bytes_per_word


def tpu_layer_latency(t: TPUTarget, s: ConvSpec, mode: str, dataflow: str,
                      m: int = 4, g_h: int = 1, g_k: int = 1,
                      batch: int = 1,
                      blocks: tuple[int, int, int] | None = None) -> float:
    """Eq. 12-15 with TPU rate constants."""
    t_cp = tpu_t_cp(t, s, mode, m, batch, blocks)
    t_ldw = tpu_t_ldw(t, s, mode, m)
    t_ldi = tpu_t_ldi(t, s, batch)
    t_sv = tpu_t_sv(t, s, batch)
    if dataflow == "is":
        body = max(t_ldi, g_h * t_ldw, t_cp, t_sv)
        penalty = t_ldw / max(1, g_k) + t_ldi / max(1, g_h)
    else:
        body = max(g_k * t_ldi, t_ldw, t_cp, t_sv)
        penalty = t_ldi / max(1, g_h) + t_ldw / max(1, g_k)
    return body + penalty


def tpu_pool_latency(t: TPUTarget, s: PoolSpec, batch: int = 1) -> float:
    """POOL on TPU is HBM-bound: read the map, write the decimated map; the
    window-max comparisons run on the VPU and never dominate."""
    ho, wo = s.out_hw
    bytes_ = (batch * s.h * s.w * s.c + batch * ho * wo * s.c) * t.bytes_per_word
    flops = batch * ho * wo * s.c * s.window * s.window
    return max(bytes_ / t.hbm_bw, flops / t.vpu_flops)


def tpu_eltwise_latency(t: TPUTarget, s: EltwiseSpec,
                        batch: int = 1) -> float:
    """ELTWISE_ADD on TPU is HBM-bound: read two fmaps, write one; the
    per-element add runs on the VPU and never dominates."""
    n = batch * s.h * s.w * s.c
    return max(3 * n * t.bytes_per_word / t.hbm_bw, n / t.vpu_flops)


def tpu_dw_latency(t: TPUTarget, s: DepthwiseSpec, batch: int = 1) -> float:
    """DEPTHWISE_CONV on TPU is VPU work (feature_group_count=C defeats the
    MXU's contraction — there is no channel reduction to feed the systolic
    array), bounded below by streaming the maps through HBM."""
    ho, wo = s.out_hw
    flops = 2.0 * batch * s.macs
    bytes_ = (batch * (s.h * s.w + ho * wo) * s.c
              + s.r * s.s * s.c) * t.bytes_per_word
    return max(flops / t.vpu_flops, bytes_ / t.hbm_bw)


def tpu_fc_latency(t: TPUTarget, s: FCSpec, batch: int = 1,
                   blocks: tuple[int, int, int] | None = None) -> float:
    """FC as a (batch, d_in) x (d_in, d_out) GEMM on the MXU.

    At serving batch sizes the MXU runs at batch/sublane-alignment
    efficiency and the layer is usually bound by streaming the weight
    matrix from HBM — the same weight-bandwidth wall Eq. 8/9 models for
    CONV weights on the FPGA.
    """
    eff = tpu_mxu_eff(batch, s.d_in, s.d_out)
    if blocks is not None:
        bm, bk, bn = blocks
        eff *= (_block_eff(batch, bm) * _block_eff(s.d_in, bk)
                * _block_eff(s.d_out, bn))
    flops = 2.0 * batch * s.d_in * s.d_out
    bytes_ = (s.d_in * s.d_out
              + batch * (s.d_in + s.d_out)) * t.bytes_per_word
    return max(flops / (t.peak_flops * eff), bytes_ / t.hbm_bw)


def layer_gops(s: ConvSpec, latency: float, batch: int = 1) -> float:
    """Effective GOPS: *algorithmic* ops (2*MACs of the direct conv) per
    second — the paper counts Winograd speedups this way (Table 4)."""
    return 2.0 * batch * s.macs / latency / 1e9


def tpu_layer_latency_xla_ref(t: TPUTarget, s: ConvSpec, mode: str,
                              m: int = 4, batch: int = 1) -> float:
    """Latency model of the UNFUSED (XLA-reference) implementation variant.

    The fused Pallas kernel keeps Winograd transforms VMEM-resident;
    the XLA reference materializes tiles, V = B^T d B, the PT^2 GEMM output
    M, and the inverse transform in HBM. This variant models that traffic —
    it is what ``bench_model_error`` compiles and validates against, exactly
    as the paper validates its model against its implementation.
    """
    ho, wo = s.out_hw
    bpw = t.bytes_per_word
    g, md, kd, nd = tpu_gemm_dims(s, mode, m, batch)
    flops = 2.0 * g * md * kd * nd
    x_b = batch * s.h * s.w * s.c
    w_b = s.k * s.c * s.r * s.s
    y_b = batch * ho * wo * s.k
    if mode == "spat":
        patches = md * kd                    # im2col matrix (T, C*R*S)
        bytes_ = (x_b + patches * 2 + w_b + y_b) * bpw
    else:
        pt = pt_for(m)
        nt = md                              # tiles
        tiles = nt * pt * pt * s.c
        v = g * nt * s.c                     # PT^2 * T * C
        u = g * s.c * s.k
        mm = g * nt * s.k
        bytes_ = (x_b + tiles + 2 * v + u + 2 * mm + y_b) * bpw
        # VPU transform flops
        flops += 4.0 * pt ** 3 * nt * (s.c + s.k)
    return max(flops / t.peak_flops, bytes_ / t.hbm_bw)
