"""The paper's primary contribution (HybridDNN, 2020):

- winograd:     F(2,3)/F(4,3) transforms, GEMM formulation, kernel decomp.
- hybrid_conv:  the hybrid Spatial/Winograd PE with IS/WS dataflows
- isa:          the 128-bit instruction set (Fig. 2)
- compiler:     DNN graph + DSE plan -> instruction stream (Fig. 4 loops)
- runtime:      functional executor with handshake-hazard checking
- layouts:      WINO/SPAT data layouts + SAVE-side reorders (Sec. 4.3)
- perf_model:   Eq. 3-15 verbatim (FPGA) + TPU-adapted analytical models
- dse:          the 3-step design space exploration (Sec. 5.3)
"""
