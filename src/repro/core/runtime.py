"""Light-weight runtime: executes a HybridDNN instruction stream (Sec. 3 (4)).

A functional interpreter of the 128-bit ISA. It models the accelerator's
on-chip state — ping-pong input/weight buffers, a bias buffer and the
accumulating output buffer — and enforces the handshake-FIFO hazard
discipline of Sec. 4.1: COMP validates that the buffer slots it addresses
hold the (layer, group) data its operands require (the "wait for the
producer's token"), and SAVE validates that every block it flushes was
produced (the "consumer token" on the COMP->SAVE FIFO). A mis-scheduled
stream — LOAD overwriting a live slot, COMP before its LOADs, SAVE before
COMP — raises ``HazardError`` rather than silently computing garbage.

DRAM is a word-addressed store (dict base-address -> tensor). Winograd-mode
weights live in DRAM pre-transformed to U-space (Sec. 4.2.3), so LOAD_WGT
traffic matches Eq. 9. The SAVE stage applies the layout reorder for the next
layer's mode (Sec. 4.3) once the layer's last block lands.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import layouts
from repro.core.compiler import CompiledLayer, Program
from repro.core.hybrid_conv import hybrid_conv2d
from repro.core.isa import Instruction, Opcode
from repro.core.winograd import (
    pt_for,
    transform_weights,
    winograd_apply_pretransformed,
)


class HazardError(RuntimeError):
    """Instruction-stream hazard: the handshake FIFO discipline was violated."""


@dataclasses.dataclass
class _Slot:
    tag: tuple | None = None
    data: Any = None


class HybridRuntime:
    """Executes a compiled Program against DRAM-resident params and input."""

    def __init__(self, program: Program, use_pallas: bool = False,
                 interpret: bool | None = None):
        self.program = program
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.dram: dict[int, Any] = {}
        # pipeline statistics (4-stage pipeline occupancy model)
        self.stats = {"load_inp": 0, "load_wgt": 0, "load_bias": 0,
                      "comp": 0, "save": 0,
                      "inp_words": 0, "wgt_words": 0}

    # -- DRAM management ----------------------------------------------------
    def load_params(self, params: list[tuple[Any, Any]]):
        """params: [(w_rsck, bias), ...] per layer. Winograd layers store U."""
        for cl, (w, b) in zip(self.program.layers, params):
            if cl.plan.mode == "wino":
                assert cl.spec.r == 3 and cl.spec.s == 3, \
                    "runtime pre-transform supports r=s=3 (VGG family)"
                self.dram[cl.wgt_addr] = transform_weights(w, cl.plan.m)
            else:
                self.dram[cl.wgt_addr] = w
            self.dram[cl.bias_addr] = b

    def write_input(self, x_nhwc):
        cl0 = self.program.layers[0]
        if cl0.inp_layout == "wino":
            x_nhwc = layouts.save_transform(x_nhwc, "wino", cl0.plan.m)
        self.dram[cl0.inp_addr] = x_nhwc

    # -- execution ----------------------------------------------------------
    def run(self, x_nhwc=None):
        if x_nhwc is not None:
            self.write_input(x_nhwc)
        inp_slots = [_Slot(), _Slot()]
        wgt_slots = [_Slot(), _Slot()]
        bias_buf = _Slot()
        out_blocks: dict[tuple[int, int], Any] = {}
        cur_layer = -1
        staging = None           # NHWC assembly of the current layer's output

        for ins in self.program.instructions:
            cl = self.program.layers[ins.layer_id]
            if ins.layer_id != cur_layer:
                if cur_layer >= 0:
                    self._flush_layer(self.program.layers[cur_layer], staging,
                                      out_blocks)
                cur_layer = ins.layer_id
                staging = None
                out_blocks = {}

            op = ins.opcode
            if op == Opcode.LOAD_BIAS:
                bias_buf = _Slot((ins.layer_id,), self.dram[ins.dram_base])
                self.stats["load_bias"] += 1
            elif op == Opcode.LOAD_INP:
                ih, slot = ins.buff_base >> 1, ins.buff_base & 1
                data = self._load_input_group(cl, ih)
                inp_slots[slot] = _Slot((ins.layer_id, ih), data)
                self.stats["load_inp"] += 1
                self.stats["inp_words"] += ins.size
            elif op == Opcode.LOAD_WGT:
                kg, slot = ins.buff_base >> 1, ins.buff_base & 1
                lo, hi = cl.k_groups[kg]
                w = self.dram[ins.dram_base][..., lo:hi]
                wgt_slots[slot] = _Slot((ins.layer_id, kg), w)
                self.stats["load_wgt"] += 1
                self.stats["wgt_words"] += ins.size
            elif op == Opcode.COMP:
                ih = ins.size & 0xFFF
                kg = (ins.size >> 12) & 0xFFF
                islot = (ins.size >> 24) & 1
                wslot = (ins.size >> 25) & 1
                if inp_slots[islot].tag != (ins.layer_id, ih):
                    raise HazardError(
                        f"COMP L{ins.layer_id} row-group {ih}: input slot "
                        f"{islot} holds {inp_slots[islot].tag}")
                if wgt_slots[wslot].tag != (ins.layer_id, kg):
                    raise HazardError(
                        f"COMP L{ins.layer_id} k-group {kg}: weight slot "
                        f"{wslot} holds {wgt_slots[wslot].tag}")
                if bias_buf.tag != (ins.layer_id,):
                    raise HazardError(f"COMP L{ins.layer_id}: stale bias buffer")
                blk = self._compute(cl, inp_slots[islot].data,
                                    wgt_slots[wslot].data,
                                    bias_buf.data, ih, kg, ins)
                out_blocks[(ih, kg)] = blk
                self.stats["comp"] += 1
            elif op == Opcode.SAVE:
                ih = ins.size & 0xFFF
                kg = (ins.size >> 12) & 0xFFF
                ho, wo = cl.spec.out_hw
                if staging is None:
                    n = self._batch(cl)
                    staging = jnp.zeros((n, ho, wo, cl.spec.k),
                                        self._dtype(cl))
                if cl.plan.dataflow == "is":
                    # one SAVE per row group: all K groups must be computed
                    need = [(ih, g) for g in range(len(cl.k_groups))]
                else:
                    need = [(ih, kg)]
                for key in need:
                    if key not in out_blocks:
                        raise HazardError(
                            f"SAVE L{ins.layer_id} block {key} not computed")
                r0, r1 = cl.row_groups[ih]
                if cl.plan.dataflow == "is":
                    row = jnp.concatenate(
                        [out_blocks.pop((ih, g)) for g in
                         range(len(cl.k_groups))], axis=-1)
                    staging = staging.at[:, r0:r1].set(row.astype(staging.dtype))
                else:
                    c0, c1 = cl.k_groups[kg]
                    staging = staging.at[:, r0:r1, :, c0:c1].set(
                        out_blocks.pop((ih, kg)).astype(staging.dtype))
                self.stats["save"] += 1
            else:
                raise ValueError(op)

        if cur_layer >= 0:
            self._flush_layer(self.program.layers[cur_layer], staging,
                              out_blocks)
        last = self.program.layers[-1]
        return self.dram[last.out_addr]

    # -- helpers ------------------------------------------------------------
    def _batch(self, cl: CompiledLayer) -> int:
        x = self.dram[cl.inp_addr]
        return x.shape[0]

    def _dtype(self, cl: CompiledLayer):
        return self.dram[cl.inp_addr].dtype

    def _input_nhwc(self, cl: CompiledLayer):
        x = self.dram[cl.inp_addr]
        return layouts.load_view(x, cl.inp_layout, hw=(cl.spec.h, cl.spec.w))

    def _load_input_group(self, cl: CompiledLayer, ih: int):
        """Slice the input rows (plus halo) needed for output rows group ih."""
        spec = cl.spec
        x = self._input_nhwc(cl)
        r0, r1 = cl.row_groups[ih]
        pad = (spec.r - 1) // 2 if spec.padding.upper() == "SAME" else 0
        in_lo = r0 * spec.stride - pad
        in_hi = (r1 - 1) * spec.stride + spec.r - pad
        pad_top = max(0, -in_lo)
        pad_bot = max(0, in_hi - spec.h)
        sl = x[:, max(0, in_lo):min(spec.h, in_hi)]
        if pad_top or pad_bot:
            sl = jnp.pad(sl, ((0, 0), (pad_top, pad_bot), (0, 0), (0, 0)))
        return sl

    def _compute(self, cl: CompiledLayer, x_slab, w_grp, bias, ih, kg, ins):
        spec, plan = cl.spec, cl.plan
        lo, hi = cl.k_groups[kg]
        b_grp = bias[lo:hi]
        # horizontal padding only: vertical halo is already materialized
        pad_w = (spec.s - 1) // 2 if spec.padding.upper() == "SAME" else 0
        padding = ((0, 0), (pad_w, spec.s - 1 - pad_w))
        if plan.mode == "wino":
            x_p = jnp.pad(x_slab, ((0, 0), (0, 0), padding[1], (0, 0)))
            blk = winograd_apply_pretransformed(
                x_p, w_grp, b_grp, plan.m, relu=ins.relu_flag,
                padding="VALID", out_dtype=x_slab.dtype)
        else:
            blk = hybrid_conv2d(
                x_slab, w_grp, b_grp, mode="spat", dataflow=plan.dataflow,
                stride=spec.stride, relu=ins.relu_flag,
                padding=[(0, 0), padding[1]] if spec.padding.upper() == "SAME"
                else "VALID",
                use_pallas=False)
        r0, r1 = cl.row_groups[ih]
        return blk[:, :r1 - r0]

    def _flush_layer(self, cl: CompiledLayer, staging, out_blocks):
        if out_blocks:
            raise HazardError(
                f"layer {cl.layer_id}: {len(out_blocks)} COMP blocks never SAVEd")
        if staging is None:
            raise HazardError(f"layer {cl.layer_id}: no SAVE executed")
        if cl.out_layout == "wino":
            self.dram[cl.out_addr] = layouts.save_transform(
                staging, "wino", cl.out_m)
        else:
            self.dram[cl.out_addr] = staging


def run_program(program: Program, params, x_nhwc, **kw):
    rt = HybridRuntime(program, **kw)
    rt.load_params(params)
    return rt.run(x_nhwc)
