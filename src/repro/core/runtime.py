"""Light-weight runtime: executes a HybridDNN instruction stream (Sec. 3 (4)).

Two execution paths share one hazard contract:

* ``strict=True`` — the original functional interpreter of the 128-bit ISA.
  It models the accelerator's on-chip state — ping-pong input/weight buffers,
  a bias buffer and the accumulating output buffer — and enforces the
  handshake-FIFO hazard discipline of Sec. 4.1 *per instruction*: COMP
  validates that the buffer slots it addresses hold the (layer, group) data
  its operands require, and SAVE validates that every block it flushes was
  produced. A mis-scheduled stream — LOAD overwriting a live slot, COMP
  before its LOADs, SAVE before COMP — raises ``HazardError`` rather than
  silently computing garbage.

* default — the **validate-once, trace-many** path (``core/executor.py``):
  the same hazard discipline runs once per ``Program`` as a symbolic
  schedule-validation pass (same ``HazardError``s, same ``stats`` counters),
  then a pure jitted ``execute(params, x)`` — cached per
  ``(Program, batch, dtype)`` in ``core/program_cache.py`` — does the math
  as a static dataflow with no Python-level dispatch. This is how the
  hardware runs: the stream is checked when it is written, not re-checked
  every inference.

DRAM is a word-addressed store (dict base-address -> tensor). Winograd-mode
weights live in DRAM pre-transformed to U-space (Sec. 4.2.3), so LOAD_WGT
traffic matches Eq. 9. The SAVE stage applies the layout reorder for the next
layer's mode (Sec. 4.3) once the layer's last block lands.

The full-network ISA (POOL/FC/ELTWISE_ADD/DEPTHWISE_CONV opcodes) runs a
whole model — CONVs, maxpools, residual adds, depthwise convs and the FC
classifier tail — from ONE instruction stream: POOL validates its input
slot like COMP and produces the pooled block; FC and DEPTHWISE_CONV
additionally check the weight slot and bias buffer; ELTWISE_ADD checks TWO
input slots (primary in slot tag (L, 0), the planner-kept skip operand in
(L, 1)) plus its word2 skip DRAM base and word3 element count; all flow
through the same SAVE/flush path, so every layer kind obeys one hazard
discipline in both execution paths.

Both paths also share one per-block PE dispatch
(``executor.conv_block_forward`` / ``executor.fc_forward``), so the
``backend="xla" | "pallas"`` knob selects the same PE implementation whether
the stream is interpreted per-instruction or lowered to the jitted executor.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import layouts
from repro.core.compiler import CompiledLayer, Program
from repro.core.executor import (  # noqa: F401  (HazardError re-export)
    HazardError,
    _fresh_stats,
    check_param_count,
    conv_block_forward,
    depthwise_forward,
    eltwise_forward,
    fc_forward,
    pool_forward,
    resolve_backend,
    resolve_opt_level,
    slice_input_rows,
    width_pad,
)
from repro.core.isa import (
    Instruction,
    Opcode,
    unpack_dw_geom,
    unpack_fc_dims,
)
from repro.core.winograd import transform_weights


@dataclasses.dataclass
class _Slot:
    tag: tuple | None = None
    data: Any = None


class HybridRuntime:
    """Executes a compiled :class:`~repro.core.compiler.Program` against
    DRAM-resident params and input.

    Parameters
    ----------
    program:
        The compiled instruction stream plus per-layer geometry.
    backend:
        PE implementation for CONV/FC blocks — ``"xla"`` (default,
        GSPMD-partitionable ``lax`` ops) or ``"pallas"`` (the Pallas PE
        kernels in ``repro.kernels``). Applies to BOTH the cached jitted
        executor and the strict interpreter, which share one per-block
        compute helper per backend. ``use_pallas=True`` is the legacy
        spelling of ``backend="pallas"``.
    interpret:
        Pallas interpret-mode override. ``None`` (default) auto-selects:
        interpret mode everywhere except real TPU hardware, so the same
        Program runs on a CPU test container. A non-None value with the
        XLA backend raises ``ValueError`` (it would otherwise be silently
        meaningless).
    opt_level:
        Lowering-optimizer level for the cached jitted executor: ``1``
        (default) fuses each layer's per-block loop into a whole-layer PE
        dispatch where provably equivalent; ``0`` keeps the literal
        per-block lowering (the reference). The strict interpreter is
        per-instruction by definition and ignores the knob. Joins the
        program-cache key.
    strict:
        ``True`` replays the stream per-instruction (hazard-faithful
        interpreter); default is the validate-once cached jitted executor.
    cache:
        A :class:`~repro.core.program_cache.ProgramCache` override;
        defaults to the process-global cache.
    quant:
        A :class:`repro.quant.QuantSidecar` switches every parameterized
        block (both paths) to the int8 PE dispatch. Params must then be
        the quantized image (``repro.quant.quantize_params``); a floating
        input is quantized at the sidecar's input scale on entry, and the
        output is the network's int8 logits (dequantize with
        ``quant.dequantize_output``). Joins the program-cache key via the
        sidecar digest.
    """

    def __init__(self, program: Program, use_pallas: bool = False,
                 interpret: bool | None = None, strict: bool = False,
                 cache=None, backend: str | None = None,
                 opt_level: int = 1, quant=None,
                 aot_dir: str | None = None):
        if backend is None:
            backend = "pallas" if use_pallas else "xla"
        # validate eagerly; keep the unresolved pair (the cache resolves
        # interpret at lookup so TPU-vs-CPU auto-selection stays late-bound)
        resolve_backend(backend, interpret)
        self.program = program
        self.backend = backend
        self.use_pallas = backend == "pallas"
        self.interpret = interpret
        self.opt_level = resolve_opt_level(opt_level)
        self.quant = quant
        self.strict = strict
        # AOT artifact bundle directory (core/aot.py): every cache lookup
        # this runtime makes may warm-load its serialized executable from
        # here instead of re-tracing + re-compiling
        self.aot_dir = aot_dir
        self._cache = cache
        self.dram: dict[int, Any] = {}
        self._raw_params: list[tuple[Any, Any]] | None = None
        # pipeline statistics (4-stage pipeline occupancy model) — same
        # counter keys as the executor's schedule-validation pass
        self.stats = _fresh_stats()

    @property
    def cache(self):
        if self._cache is None:
            from repro.core.program_cache import default_cache
            self._cache = default_cache()
        return self._cache

    # -- DRAM management ----------------------------------------------------
    def load_params(self, params: list[tuple[Any, Any]]):
        """params: [(w, bias), ...] — one entry per *parameterized* layer
        (CONV, FC and DEPTHWISE, in network order; POOL and ELTWISE layers
        carry no params). Winograd CONV layers store U-space weights."""
        check_param_count(self.program, params)
        self._raw_params = [tuple(p) for p in params]
        it = iter(params)
        for cl in self.program.layers:
            if cl.kind in ("pool", "eltwise"):
                continue
            w, b = next(it)
            if cl.kind == "conv" and cl.plan.mode == "wino":
                assert cl.spec.r == 3 and cl.spec.s == 3, \
                    "runtime pre-transform supports r=s=3 (VGG family)"
                self.dram[cl.wgt_addr] = transform_weights(w, cl.plan.m)
            else:
                self.dram[cl.wgt_addr] = w
            self.dram[cl.bias_addr] = b

    def dram_params(self) -> list[tuple[Any, Any]]:
        """The DRAM weight image ``load_params`` built — U-space for Winograd
        CONV layers, raw for Spatial/FC; one entry per parameterized layer."""
        if self._raw_params is None:
            raise RuntimeError("load_params must be called first")
        return [(self.dram[cl.wgt_addr], self.dram[cl.bias_addr])
                for cl in self.program.layers
                if cl.kind not in ("pool", "eltwise")]

    def executor_entry(self, batch: int, dtype, *,
                       donate_input: bool = False, mesh=None,
                       backend: str | None = None):
        """The cached jitted executor + DRAM weight image for (batch, dtype).

        The serving hot path: a caller holding a fixed parameter set (e.g.
        ``api.ServingSession``) invokes ``entry(params, x)`` directly,
        skipping the per-request DRAM dict writes ``run`` performs. Schedule
        validation still runs (once per schedule key, cached).
        ``donate_input=True`` hands back an executor that donates the
        activation buffer — only for callers that never reuse the array
        they pass (the pipelined serving queue). ``mesh`` requests the
        shard_map'd executor variant (batch split over every mesh axis,
        Pallas PEs running per-shard); the batch must divide evenly by the
        mesh's device count.

        ``backend`` overrides the runtime's own backend for this one entry
        — the serving layer's graceful-degradation path re-dispatches a
        failed Pallas batch through ``backend="xla"``. An override resets
        ``interpret`` (a Pallas-only knob the XLA lowering would reject)
        and skips the AOT artifact dir (keyed for the primary backend;
        probing it would only log spurious stale-artifact warnings) — the
        DRAM weight image is shared, since backend selection changes the
        lowering, never the weights."""
        if self.strict:
            raise RuntimeError(
                "strict interpreter mode has no cached executor entry")
        params = self.dram_params()
        self.stats = self.cache.validate(self.program)
        is_fallback = backend is not None and backend != self.backend
        entry = self.cache.get(
            self.program, batch=batch, dtype=dtype,
            param_dtypes=tuple(jnp.dtype(w.dtype).name for w, _ in params),
            backend=self.backend if backend is None else backend,
            interpret=self.interpret if not is_fallback else None,
            opt_level=self.opt_level, donate_input=donate_input, mesh=mesh,
            quant=self.quant,
            aot_dir=self.aot_dir if not is_fallback else None,
            fallback=is_fallback)
        return entry, params

    def export_aot(self, aot_dir: str, x_shape, dtype, *,
                   donate_input: bool = False) -> str:
        """AOT-compile the executor for input shape ``x_shape`` (batch
        leading) and persist the serialized executable into ``aot_dir``,
        keyed by the full program-cache key + device/version fingerprint
        (see ``core/aot.py``). Returns the artifact digest. Lowering runs
        against ``ShapeDtypeStruct`` stand-ins — no device math at export
        time."""
        from repro.core import aot
        from repro.core.executor import compile_executor
        from repro.core.program_cache import cache_key

        batch = int(x_shape[0])
        entry, params = self.executor_entry(batch, dtype,
                                            donate_input=donate_input)
        if getattr(entry, "aot_loaded", False):
            # a deserialized executable cannot be re-lowered — rebuild a
            # jit-stage executor so re-exporting a warm-loaded runtime to a
            # new bundle directory still works
            entry = compile_executor(
                self.program, stats=self.stats, backend=self.backend,
                interpret=self.interpret, opt_level=self.opt_level,
                donate_input=donate_input, quant=self.quant)
        key = cache_key(
            self.program, batch=batch, dtype=dtype,
            param_dtypes=tuple(jnp.dtype(w.dtype).name for w, _ in params),
            backend=self.backend, interpret=self.interpret,
            opt_level=self.opt_level, donate_input=donate_input,
            quant=self.quant)
        return aot.save_entry(aot_dir, entry, params, tuple(x_shape), dtype,
                              key)

    def write_input(self, x_nhwc):
        cl0 = self.program.layers[0]
        if cl0.inp_layout == "wino":
            x_nhwc = layouts.save_transform(x_nhwc, "wino", cl0.plan.m)
        self.dram[cl0.inp_addr] = x_nhwc

    # -- execution ----------------------------------------------------------
    def run(self, x_nhwc=None):
        """Validate + execute the program; returns the last layer's output.

        Default: one-time schedule validation (cached per Program) + the
        jitted executor. ``strict=True``: the per-instruction interpreter.
        """
        if self.strict:
            return self._run_interpreter(x_nhwc)
        if self._raw_params is None:
            raise RuntimeError("load_params must be called before run()")
        x_nhwc = self._maybe_quantize_input(x_nhwc)
        if x_nhwc is not None:
            self.write_input(x_nhwc)       # same DRAM contract as strict mode
        else:
            cl0 = self.program.layers[0]
            stored = self.dram[cl0.inp_addr]
            if cl0.kind == "fc":           # FC-first: flat activation, no hw
                x_nhwc = stored.reshape(stored.shape[0], -1)
            else:
                x_nhwc = layouts.load_view(stored, cl0.inp_layout,
                                           hw=(cl0.spec.h, cl0.spec.w))
        # the executor consumes the DRAM weight image load_params already
        # built (U-space for wino) — no per-request weight work; POOL
        # layers carry no params.  executor_entry validates the schedule
        # (HazardError on bad streams; cached per schedule key).
        entry, params = self.executor_entry(x_nhwc.shape[0], x_nhwc.dtype)
        y = entry(params, x_nhwc)
        self.dram[self.program.layers[-1].out_addr] = y
        return y

    def _maybe_quantize_input(self, x_nhwc):
        """Quantized runtimes accept fp inputs for convenience: quantize at
        the sidecar's input scale (a no-op for already-int8 inputs)."""
        if self.quant is not None and x_nhwc is not None \
                and jnp.issubdtype(jnp.asarray(x_nhwc).dtype, jnp.floating):
            return self.quant.quantize_input(x_nhwc)
        return x_nhwc

    def _run_interpreter(self, x_nhwc=None):
        x_nhwc = self._maybe_quantize_input(x_nhwc)
        if x_nhwc is not None:
            self.write_input(x_nhwc)
        inp_slots = [_Slot(), _Slot()]
        wgt_slots = [_Slot(), _Slot()]
        bias_buf = _Slot()
        out_blocks: dict[tuple[int, int], Any] = {}
        cur_layer = -1
        staging = None           # NHWC assembly of the current layer's output

        for ins in self.program.instructions:
            cl = self.program.layers[ins.layer_id]
            if ins.layer_id != cur_layer:
                if cur_layer >= 0:
                    self._flush_layer(self.program.layers[cur_layer], staging,
                                      out_blocks)
                cur_layer = ins.layer_id
                staging = None
                out_blocks = {}

            op = ins.opcode
            if op == Opcode.LOAD_BIAS:
                bias_buf = _Slot((ins.layer_id,), self.dram[ins.dram_base])
                self.stats["load_bias"] += 1
            elif op == Opcode.LOAD_INP:
                ih, slot = ins.buff_base >> 1, ins.buff_base & 1
                if cl.kind in ("pool", "fc", "dw", "eltwise"):
                    # identity load of the stored tensor (the forward
                    # helpers apply the layout view themselves); ELTWISE
                    # reads TWO operands, each by the DRAM base its own
                    # LOAD_INP names — primary (ih 0) from cl.inp_addr,
                    # skip (ih 1) from the planner-kept cl.skip_addr
                    data = self.dram[ins.dram_base]
                else:
                    data = self._load_input_group(cl, ih)
                inp_slots[slot] = _Slot((ins.layer_id, ih), data)
                self.stats["load_inp"] += 1
                self.stats["inp_words"] += ins.size
            elif op == Opcode.LOAD_WGT:
                kg, slot = ins.buff_base >> 1, ins.buff_base & 1
                lo, hi = cl.k_groups[kg]
                w = self.dram[ins.dram_base][..., lo:hi]
                wgt_slots[slot] = _Slot((ins.layer_id, kg), w)
                self.stats["load_wgt"] += 1
                self.stats["wgt_words"] += ins.size
            elif op == Opcode.COMP:
                ih = ins.size & 0xFFF
                kg = (ins.size >> 12) & 0xFFF
                islot = (ins.size >> 24) & 1
                wslot = (ins.size >> 25) & 1
                if inp_slots[islot].tag != (ins.layer_id, ih):
                    raise HazardError(
                        f"COMP L{ins.layer_id} row-group {ih}: input slot "
                        f"{islot} holds {inp_slots[islot].tag}")
                if wgt_slots[wslot].tag != (ins.layer_id, kg):
                    raise HazardError(
                        f"COMP L{ins.layer_id} k-group {kg}: weight slot "
                        f"{wslot} holds {wgt_slots[wslot].tag}")
                if bias_buf.tag != (ins.layer_id,):
                    raise HazardError(f"COMP L{ins.layer_id}: stale bias buffer")
                blk = self._compute(cl, inp_slots[islot].data,
                                    wgt_slots[wslot].data,
                                    bias_buf.data, ih, kg, ins)
                out_blocks[(ih, kg)] = blk
                self.stats["comp"] += 1
            elif op == Opcode.POOL:
                islot = ins.buff_base & 1
                cfg = (ins.pool_window, ins.pool_stride)
                if cfg != (cl.spec.window, cl.spec.stride):
                    raise HazardError(
                        f"POOL L{ins.layer_id}: word0 window/stride {cfg} "
                        f"disagree with compiled spec "
                        f"({cl.spec.window}, {cl.spec.stride})")
                if inp_slots[islot].tag != (ins.layer_id, 0):
                    raise HazardError(
                        f"POOL L{ins.layer_id}: input slot {islot} holds "
                        f"{inp_slots[islot].tag}")
                out_blocks[(0, 0)] = pool_forward(
                    cl, inp_slots[islot].data, ins.pool_window,
                    ins.pool_stride)
                self.stats["pool"] += 1
            elif op == Opcode.FC:
                islot = ins.buff_base & 1
                wslot = (ins.buff_base >> 1) & 1
                dims = unpack_fc_dims(ins.size)
                if dims != (cl.spec.d_in, cl.spec.d_out):
                    raise HazardError(
                        f"FC L{ins.layer_id}: word3 dims {dims} disagree "
                        f"with compiled spec ({cl.spec.d_in}, {cl.spec.d_out})")
                if inp_slots[islot].tag != (ins.layer_id, 0):
                    raise HazardError(
                        f"FC L{ins.layer_id}: input slot {islot} holds "
                        f"{inp_slots[islot].tag}")
                if wgt_slots[wslot].tag != (ins.layer_id, 0):
                    raise HazardError(
                        f"FC L{ins.layer_id}: weight slot {wslot} holds "
                        f"{wgt_slots[wslot].tag}")
                if bias_buf.tag != (ins.layer_id,):
                    raise HazardError(f"FC L{ins.layer_id}: stale bias buffer")
                out_blocks[(0, 0)] = fc_forward(
                    cl, wgt_slots[wslot].data, bias_buf.data,
                    inp_slots[islot].data, ins.relu_flag,
                    backend=self.backend, interpret=self.interpret,
                    quant=self._layer_quant(cl))
                self.stats["fc"] += 1
            elif op == Opcode.ELTWISE_ADD:
                pslot = ins.buff_base & 1
                sslot = (ins.buff_base >> 1) & 1
                n_el = cl.spec.h * cl.spec.w * cl.spec.c
                if ins.size != n_el:
                    raise HazardError(
                        f"ELTWISE L{ins.layer_id}: word3 element count "
                        f"{ins.size} disagrees with compiled spec ({n_el})")
                if ins.dram_base != cl.skip_addr:
                    raise HazardError(
                        f"ELTWISE L{ins.layer_id}: word2 skip base "
                        f"{ins.dram_base} disagrees with compiled skip "
                        f"operand ({cl.skip_addr})")
                if inp_slots[pslot].tag != (ins.layer_id, 0):
                    raise HazardError(
                        f"ELTWISE L{ins.layer_id}: primary input slot "
                        f"{pslot} holds {inp_slots[pslot].tag}")
                if inp_slots[sslot].tag != (ins.layer_id, 1):
                    raise HazardError(
                        f"ELTWISE L{ins.layer_id}: skip input slot {sslot} "
                        f"holds {inp_slots[sslot].tag}")
                out_blocks[(0, 0)] = eltwise_forward(
                    cl, inp_slots[pslot].data, inp_slots[sslot].data,
                    ins.relu_flag, quant=self._layer_quant(cl))
                self.stats["eltwise"] += 1
            elif op == Opcode.DEPTHWISE_CONV:
                islot = ins.buff_base & 1
                wslot = (ins.buff_base >> 1) & 1
                geom = unpack_dw_geom(ins.size)
                if geom != (cl.spec.r, cl.spec.s, cl.spec.stride):
                    raise HazardError(
                        f"DEPTHWISE L{ins.layer_id}: word3 geometry {geom} "
                        f"disagrees with compiled spec "
                        f"({cl.spec.r}, {cl.spec.s}, {cl.spec.stride})")
                if inp_slots[islot].tag != (ins.layer_id, 0):
                    raise HazardError(
                        f"DEPTHWISE L{ins.layer_id}: input slot {islot} "
                        f"holds {inp_slots[islot].tag}")
                if wgt_slots[wslot].tag != (ins.layer_id, 0):
                    raise HazardError(
                        f"DEPTHWISE L{ins.layer_id}: weight slot {wslot} "
                        f"holds {wgt_slots[wslot].tag}")
                if bias_buf.tag != (ins.layer_id,):
                    raise HazardError(
                        f"DEPTHWISE L{ins.layer_id}: stale bias buffer")
                out_blocks[(0, 0)] = depthwise_forward(
                    cl, wgt_slots[wslot].data, bias_buf.data,
                    inp_slots[islot].data, ins.relu_flag,
                    quant=self._layer_quant(cl))
                self.stats["dw"] += 1
            elif op == Opcode.SAVE and cl.kind != "conv":
                if (0, 0) not in out_blocks:
                    raise HazardError(
                        f"SAVE L{ins.layer_id} block (0, 0) not computed")
                staging = out_blocks.pop((0, 0))
                self.stats["save"] += 1
            elif op == Opcode.SAVE:
                ih = ins.size & 0xFFF
                kg = (ins.size >> 12) & 0xFFF
                ho, wo = cl.spec.out_hw
                if staging is None:
                    n = self._batch(cl)
                    staging = jnp.zeros((n, ho, wo, cl.spec.k),
                                        self._dtype(cl))
                if cl.plan.dataflow == "is":
                    # one SAVE per row group: all K groups must be computed
                    need = [(ih, g) for g in range(len(cl.k_groups))]
                else:
                    need = [(ih, kg)]
                for key in need:
                    if key not in out_blocks:
                        raise HazardError(
                            f"SAVE L{ins.layer_id} block {key} not computed")
                r0, r1 = cl.row_groups[ih]
                if cl.plan.dataflow == "is":
                    row = jnp.concatenate(
                        [out_blocks.pop((ih, g)) for g in
                         range(len(cl.k_groups))], axis=-1)
                    staging = staging.at[:, r0:r1].set(row.astype(staging.dtype))
                else:
                    c0, c1 = cl.k_groups[kg]
                    staging = staging.at[:, r0:r1, :, c0:c1].set(
                        out_blocks.pop((ih, kg)).astype(staging.dtype))
                self.stats["save"] += 1
            else:
                raise ValueError(op)

        if cur_layer >= 0:
            self._flush_layer(self.program.layers[cur_layer], staging,
                              out_blocks)
        last = self.program.layers[-1]
        return self.dram[last.out_addr]

    # -- helpers ------------------------------------------------------------
    def _batch(self, cl: CompiledLayer) -> int:
        x = self.dram[cl.inp_addr]
        return x.shape[0]

    def _dtype(self, cl: CompiledLayer):
        return self.dram[cl.inp_addr].dtype

    def _input_nhwc(self, cl: CompiledLayer):
        x = self.dram[cl.inp_addr]
        return layouts.load_view(x, cl.inp_layout, hw=(cl.spec.h, cl.spec.w))

    def _load_input_group(self, cl: CompiledLayer, ih: int):
        """Slice the input rows (plus halo) needed for output rows group ih.

        Delegates to the executor's helper so the interpreter and the jitted
        path share one copy of the halo arithmetic."""
        return slice_input_rows(cl, self._input_nhwc(cl), ih)

    def _layer_quant(self, cl: CompiledLayer):
        return self.quant.layers[cl.layer_id] if self.quant is not None \
            else None

    def _compute(self, cl: CompiledLayer, x_slab, w_grp, bias, ih, kg, ins):
        lo, hi = cl.k_groups[kg]
        # one shared per-block PE dispatch (executor.conv_block_forward) so
        # the interpreter and the lowered executor can never drift — the
        # backend knob routes both through the same XLA or Pallas PE
        blk = conv_block_forward(
            cl, x_slab, w_grp, bias[lo:hi], ins.relu_flag,
            backend=self.backend, interpret=self.interpret,
            quant=self._layer_quant(cl), k_range=(lo, hi))
        r0, r1 = cl.row_groups[ih]
        return blk[:, :r1 - r0]

    def _flush_layer(self, cl: CompiledLayer, staging, out_blocks):
        if out_blocks:
            raise HazardError(
                f"layer {cl.layer_id}: {len(out_blocks)} COMP blocks never SAVEd")
        if staging is None:
            raise HazardError(f"layer {cl.layer_id}: no SAVE executed")
        if cl.out_layout == "wino":
            self.dram[cl.out_addr] = layouts.save_transform(
                staging, "wino", cl.out_m)
        else:
            self.dram[cl.out_addr] = staging


def run_program(program: Program, params, x_nhwc, **kw):
    rt = HybridRuntime(program, **kw)
    rt.load_params(params)
    return rt.run(x_nhwc)
