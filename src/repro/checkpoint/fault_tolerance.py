"""Fault tolerance: heartbeat/straggler monitoring, restart-from-latest,
elastic re-meshing.

At 1000+ nodes the failure model is: a worker dies (checkpoint-restart), a
worker slows down (straggler mitigation), or capacity changes (elastic
re-mesh). All three are handled here and exercised by
``examples/fault_tolerant_training.py`` and the integration tests:

* ``HeartbeatMonitor`` — per-worker step-completion timestamps; a worker is a
  straggler when its step time exceeds ``zscore_threshold`` sigma over the
  fleet median (rolling window), dead when silent for ``dead_after_s``.
* ``run_with_recovery`` — drives a step function; on failure restores the
  latest checkpoint and replays the data stream (deterministic pipeline =>
  bit-exact recovery).
* ``elastic_restore`` — restores a checkpoint onto a *different* mesh: the
  deterministic data pipeline re-slices the global batch and ``device_put``
  re-shards every leaf.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib


@dataclasses.dataclass
class WorkerState:
    last_seen: float
    step_times: list[float] = dataclasses.field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, n_workers: int, window: int = 16,
                 zscore_threshold: float = 3.0, dead_after_s: float = 60.0):
        now = time.monotonic()
        self.workers = {i: WorkerState(now) for i in range(n_workers)}
        self.window = window
        self.z = zscore_threshold
        self.dead_after = dead_after_s

    def report(self, worker: int, step_time: float,
               now: float | None = None):
        w = self.workers[worker]
        w.last_seen = now if now is not None else time.monotonic()
        w.step_times.append(step_time)
        if len(w.step_times) > self.window:
            w.step_times.pop(0)

    def stragglers(self) -> list[int]:
        """Workers whose median step time z-scores above the fleet."""
        meds = {i: np.median(w.step_times)
                for i, w in self.workers.items() if w.step_times}
        if len(meds) < 2:
            return []
        vals = np.array(list(meds.values()))
        fleet_med = np.median(vals)
        mad = np.median(np.abs(vals - fleet_med)) + 1e-9
        return [i for i, m in meds.items()
                if (m - fleet_med) / (1.4826 * mad) > self.z]

    def dead(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.monotonic()
        return [i for i, w in self.workers.items()
                if now - w.last_seen > self.dead_after]


def run_with_recovery(
    step_fn: Callable,        # (state, step) -> state ; may raise
    init_state,
    n_steps: int,
    ckpt_dir: str,
    *,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    on_restore: Callable | None = None,
):
    """Training driver with checkpoint/restart. Returns (state, log).

    ``step_fn`` may raise (simulated node failure); the driver restores the
    latest checkpoint and resumes from its step. The log records every
    restart so tests can assert recovery behavior.
    """
    state = init_state
    log = {"restarts": 0, "completed": []}
    step = 0
    restarts = 0
    ckpt_lib.save(ckpt_dir, 0, state)
    while step < n_steps:
        try:
            state = step_fn(state, step)
            log["completed"].append(step)
            step += 1
            if step % ckpt_every == 0:
                ckpt_lib.save(ckpt_dir, step, state)
        except Exception:
            restarts += 1
            log["restarts"] = restarts
            if restarts > max_restarts:
                raise
            state, restored_step = ckpt_lib.restore(ckpt_dir, state)
            if on_restore is not None:
                state = on_restore(state)
            step = restored_step
    ckpt_lib.save(ckpt_dir, n_steps, state)
    return state, log


def elastic_restore(ckpt_dir: str, template, new_rules, param_sharding_fn):
    """Restore the latest checkpoint onto a different mesh.

    ``param_sharding_fn(template, rules)`` -> shardings pytree (e.g.
    ``parallel.sharding.param_shardings``).
    """
    shardings = param_sharding_fn(template, new_rules) if new_rules else None
    return ckpt_lib.restore(ckpt_dir, template, shardings=shardings)
