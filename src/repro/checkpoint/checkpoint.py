"""Sharded checkpointing: npz-per-step + JSON manifest, async writes,
restore-with-resharding (elastic re-meshing).

Layout::

    <dir>/step_<N>/manifest.json       # step, paths, shapes, dtypes, mesh
    <dir>/step_<N>/arrays.npz          # one entry per pytree leaf
    <dir>/LATEST                       # atomic pointer

Restore never requires the saving mesh: leaves are placed with the *current*
rules' shardings (``device_put`` reshards), which is exactly the elastic
scale-up/down path — a 16x16 checkpoint restores onto 8x16 or 2x16x16
unchanged.
"""
from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True,
         extra_meta: dict | None = None) -> threading.Thread | None:
    """Write a checkpoint. ``blocking=False`` returns the writer thread
    (async checkpointing: training continues while the host writes)."""
    flat = _flatten(tree)   # device_get happens on the caller thread

    def _write():
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        np.savez(os.path.join(d, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in flat.items()},
            **(extra_meta or {}),
        }
        with open(os.path.join(d, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(f"step_{step:08d}")
        os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip().split("_")[1])


def restore(ckpt_dir: str, template, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``template``. ``shardings`` (a matching
    pytree of NamedSharding, or None) places each leaf — pass the current
    mesh's shardings to reshard elastically."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    arrays = np.load(os.path.join(d, "arrays.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(paths))
    leaves = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = arrays[key]
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
