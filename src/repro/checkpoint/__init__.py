"""``repro.checkpoint`` — sharded checkpoints and fault-tolerance seeds.

``HeartbeatMonitor`` (straggler z-score + dead-after-silence detection)
is re-exported at the package level because the serving watchdog
(``repro.serving.watchdog.ThreadSupervisor``) adapts it as its pipeline
hang detector — see ``docs/ARCHITECTURE.md`` "Failure model".
"""
from repro.checkpoint.fault_tolerance import (
    HeartbeatMonitor,
    elastic_restore,
    run_with_recovery,
)

__all__ = ["HeartbeatMonitor", "elastic_restore", "run_with_recovery"]
