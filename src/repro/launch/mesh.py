"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state."""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None):
    """1-D batch mesh over the local devices — the serving-fleet topology.

    The sharded executor splits the request batch over every mesh axis, so
    a flat ``("batch",)`` mesh is the natural spelling for data-parallel
    serving (one shard of every device batch per device). ``n_devices``
    caps the fleet to the first N local devices (``None`` = all of them) —
    a multi-model :class:`repro.api.Fleet` can carve disjoint sub-fleets
    this way.
    """
    devices = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"n_devices={n_devices} outside [1, {len(devices)}] local "
                f"devices")
        devices = devices[:n_devices]
    return make_mesh((len(devices),), ("batch",), devices=devices)
