"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state."""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
