"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run before anything else initializes jax — the first two
lines pin 512 placeholder host devices so ``jax.make_mesh`` can build the
production meshes (this file only; smoke tests and benches see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json with the
memory analysis, cost analysis, parsed collective schedule and roofline
terms (launch/roofline.py).
"""
import os

# 512 placeholder devices for the production meshes + bf16 (not f32)
# TP-boundary collectives: excess precision keeps bf16 dot partial sums in
# f32 straight through the all-reduce/reduce-scatter — 2x ICI bytes on the
# dominant collectives (measured: minitron train_4k 10.2s -> 5.1s).
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_allow_excess_precision=false")

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config, list_archs
from repro.configs.shapes import SHAPES, SHAPE_NAMES, applicability
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import abstract_params, input_specs
from repro.models.transformer import group_period
from repro.optim import adamw
from repro.parallel.sharding import (
    Rules, make_rules, param_shardings, use_rules, zero1_specs,
)
from repro.train import steps

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def trip_count(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe", "vlm"):
        return cfg.n_layers // group_period(cfg)
    if cfg.family == "ssm":
        return cfg.n_layers
    if cfg.family == "hybrid":
        return max(1, cfg.n_layers // cfg.shared_attn_every)
    if cfg.family == "audio":
        return cfg.n_layers
    return 1


def _batch_shardings(cfg, specs_tree, rules: Rules, batch_leading=True):
    def spec_for(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return rules.sharding()
        logical = [None] * nd
        if batch_leading and leaf.shape[0] > 1:
            logical[0] = "batch"
        return rules.sharding(*logical)
    return jax.tree.map(spec_for, specs_tree)


def _cache_shardings(cfg: ModelConfig, cache, rules: Rules, batch: int):
    """KV caches: (.., B, S, kv, hd) -> batch over dp, seq over model.
    SSM states: heads over model. Identified by leaf shapes."""
    def spec_for(path, leaf):
        nd = len(leaf.shape)
        key = ""
        for pp in reversed(path):
            k = getattr(pp, "key", None)
            if isinstance(k, str):
                key = k
                break
        logical = [None] * nd
        # find the batch dim (== batch size)
        try:
            bdim = leaf.shape.index(batch)
        except ValueError:
            bdim = None
        if bdim is not None and batch > 1:
            logical[bdim] = "batch"
        if key in ("k", "v", "attn_k", "attn_v"):
            # (..., B, S, KV, hd): seq dim right after batch
            sdim = (bdim + 1) if bdim is not None else nd - 3
            logical[sdim] = "seq"
        elif key in ("ssm", "groups_ssm", "tail_ssm"):
            logical[-3] = "ssm_heads"       # (..., H, N, P)
        elif key in ("conv", "groups_conv", "tail_conv"):
            logical[-1] = "mlp"             # conv channel dim
        from repro.parallel.sharding import _drop_indivisible
        spec = _drop_indivisible(rules.spec(*logical), leaf.shape, rules)
        return jax.sharding.NamedSharding(rules.mesh, spec)
    return jax.tree_util.tree_map_with_path(spec_for, cache)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_total_steps: int = 10000):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh)

    aparams = abstract_params(cfg)
    p_shard = param_shardings(aparams, rules)
    ins = input_specs(cfg, shape)

    with use_rules(rules):
        if shape.kind == "train":
            opt = adamw.AdamWConfig(total_steps=opt_total_steps)
            aopt = jax.eval_shape(lambda p: adamw.init(p), aparams)
            o_specs = zero1_specs(aopt, rules)
            o_shard = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(rules.mesh, s),
                o_specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))
            b_shard = _batch_shardings(cfg, ins, rules)
            step_fn = steps.make_train_step(cfg, opt)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, aopt, ins)
        elif shape.kind in ("prefill", "decode"):
            prefill_fn, decode_fn = steps.make_serve_steps(cfg)
            c_shard = _cache_shardings(cfg, ins["cache"], rules,
                                       shape.global_batch)
            e_shard = _batch_shardings(cfg, ins["extras"], rules)
            if shape.kind == "prefill":
                t_shard = _batch_shardings(
                    cfg, {"t": ins["tokens"]}, rules)["t"]
                jitted = jax.jit(
                    lambda p, t, c, e: prefill_fn(p, t, c, e),
                    in_shardings=(p_shard, t_shard, c_shard, e_shard),
                    donate_argnums=(2,))
                lowered = jitted.lower(aparams, ins["tokens"], ins["cache"],
                                       ins["extras"])
            else:
                t_shard = _batch_shardings(cfg, {"t": ins["token"]},
                                           rules)["t"]
                jitted = jax.jit(
                    lambda p, t, c, pos, e: decode_fn(p, t, c, pos, e),
                    in_shardings=(p_shard, t_shard, c_shard,
                                  rules.sharding(), e_shard),
                    donate_argnums=(2,))
                lowered = jitted.lower(aparams, ins["token"], ins["cache"],
                                       ins["pos"], ins["extras"])
        else:
            raise ValueError(shape.kind)

    return lowered, cfg, shape, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicability(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="SKIP", reason=why)
        _write(rec, out_dir)
        return rec

    t0 = time.time()
    try:
        lowered, cfg, shape, mesh = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        n_chips = mesh.devices.size
        trip = trip_count(cfg)
        st = rl.analyze_hlo(compiled.as_text(), trip_count=trip)
        # XLA's CPU backend legalizes bf16 compute to f32 (verified: internal
        # activations/collectives appear as f32 in the optimized HLO); on the
        # TPU target they stay bf16. Correct traffic terms by 0.5 for bf16
        # models — FLOPs are dtype-invariant. Raw numbers are kept alongside.
        bf16_corr = 0.5 if cfg.dtype == "bfloat16" else 1.0
        st_c = dataclasses.replace(
            st, bytes_accessed=st.bytes_accessed * bf16_corr,
            collective_bytes=st.collective_bytes * bf16_corr)
        roof = rl.roofline_from_stats(st_c, n_chips)

        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                       else (shape.seq_len if shape.kind ==
                                             "prefill" else 1))
        n_active = cfg.active_param_count()
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * n_active * tokens
        model_flops_chip = model_flops / n_chips

        rec.update(
            status="OK",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            n_chips=n_chips,
            memory={k: getattr(ma, k) for k in
                    ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes")},
            bytes_per_device_gb=round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes) / 2**30, 3),
            bytes_per_device_gb_tpu_est=round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes * bf16_corr) / 2**30, 3),
            cost_analysis={"flops_unscaled": ca.get("flops", 0.0),
                           "bytes_unscaled": ca.get("bytes accessed", 0.0)},
            trip_count=trip,
            bf16_correction=bf16_corr,
            hlo_flops_per_chip=st.flops,
            hlo_bytes_per_chip=st_c.bytes_accessed,
            hlo_bytes_per_chip_raw_cpu=st.bytes_accessed,
            collective_bytes_per_chip=st_c.collective_bytes,
            collective_counts=st.collective_counts,
            roofline={
                "compute_s": roof.compute_s,
                "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "bound": roof.bound,
                "step_time_s": roof.step_time_s,
            },
            model_flops_global=model_flops,
            model_flops_per_chip=model_flops_chip,
            useful_flops_ratio=(model_flops_chip / st.flops
                                if st.flops else None),
        )
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] OK "
                  f"compile={t_compile:.1f}s mem/dev="
                  f"{rec['bytes_per_device_gb']}GB bound={roof.bound} "
                  f"step={roof.step_time_s*1e3:.2f}ms")
    except Exception as e:  # noqa: BLE001
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] FAIL: {e}")
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str | None):
    out_dir = out_dir or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPE_NAMES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    lm_archs = [a for a in list_archs() if a != "vgg16"]
    archs = lm_archs if args.all or not args.arch else [args.arch]
    shapes = list(SHAPE_NAMES) if args.all or not args.shape else [args.shape]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.out))
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"of {len(results)} cells ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
