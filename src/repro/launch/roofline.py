"""Roofline-term extraction from compiled HLO (post-SPMD-partitioning).

XLA's ``cost_analysis()`` visits a ``while`` body ONCE (scan trip counts are
not applied), so for scan-over-layers models we parse the optimized HLO text
ourselves:

* FLOPs        — every ``dot``/``convolution`` op: 2 * prod(result shape) *
                 contraction size, scaled by the enclosing loop's trip count.
* HBM bytes    — per top-level op: operand bytes + result bytes (post-fusion
                 accounting, matching HloCostAnalysis), scaled likewise.
* Collective bytes — ``all-reduce``/``all-gather``/``reduce-scatter``/
                 ``all-to-all``/``collective-permute`` (+ ``-start``
                 variants): max(operand, result) bytes, scaled likewise.

Loop attribution: computations reachable (via ``body=``/``to_apply=``/
``calls=``/fusion) from a ``while`` body get the ``trip_count`` multiplier.

Everything is PER-PARTITION (the HLO is the single SPMD program), i.e.
per-chip — exactly what the roofline terms want.
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$")
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=\s*%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of 'bf16[2,3]{1,0}' or a tuple '(f32[2], s32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str           # operand list + attributes (raw tail of the line)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symbols: dict[str, str]   # op name -> type string


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        ls = line.strip()
        if (ls.startswith("%") or ls.startswith("ENTRY")) and ls.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", ls)
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        cur.ops.append(Op(name, type_str, opcode, rest))
        cur.symbols[name] = type_str
    return comps


def _loop_multipliers(comps: dict[str, Computation],
                      trip_count: int) -> dict[str, int]:
    """computation name -> multiplier (trip_count if inside a while body)."""
    # call edges
    edges: dict[str, set[str]] = {c: set() for c in comps}
    while_bodies: set[str] = set()
    for cname, comp in comps.items():
        for op in comp.ops:
            for callee in _CALL_ATTR_RE.findall(op.rest):
                if callee in comps:
                    edges[cname].add(callee)
                    if op.opcode == "while":
                        while_bodies.add(callee)

    mult = {c: 1 for c in comps}
    # BFS from while bodies: everything reachable runs trip_count times
    stack = list(while_bodies)
    seen = set()
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen.add(c)
        mult[c] = trip_count
        stack.extend(edges.get(c, ()))
    return mult


def _operand_names(comp: Computation, op: Op) -> list[str]:
    """Operand op-names: tokens in rest up to the first attr (=)."""
    depth = 0
    args = ""
    for ch in op.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        args += ch
    names = []
    for tok in args.split(","):
        tok = tok.strip()
        m = re.match(r"%?([\w.\-]+)$", tok)
        if m and m.group(1) in comp.symbols:
            names.append(m.group(1))
    return names


def _dot_flops(comp: Computation, op: Op) -> float:
    out_dims = _shape_dims(op.type_str)
    out_elems = math.prod(out_dims) if out_dims else 1
    ops_ = _operand_names(comp, op)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if m and ops_:
        lhs_dims = _shape_dims(comp.symbols[ops_[0]])
        for d in (m.group(1).split(",") if m.group(1) else []):
            contract *= lhs_dims[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(comp: Computation, op: Op) -> float:
    out_dims = _shape_dims(op.type_str)
    out_elems = math.prod(out_dims) if out_dims else 1
    ops_ = _operand_names(comp, op)
    if len(ops_) < 2:
        return 0.0
    k_dims = _shape_dims(comp.symbols[ops_[1]])
    m = re.search(r"dim_labels=[^\s,]*_([0-9a-z]+)->", op.rest)
    kernel_contract = 1
    if m and k_dims:
        labels = m.group(1)          # e.g. '01io'
        for i, lab in enumerate(labels):
            if lab != "o":           # all kernel dims except output feature
                kernel_contract *= k_dims[i]
    else:
        kernel_contract = math.prod(k_dims[:-1]) if k_dims else 1
    feature_group = 1
    fg = re.search(r"feature_group_count=(\d+)", op.rest)
    if fg:
        feature_group = int(fg.group(1))
    return 2.0 * out_elems * kernel_contract / max(1, feature_group)


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)


def _scan_aware_bytes(type_str: str, m: int, trip: int) -> int:
    """Bytes of a tensor touched per loop iteration: a stacked scan
    input/output (leading dim == trip inside a x-trip computation) is
    dynamic-sliced — only 1/trip of it moves per iteration."""
    b = _shape_bytes(type_str)
    if m == trip > 1:
        dims = _shape_dims(type_str)
        if dims and dims[0] == trip:
            return b // trip
    return b


def analyze_hlo(text: str, trip_count: int = 1) -> HLOStats:
    comps = parse_hlo(text)
    mult = _loop_multipliers(comps, trip_count)
    st = HLOStats()
    skip_opcodes = {"parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "call", "conditional"}
    # computations whose ops are accounted at their caller's boundary
    sub_comps: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode in ("fusion", "reduce", "scatter", "sort", "map",
                             "reduce-window", "select-and-scatter"):
                for callee in _CALL_ATTR_RE.findall(op.rest):
                    sub_comps.add(callee)
    for cname, comp in comps.items():
        if cname in sub_comps:
            continue
        m = mult[cname]
        for op in comp.ops:
            if op.opcode in skip_opcodes:
                continue
            out_b = _scan_aware_bytes(op.type_str, m, trip_count)
            operands = _operand_names(comp, op)
            if op.opcode == "dynamic-slice":
                # touches only the slice, not the full operand (a scan
                # carrying a stacked KV cache would otherwise count the
                # whole cache once per layer: ~64x overcount on decode)
                in_b = out_b
            elif op.opcode == "dynamic-update-slice":
                # in-place read-modify-write of the update region
                upd = (_shape_bytes(comp.symbols[operands[1]])
                       if len(operands) > 1 else 0)
                in_b = upd
                out_b = upd
            elif op.opcode in ("gather", "scatter"):
                in_b = out_b
            else:
                in_b = sum(_scan_aware_bytes(comp.symbols[o], m, trip_count)
                           for o in operands)
            if op.opcode == "fusion":
                # fused computation's ops are internal; count boundary only —
                # but a fusion PARAMETER consumed solely by an internal
                # dynamic-slice touches only the slice (stacked-cache reads)
                callee = _CALL_ATTR_RE.search(op.rest)
                fc = comps.get(callee.group(1)) if callee else None
                if fc is not None:
                    in_b = sum(_scan_aware_bytes(comp.symbols[o], m,
                                                 trip_count)
                               for o in operands)
                    for fop in fc.ops:
                        if fop.opcode == "dot":
                            st.flops += m * _dot_flops(fc, fop)
                        elif fop.opcode == "convolution":
                            st.flops += m * _conv_flops(fc, fop)
                st.bytes_accessed += m * (out_b + in_b)
                continue
            st.bytes_accessed += m * (out_b + in_b)
            if op.opcode == "dot":
                st.flops += m * _dot_flops(comp, op)
            elif op.opcode == "convolution":
                st.flops += m * _conv_flops(comp, op)
            base = op.opcode.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                b = max(out_b, in_b)
                st.collective_bytes += m * b
                st.collective_counts[base] = \
                    st.collective_counts.get(base, 0) + m
    # fused computations are counted via their fusion op; avoid double count:
    # (we never iterate into callee comps for bytes — only entry + bodies are
    # top-level; called comps still appear in `comps`, subtract their direct
    # contributions)
    return st


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    collective_bytes: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


def roofline_from_stats(st: HLOStats, n_chips: int = 1) -> Roofline:
    """Terms are already per-chip (SPMD program == one partition)."""
    return Roofline(
        compute_s=st.flops / PEAK_FLOPS,
        memory_s=st.bytes_accessed / HBM_BW,
        collective_s=st.collective_bytes / ICI_BW,
        flops=st.flops, bytes=st.bytes_accessed,
        collective_bytes=st.collective_bytes)
