"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns (step_kind, arg-specs dict); together
with ``abstract_params`` these are everything ``.lower()`` needs — the
weak-type-correct, shardable pattern for compile-only dry-runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.train import steps


def abstract_params(cfg: ModelConfig):
    """Param ShapeDtypeStructs via eval_shape — no allocation."""
    return jax.eval_shape(
        lambda: steps.init_params(jax.random.PRNGKey(0), cfg))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: steps.init_cache(cfg, batch, max_len))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model-input ShapeDtypeStructs for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.jnp_dtype
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "targets": _sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = _sds((b, cfg.n_image_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            batch["frames"] = _sds((b, cfg.n_audio_frames, cfg.d_model), dt)
        return batch
    if shape.kind == "prefill":
        extras = {}
        if cfg.family == "vlm":
            extras["image_embeds"] = _sds((b, cfg.n_image_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            extras["enc_out"] = _sds((b, cfg.n_audio_frames, cfg.d_model), dt)
        return {"tokens": _sds((b, s), jnp.int32),
                "cache": abstract_cache(cfg, b, s),
                "extras": extras}
    if shape.kind == "decode":
        extras = {}
        if cfg.family == "vlm":
            extras["image_embeds"] = _sds((b, cfg.n_image_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            extras["enc_out"] = _sds((b, cfg.n_audio_frames, cfg.d_model), dt)
        return {"token": _sds((b, 1), jnp.int32),
                "cache": abstract_cache(cfg, b, s),
                "pos": _sds((), jnp.int32),
                "extras": extras}
    raise ValueError(shape.kind)
