"""Training entrypoint.

On the CPU container this drives a REDUCED config end-to-end (the smoke /
example path); on real hardware the same code runs the full config on the
production mesh. Integrates: deterministic sharded data pipeline, jitted
train step with in/out shardings, async checkpointing, heartbeat/straggler
monitoring, and crash recovery (restart-from-latest).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.checkpoint.fault_tolerance import HeartbeatMonitor
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import adamw
from repro.parallel.sharding import make_rules, param_shardings, use_rules
from repro.train import steps as steps_lib


def build(cfg, opt_cfg, mesh, seed=0):
    rules = make_rules(mesh)
    with use_rules(rules):
        params = steps_lib.init_params(jax.random.PRNGKey(seed), cfg)
        opt_state = adamw.init(params)
    p_shard = param_shardings(params, rules)
    params = jax.device_put(params, p_shard)
    step_fn = steps_lib.make_train_step(cfg, opt_cfg)

    def wrapped(params, opt_state, batch):
        with use_rules(rules):
            return step_fn(params, opt_state, batch)

    jitted = jax.jit(wrapped, donate_argnums=(0, 1))
    return params, opt_state, jitted, rules


def extras_for(cfg, batch_rows, rng):
    out = {}
    if cfg.family == "vlm":
        out["image_embeds"] = rng.standard_normal(
            (batch_rows, cfg.n_image_tokens, cfg.d_model), np.float32
        ).astype(cfg.dtype)
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (batch_rows, cfg.n_audio_frames, cfg.d_model), np.float32
        ).astype(cfg.dtype)
    return out


def train(arch: str, *, reduced: bool = True, steps: int = 20, batch: int = 8,
          seq: int = 64, ckpt_dir: str | None = None, ckpt_every: int = 10,
          lr: float = 1e-3, production_mesh: bool = False,
          resume: bool = True, log_every: int = 5,
          total_steps: int | None = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if production_mesh else make_host_mesh())
    total_steps = total_steps or steps   # schedule horizon (stable across
    # restarts: a resumed run must pass the ORIGINAL horizon or the cosine
    # schedule, and therefore the training trajectory, changes)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=max(2, total_steps // 10),
                                total_steps=total_steps)
    params, opt_state, jitted, rules = build(cfg, opt_cfg, mesh)

    data_cfg = DataConfig(cfg.vocab_size, seq, batch)
    rng = np.random.default_rng(0)
    monitor = HeartbeatMonitor(n_workers=1)

    start = 0
    if ckpt_dir and resume and ckpt_lib.latest_step(ckpt_dir) is not None:
        (params, opt_state), start = ckpt_lib.restore(
            ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    losses = []
    pending_ckpt = None
    for step in range(start, steps):
        t0 = time.monotonic()
        b = batch_for_step(data_cfg, step)
        b.update(extras_for(cfg, batch, rng))
        params, opt_state, metrics = jitted(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.report(0, time.monotonic() - t0)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"dt {time.monotonic()-t0:.2f}s")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            if pending_ckpt is not None:
                pending_ckpt.join()
            pending_ckpt = ckpt_lib.save(
                ckpt_dir, step + 1, (params, opt_state), blocking=False)
    if pending_ckpt is not None:
        pending_ckpt.join()
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, steps, (params, opt_state))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()
    losses = train(args.arch, reduced=args.reduced, steps=args.steps,
                   batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, lr=args.lr)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
