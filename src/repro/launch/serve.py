"""Serving entrypoint: batched prefill + decode with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import make_rules, use_rules
from repro.train import steps as steps_lib


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          greedy: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    rng = np.random.default_rng(seed)

    with use_rules(rules):
        params = steps_lib.init_params(jax.random.PRNGKey(seed), cfg)
    prefill_fn, decode_fn = steps_lib.make_serve_steps(cfg)

    max_len = prompt_len + gen
    cache = steps_lib.init_cache(cfg, batch, max_len)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_image_tokens, cfg.d_model)), cfg.jnp_dtype)
    if cfg.family == "audio":
        from repro.models import whisper
        frames = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_audio_frames, cfg.d_model)), cfg.jnp_dtype)
        with use_rules(rules):
            extras["enc_out"] = whisper.encode(params, frames, cfg)

    def _prefill(params, tokens, cache, extras):
        with use_rules(rules):
            return prefill_fn(params, tokens, cache, extras)

    def _decode(params, token, cache, pos, extras):
        with use_rules(rules):
            return decode_fn(params, token, cache, pos, extras)

    jit_prefill = jax.jit(_prefill, donate_argnums=(2,))
    jit_decode = jax.jit(_decode, donate_argnums=(2,))

    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)
    t0 = time.monotonic()
    logits, cache = jit_prefill(params, jnp.asarray(prompts), cache, extras)
    t_prefill = time.monotonic() - t0

    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.monotonic()
    for i in range(gen):
        outs.append(np.asarray(tok)[:, 0])
        logits, cache = jit_decode(params, tok, cache,
                                   jnp.int32(prompt_len + i), extras)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_decode = time.monotonic() - t0
    gen_tokens = np.stack(outs, 1)
    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.1f}ms; "
          f"decode {gen} steps: {t_decode/gen*1e3:.1f}ms/tok")
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    toks = serve(args.arch, reduced=args.reduced, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen)
    print("generated token grid:\n", toks)


if __name__ == "__main__":
    main()
