"""Serving entrypoints.

LM serving (batched prefill + decode with a KV/SSM cache):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --batch 4 --prompt-len 32 --gen 16

CNN serving through the HybridDNN pipeline — DSE -> compile -> validated,
cached, jitted executor (the paper's Fig. 1 flow end-to-end):

  PYTHONPATH=src python -m repro.launch.serve --arch vgg16 --reduced \
      --batch 8 --iters 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import make_rules, use_rules
from repro.train import steps as steps_lib


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          greedy: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    rng = np.random.default_rng(seed)

    with use_rules(rules):
        params = steps_lib.init_params(jax.random.PRNGKey(seed), cfg)
    prefill_fn, decode_fn = steps_lib.make_serve_steps(cfg)

    max_len = prompt_len + gen
    cache = steps_lib.init_cache(cfg, batch, max_len)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_image_tokens, cfg.d_model)), cfg.jnp_dtype)
    if cfg.family == "audio":
        from repro.models import whisper
        frames = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_audio_frames, cfg.d_model)), cfg.jnp_dtype)
        with use_rules(rules):
            extras["enc_out"] = whisper.encode(params, frames, cfg)

    def _prefill(params, tokens, cache, extras):
        with use_rules(rules):
            return prefill_fn(params, tokens, cache, extras)

    def _decode(params, token, cache, pos, extras):
        with use_rules(rules):
            return decode_fn(params, token, cache, pos, extras)

    jit_prefill = jax.jit(_prefill, donate_argnums=(2,))
    jit_decode = jax.jit(_decode, donate_argnums=(2,))

    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)
    t0 = time.monotonic()
    logits, cache = jit_prefill(params, jnp.asarray(prompts), cache, extras)
    t_prefill = time.monotonic() - t0

    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.monotonic()
    for i in range(gen):
        outs.append(np.asarray(tok)[:, 0])
        logits, cache = jit_decode(params, tok, cache,
                                   jnp.int32(prompt_len + i), extras)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_decode = time.monotonic() - t0
    gen_tokens = np.stack(outs, 1)
    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.1f}ms; "
          f"decode {gen} steps: {t_decode/gen*1e3:.1f}ms/tok")
    return gen_tokens


def make_vgg_params(specs, seed: int = 0):
    """Random [(w, b), ...] for every parameterized layer (CONV + FC)."""
    from repro.core.hybrid_conv import ConvSpec, FCSpec

    rng = np.random.default_rng(seed)
    params = []
    for s in specs:
        if isinstance(s, ConvSpec):
            w = jnp.asarray(rng.standard_normal((s.r, s.s, s.c, s.k)),
                            jnp.float32) * (s.r * s.s * s.c) ** -0.5
            params.append((w, jnp.zeros((s.k,), jnp.float32)))
        elif isinstance(s, FCSpec):
            w = jnp.asarray(rng.standard_normal((s.d_in, s.d_out)),
                            jnp.float32) * s.d_in ** -0.5
            params.append((w, jnp.zeros((s.d_out,), jnp.float32)))
    return params


def build_segmented_request(specs, plans, params, *, strict: bool = False):
    """The legacy multi-Program path: one compiled Program per CONV segment,
    host-side 2x2 maxpool glue between segments, and the FC tail outside
    the runtime. Kept as the ``--segmented`` compatibility path; asserted
    numerically identical to the single-Program path in
    ``tests/test_integration.py``. ``strict=True`` builds the per-segment
    runtimes on the per-instruction interpreter instead of the cached
    jitted executor (the ``--compare-interpreter`` baseline)."""
    from repro.core.compiler import compile_network
    from repro.core.hybrid_conv import ConvSpec, FCSpec, dense, max_pool2d
    from repro.core.runtime import HybridRuntime
    from repro.models import vgg

    # params align with the non-pool specs, in network order
    nonpool = [s for s in specs if not isinstance(s, vgg.PoolSpec)]
    assert len(nonpool) == len(params)
    conv_specs = [s for s in specs if isinstance(s, ConvSpec)]
    conv_plans = [p for s, p in zip(specs, plans) if isinstance(s, ConvSpec)]
    conv_params = [p for s, p in zip(nonpool, params)
                   if isinstance(s, ConvSpec)]
    pool_specs = [s for s in specs if isinstance(s, vgg.PoolSpec)]
    fc_specs = [s for s in nonpool if isinstance(s, FCSpec)]
    fc_params = [p for s, p in zip(nonpool, params) if isinstance(s, FCSpec)]

    runtimes, idx, n_instr = [], 0, 0
    for n in vgg.conv_segments():
        program = compile_network(conv_specs[idx:idx + n],
                                  conv_plans[idx:idx + n])
        rt = HybridRuntime(program, strict=strict)
        rt.load_params(conv_params[idx:idx + n])
        runtimes.append(rt)
        n_instr += len(program.instructions)
        idx += n

    assert len(pool_specs) == len(runtimes), \
        "segmented path expects one maxpool after each CONV segment"

    def request(x):
        for rt, ps in zip(runtimes, pool_specs):
            x = max_pool2d(rt.run(x), ps.window, ps.stride)
        x = x.reshape(x.shape[0], -1)
        for s, (w, b) in zip(fc_specs, fc_params):
            x = dense(x, w, b, relu=s.relu)
        return x

    return request, runtimes, n_instr


def serve_cnn(arch: str = "vgg16", *, reduced: bool = True, batch: int = 8,
              iters: int = 20, seed: int = 0, compare_interpreter: bool = False,
              segmented: bool = False):
    """CNN inference through the full HybridDNN pipeline.

    DSE picks per-layer (mode, dataflow, m, g_h, g_k) over the WHOLE model
    (CONV + POOL + FC latency terms); the compiler lowers all 21 layers to
    ONE 128-bit instruction stream; the runtime validates the schedule ONCE
    and serves every request from the cached jitted executor — steady-state
    requests never touch the Python interpreter. ``segmented=True`` keeps
    the legacy multi-Program path (one Program per CONV segment, host-side
    maxpool glue, FC tail outside the runtime) for comparison.
    """
    from repro.core.compiler import compile_network
    from repro.core.dse import run_tpu_dse
    from repro.core.program_cache import default_cache
    from repro.core.runtime import HybridRuntime
    from repro.models import vgg

    if arch != "vgg16":
        raise ValueError(f"CNN serving supports 'vgg16' (the paper's case "
                         f"study), got {arch!r}")
    iters = max(1, iters)
    img, scale = (64, 8) if reduced else (224, 1)
    n_classes = 10 if reduced else 1000
    specs = vgg.network_specs(img=img, scale=scale, n_classes=n_classes)
    t0 = time.monotonic()
    dse = run_tpu_dse(specs, batch=batch)
    t_dse = time.monotonic() - t0

    params = make_vgg_params(specs, seed)
    n_wino = sum(p.mode == "wino" for s, p in zip(specs, dse.plans)
                 if isinstance(s, vgg.ConvSpec))
    n_spat = sum(p.mode == "spat" for s, p in zip(specs, dse.plans)
                 if isinstance(s, vgg.ConvSpec))

    if segmented:
        request, runtimes, n_instr = build_segmented_request(
            specs, dse.plans, params)
        desc = f"{len(runtimes)} segment Programs + host maxpool/FC glue"
    else:
        program = compile_network(specs, dse.plans)
        rt = HybridRuntime(program)
        rt.load_params(params)
        request = rt.run
        n_instr = len(program.instructions)
        desc = "ONE Program (POOL/FC in-stream)"
    print(f"{arch}: {len(specs)} layers as {desc}, "
          f"{n_wino} wino / {n_spat} spat CONVs; "
          f"DSE {t_dse * 1e3:.0f}ms over {dse.candidates_searched} candidates, "
          f"{n_instr} instructions")

    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.standard_normal((batch, img, img, 3)), jnp.float32)
    t0 = time.monotonic()
    y = jax.block_until_ready(request(x))      # validate + compile + run
    t_first = time.monotonic() - t0
    t0 = time.monotonic()
    for _ in range(iters):                     # steady state: cache hits only
        y = jax.block_until_ready(request(x))
    t_steady = (time.monotonic() - t0) / max(1, iters)
    macs = sum(s.macs for s in specs)
    gops = 2 * macs * batch / 1e9 / t_steady
    cache = default_cache()
    print(f"first request (validate+jit): {t_first * 1e3:.1f}ms; "
          f"steady: {t_steady * 1e3:.2f}ms/batch{batch} "
          f"({gops:.1f} GOPS); cache hits={cache.stats.hits} "
          f"misses={cache.stats.misses}")
    if compare_interpreter:
        if segmented:
            strict_request, _, _ = build_segmented_request(
                specs, dse.plans, params, strict=True)
        else:
            s_rt = HybridRuntime(program, strict=True)
            s_rt.load_params(params)
            strict_request = s_rt.run
        jax.block_until_ready(strict_request(x))   # warm XLA op caches
        t0 = time.monotonic()
        y_i = jax.block_until_ready(strict_request(x))
        t_interp = time.monotonic() - t0
        err = float(jnp.max(jnp.abs(y - y_i)))
        print(f"interpreter: {t_interp * 1e3:.1f}ms/batch "
              f"({t_interp / t_steady:.1f}x slower than cached executor; "
              f"max |diff| {err:.2e})")
    return np.asarray(y)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--iters", type=int, default=20,
                    help="steady-state requests to time (CNN serving)")
    ap.add_argument("--compare-interpreter", action="store_true")
    ap.add_argument("--segmented", action="store_true",
                    help="legacy multi-Program CNN path (one Program per "
                         "CONV segment, host-side maxpool/FC glue)")
    args = ap.parse_args()
    if args.arch.startswith("vgg"):
        y = serve_cnn(args.arch, reduced=args.reduced, batch=args.batch,
                      iters=args.iters,
                      compare_interpreter=args.compare_interpreter,
                      segmented=args.segmented)
        print("logits:", y.shape)
        return
    toks = serve(args.arch, reduced=args.reduced, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen)
    print("generated token grid:\n", toks)


if __name__ == "__main__":
    main()
