"""Serving entrypoints.

LM serving (batched prefill + decode with a KV/SSM cache):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --batch 4 --prompt-len 32 --gen 16

CNN serving through the HybridDNN pipeline — DSE -> compile -> validated,
cached, jitted executor (the paper's Fig. 1 flow end-to-end):

  PYTHONPATH=src python -m repro.launch.serve --arch vgg16 --reduced \
      --batch 8 --iters 20
  PYTHONPATH=src python -m repro.launch.serve --model resnet18 --reduced \
      --batch 4 --iters 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import make_rules, use_rules
from repro.train import steps as steps_lib


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          greedy: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    rng = np.random.default_rng(seed)

    with use_rules(rules):
        params = steps_lib.init_params(jax.random.PRNGKey(seed), cfg)
    prefill_fn, decode_fn = steps_lib.make_serve_steps(cfg)

    max_len = prompt_len + gen
    cache = steps_lib.init_cache(cfg, batch, max_len)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_image_tokens, cfg.d_model)), cfg.jnp_dtype)
    if cfg.family == "audio":
        from repro.models import whisper
        frames = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_audio_frames, cfg.d_model)), cfg.jnp_dtype)
        with use_rules(rules):
            extras["enc_out"] = whisper.encode(params, frames, cfg)

    def _prefill(params, tokens, cache, extras):
        with use_rules(rules):
            return prefill_fn(params, tokens, cache, extras)

    def _decode(params, token, cache, pos, extras):
        with use_rules(rules):
            return decode_fn(params, token, cache, pos, extras)

    jit_prefill = jax.jit(_prefill, donate_argnums=(2,))
    jit_decode = jax.jit(_decode, donate_argnums=(2,))

    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)
    t0 = time.monotonic()
    logits, cache = jit_prefill(params, jnp.asarray(prompts), cache, extras)
    t_prefill = time.monotonic() - t0

    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.monotonic()
    for i in range(gen):
        outs.append(np.asarray(tok)[:, 0])
        logits, cache = jit_decode(params, tok, cache,
                                   jnp.int32(prompt_len + i), extras)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_decode = time.monotonic() - t0
    gen_tokens = np.stack(outs, 1)
    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.1f}ms; "
          f"decode {gen} steps: {t_decode/gen*1e3:.1f}ms/tok")
    return gen_tokens


# Back-compat aliases: both lived here before the ``repro.api`` façade
# (PR 3); benchmarks and tests import them from this module.
from repro.api import build_segmented_request  # noqa: E402,F401
from repro.api import random_params as make_vgg_params  # noqa: E402,F401

CNN_TARGETS = {"tpu": "V5E", "vu9p": "VU9P", "pynq": "PYNQ_Z1"}


def serve_cnn(arch: str = "vgg16", *, reduced: bool = True, batch: int = 8,
              iters: int = 20, seed: int = 0, compare_interpreter: bool = False,
              segmented: bool = False, target: str = "tpu",
              session: bool = False, backend: str = "xla",
              opt_level: int = 1, mesh: str = "host",
              scheduler: str = "continuous", dtype: str = "float32",
              deadline_ms: float | None = None,
              queue_limit: int | None = None):
    """CNN inference through the full HybridDNN pipeline — now a thin driver
    over ``repro.api``.

    ``Accelerator.build`` runs the DSE (per-layer mode/dataflow/m/g_h/g_k
    over the WHOLE model), lowers all 21 layers to ONE 128-bit instruction
    stream, validates the schedule ONCE, and serves every request from the
    cached jitted executor — steady-state requests never touch the Python
    interpreter. ``target`` picks the DSE backend through the unified
    ``Target`` protocol (``tpu``/``vu9p``/``pynq``). ``segmented=True``
    keeps the legacy multi-Program path for comparison, and ``session=True``
    additionally drives requests through the batching (pipelined-dispatch)
    ``ServingSession``. ``backend="pallas"`` serves through the Pallas PE
    kernels (interpret-mode off-TPU) instead of the XLA lowering;
    ``opt_level=0`` disables the lowering optimizer (literal per-block
    lowering — the reference the fused default is tested against).
    ``dtype="int8"`` serves the quantized accelerator (post-training
    calibration on the request distribution, int8 PEs with fused
    requantize, int8-aware DSE — see ``docs/ARCHITECTURE.md``).
    """
    from repro import api
    from repro.core import perf_model as pm
    from repro.core.program_cache import default_cache
    from repro.models import resnet, vgg

    if arch not in ("vgg16", "resnet18"):
        raise ValueError(f"CNN serving supports 'vgg16' (the paper's case "
                         f"study) and 'resnet18' (the residual workload), "
                         f"got {arch!r}")
    if target not in CNN_TARGETS:
        raise ValueError(f"--target must be one of {sorted(CNN_TARGETS)}")
    if segmented and arch == "resnet18":
        raise ValueError(
            "--segmented is the legacy conv-segment path (host-side maxpool "
            "glue between linear CONV runs) — a residual topology has no "
            "such segmentation; resnet18 serves single-Program only")
    iters = max(1, iters)
    img, scale = (64, 8) if reduced else (224, 1)
    n_classes = 10 if reduced else 1000
    if arch == "resnet18":
        specs = resnet.resnet18_specs(img=img, scale=scale,
                                      n_classes=n_classes)
    else:
        specs = vgg.network_specs(img=img, scale=scale, n_classes=n_classes)
    rng = np.random.default_rng(seed + 1)
    x_np = rng.standard_normal((batch, img, img, 3)).astype(np.float32)
    t0 = time.monotonic()
    # int8 calibrates on the request distribution itself — the serving
    # analog of calibrating on a training-set slice
    acc = api.Accelerator.build(specs, target=getattr(pm, CNN_TARGETS[target]),
                                batch=batch, seed=seed, segmented=segmented,
                                backend=backend, opt_level=opt_level,
                                dtype=dtype,
                                calib=x_np if dtype == "int8" else None)
    t_build = time.monotonic() - t0
    print(acc.summary())
    print(f"build (DSE+compile+validate): {t_build * 1e3:.0f}ms; "
          f"PE backend: {backend}; opt_level: {opt_level}; dtype: {dtype}")

    x = jnp.asarray(x_np)
    t0 = time.monotonic()
    y = jax.block_until_ready(acc(x))          # first request: jit trace
    t_first = time.monotonic() - t0
    t0 = time.monotonic()
    for _ in range(iters):                     # steady state: cache hits only
        y = jax.block_until_ready(acc(x))
    t_steady = (time.monotonic() - t0) / max(1, iters)
    macs = sum(s.macs for s in specs)
    gops = 2 * macs * batch / 1e9 / t_steady
    cache = default_cache()
    print(f"first request (jit): {t_first * 1e3:.1f}ms; "
          f"steady: {t_steady * 1e3:.2f}ms/batch{batch} "
          f"({gops:.1f} GOPS); cache hits={cache.stats.hits} "
          f"misses={cache.stats.misses}")
    if session:
        mesh_arg = None if mesh == "none" else mesh
        with acc.serve(max_batch=batch, buckets=(batch,), warmup=True,
                       mesh=mesh_arg, scheduler=scheduler,
                       deadline_ms=deadline_ms,
                       queue_limit=queue_limit) as s:
            n_req = batch * iters
            # materialize requests host-side before timing, like real
            # clients arriving with their own arrays
            reqs = [np.asarray(x[i % batch]) for i in range(n_req)]
            t0 = time.monotonic()
            outs = s.run_many(reqs)
            jax.block_until_ready(outs[-1])
            dt = time.monotonic() - t0
            st = s.stats
            print(f"ServingSession[{scheduler}, mesh={mesh}]: {n_req} "
                  f"requests in {dt * 1e3:.1f}ms "
                  f"({n_req / dt:.1f} req/s, {st.batches} device "
                  f"batches, {st.padded_rows} padded rows, "
                  f"occupancy {st.occupancy():.3f}; "
                  f"latency p50 {st.p50_ms():.2f}ms "
                  f"p95 {st.p95_ms():.2f}ms; "
                  f"queue wait p50 {st.wait_p50_ms():.2f}ms "
                  f"p95 {st.wait_p95_ms():.2f}ms; "
                  f"compile {st.compile_ms:.0f}ms "
                  f"warm-load {st.warm_load_ms:.0f}ms)")
            per_dev = ", ".join(f"{d}: {n}" for d, n in
                                sorted(st.device_batches.items()))
            print(f"  per-device batches: {{{per_dev}}}")
            # failure-model counters: the liveness ledger (submitted ==
            # completed + errors + shed, enforced by the fault suite)
            print(f"  failure model: submitted {st.submitted} = "
                  f"completed {st.requests} + errors {st.errors} + "
                  f"shed {st.shed}; deadline_exceeded "
                  f"{st.deadline_exceeded}, retries {st.retries}, "
                  f"isolated {st.isolated}, degraded {st.degraded}, "
                  f"watchdog restarts {st.watchdog_restarts}")
    if compare_interpreter:
        strict_request = acc.strict_request()
        jax.block_until_ready(strict_request(x))   # warm XLA op caches
        t0 = time.monotonic()
        y_i = jax.block_until_ready(strict_request(x))
        t_interp = time.monotonic() - t0
        if acc.quant is not None:       # both paths emit int8: compare in
            y_i = acc.quant.dequantize_output(y_i)   # the dequantized space
        err = float(jnp.max(jnp.abs(y - y_i)))
        print(f"interpreter: {t_interp * 1e3:.1f}ms/batch "
              f"({t_interp / t_steady:.1f}x slower than cached executor; "
              f"max |diff| {err:.2e})")
    return np.asarray(y)


def main():
    ap = argparse.ArgumentParser()
    # --model is the CNN-serving spelling of the same knob (resnet18/vgg16)
    ap.add_argument("--arch", "--model", dest="arch", required=True)
    # BooleanOptionalAction so --no-reduced actually reaches full-size mode
    # (a bare store_true with default=True made it unreachable)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--iters", type=int, default=20,
                    help="steady-state requests to time (CNN serving)")
    ap.add_argument("--compare-interpreter", action="store_true")
    ap.add_argument("--segmented", action="store_true",
                    help="legacy multi-Program CNN path (one Program per "
                         "CONV segment, host-side maxpool/FC glue)")
    ap.add_argument("--target", default="tpu", choices=sorted(CNN_TARGETS),
                    help="DSE backend for CNN serving (unified Target "
                         "protocol: TPU v5e or the paper's FPGA devices)")
    ap.add_argument("--session", action="store_true",
                    help="also drive requests through the batching "
                         "ServingSession (host-mesh sharded)")
    ap.add_argument("--mesh", default="host", choices=("none", "host"),
                    help="ServingSession device mesh: 'host' shards device "
                         "batches over every local device via shard_map; "
                         "'none' keeps single-device dispatch")
    ap.add_argument("--scheduler", default="continuous",
                    choices=("continuous", "bucketed"),
                    help="ServingSession admission policy: 'continuous' "
                         "keeps admitting while the device pipeline is "
                         "busy; 'bucketed' is the legacy fixed window")
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"),
                    help="PE implementation the executor lowers through "
                         "(pallas runs interpret-mode off-TPU)")
    ap.add_argument("--dtype", default="float32",
                    choices=("float32", "int8"),
                    help="CNN serving precision: int8 builds the quantized "
                         "accelerator (calibrated sidecar, int8 PEs with "
                         "fused requantize, int8-aware DSE)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for the ServingSession: "
                         "requests not completed in time fail with "
                         "DeadlineExceeded instead of waiting forever")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the ServingSession's pending queue; "
                         "overflow requests are shed with Overloaded "
                         "(explicit backpressure instead of unbounded "
                         "memory growth)")
    ap.add_argument("--opt-level", type=int, default=1, choices=(0, 1),
                    help="lowering-optimizer level: 1 fuses each layer's "
                         "per-block loop into one PE dispatch where "
                         "provably equivalent; 0 keeps the literal "
                         "per-block lowering")
    args = ap.parse_args()
    if args.arch.startswith("vgg") or args.arch.startswith("resnet"):
        y = serve_cnn(args.arch, reduced=args.reduced, batch=args.batch,
                      iters=args.iters,
                      compare_interpreter=args.compare_interpreter,
                      segmented=args.segmented, target=args.target,
                      session=args.session, backend=args.backend,
                      opt_level=args.opt_level, mesh=args.mesh,
                      scheduler=args.scheduler, dtype=args.dtype,
                      deadline_ms=args.deadline_ms,
                      queue_limit=args.queue_limit)
        print("logits:", y.shape)
        return
    toks = serve(args.arch, reduced=args.reduced, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen)
    print("generated token grid:\n", toks)


if __name__ == "__main__":
    main()
