"""VGG16 — the paper's case-study model (Sec. 6.1), on the hybrid engine.

13 CONV layers + 3 FC layers. Every CONV routes through ``core.hybrid_conv``
with a per-layer ``LayerPlan`` (mode/dataflow/m) — by default the plan the
TPU DSE selects, or the FPGA DSE's plan for the paper-faithful benchmarks.
Also exposes the ``ConvSpec`` list consumed by the DSE / compiler / runtime
and the perf-model benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.compiler import LayerPlan
from repro.core.hybrid_conv import (
    ConvSpec,
    FCSpec,
    PoolSpec,
    dense,
    hybrid_conv2d,
    max_pool2d,
)
from repro.models.layers import _init

# (input hw, in_ch, out_ch); 'M' = 2x2 maxpool
_VGG16 = [
    (224, 3, 64), (224, 64, 64), "M",
    (112, 64, 128), (112, 128, 128), "M",
    (56, 128, 256), (56, 256, 256), (56, 256, 256), "M",
    (28, 256, 512), (28, 512, 512), (28, 512, 512), "M",
    (14, 512, 512), (14, 512, 512), (14, 512, 512), "M",
]


def conv_specs(img: int = 224, scale: int = 1) -> list[ConvSpec]:
    """The 13 CONV ConvSpecs. ``scale`` divides channel counts (smoke tests);
    ``img`` rescales the input resolution."""
    specs = []
    i = 0
    for entry in _VGG16:
        if entry == "M":
            continue
        h, c, k = entry
        hh = h * img // 224
        specs.append(ConvSpec(
            f"conv{i}", hh, hh, max(3, c // scale) if c == 3 else c // scale,
            k // scale, relu=True))
        i += 1
    return specs


def network_specs(img: int = 224, scale: int = 1, *, n_classes: int = 1000,
                  fc_dim: int | None = None
                  ) -> list[ConvSpec | PoolSpec | FCSpec]:
    """The FULL 21-layer VGG16 as one compilable spec chain: 13 CONVs with
    the 5 interleaved 2x2 maxpools (``PoolSpec``) and the 3-layer FC
    classifier tail (``FCSpec``) — directly consumable by
    ``compile_network`` / ``run_tpu_dse`` so the whole model becomes ONE
    ``Program``. ``scale`` divides channel/FC widths (smoke tests); ``img``
    rescales the input resolution (must be divisible by 32)."""
    convs = conv_specs(img, scale)
    specs: list = []
    ci, hw, c, pi = 0, img, 0, 0
    for entry in _VGG16:
        if entry == "M":
            specs.append(PoolSpec(f"pool{pi}", hw, hw, c))
            hw //= 2
            pi += 1
        else:
            s = convs[ci]
            specs.append(s)
            ci, hw, c = ci + 1, s.h, s.k
    feat = hw * hw * c
    fc_dim = fc_dim or max(64, 4096 // scale)
    specs += [FCSpec("fc1", feat, fc_dim, relu=True),
              FCSpec("fc2", fc_dim, fc_dim, relu=True),
              FCSpec("fc3", fc_dim, n_classes, relu=False)]
    return specs


def conv_segments() -> list[int]:
    """Consecutive-CONV run lengths between maxpools: [2, 2, 3, 3, 3].

    Legacy multi-Program serving (the ``--segmented`` compatibility path):
    one compiled ``Program`` per CONV segment, the 2x2 maxpool applied
    host-side between segments, and the FC tail outside the runtime. The
    default path compiles ``network_specs()`` into ONE Program instead —
    the POOL/FC opcodes put every layer inside the instruction stream.
    """
    sizes, run = [], 0
    for entry in _VGG16:
        if entry == "M":
            if run:
                sizes.append(run)
            run = 0
        else:
            run += 1
    if run:
        sizes.append(run)
    return sizes


def default_plans(specs: list[ConvSpec] | None = None, *,
                  target=None, batch: int = 1) -> list[LayerPlan]:
    """DSE-selected plans through the unified ``Target`` protocol
    (``repro.api``); defaults to the TPU target ``pm.V5E``."""
    from repro.core import perf_model as pm
    specs = specs or conv_specs()
    target = target if target is not None else pm.V5E
    return target.run_dse(specs, batch=batch).plans


def accelerator(*, img: int = 224, scale: int = 1, n_classes: int = 1000,
                target=None, batch: int = 8, seed: int = 0,
                backend: str = "xla", interpret: bool | None = None,
                opt_level: int = 1, **kwargs):
    """One-call VGG16 accelerator: ``network_specs`` ->
    ``api.Accelerator.build`` with every executor knob surfaced —
    ``backend`` (PE implementation), ``interpret`` (Pallas interpret-mode
    override) and ``opt_level`` (lowering optimizer; 0 = literal per-block
    reference) thread through to the cached jitted executor end-to-end.
    Extra keywords pass straight to ``Accelerator.build``."""
    from repro import api
    from repro.core import perf_model as pm
    specs = network_specs(img=img, scale=scale, n_classes=n_classes)
    return api.Accelerator.build(
        specs, target if target is not None else pm.V5E, batch=batch,
        seed=seed, backend=backend, interpret=interpret,
        opt_level=opt_level, **kwargs)


def init_params(key, cfg: ModelConfig | None = None, *, img: int = 224,
                scale: int = 1, n_classes: int = 1000,
                dtype=jnp.float32):
    """Raw VGG16 param pytree for :func:`forward` (not the compiled path).

    ``cfg`` carries no VGG sizing information, so a non-None value would be
    silently ignored — callers who pass one would believe the config shaped
    the model. Raise instead (the ``interpret=`` precedent); size the model
    with the explicit ``img``/``scale``/``n_classes`` keywords.
    """
    if cfg is not None:
        raise ValueError(
            "vgg.init_params does not derive shapes from a ModelConfig — "
            "pass img=/scale=/n_classes= explicitly (cfg would be "
            "silently ignored)")
    specs = conv_specs(img, scale)
    ks = jax.random.split(key, len(specs) + 3)
    convs = []
    for i, s in enumerate(specs):
        w = _init(ks[i], (s.r, s.s, s.c, s.k),
                  scale=(s.r * s.s * s.c) ** -0.5, dtype=dtype)
        b = jnp.zeros((s.k,), dtype)
        convs.append({"w": w, "b": b})
    feat = (img // 32) ** 2 * specs[-1].k
    fc_dim = max(64, 4096 // scale)
    return {
        "convs": convs,
        "fc1": {"w": _init(ks[-3], (feat, fc_dim), dtype=dtype),
                "b": jnp.zeros((fc_dim,), dtype)},
        "fc2": {"w": _init(ks[-2], (fc_dim, fc_dim), dtype=dtype),
                "b": jnp.zeros((fc_dim,), dtype)},
        "fc3": {"w": _init(ks[-1], (fc_dim, n_classes), dtype=dtype),
                "b": jnp.zeros((n_classes,), dtype)},
    }


def forward(params, x_nhwc, plans: list[LayerPlan], *,
            use_pallas: bool = False, interpret: bool | None = None):
    """x: (N, img, img, C0) -> logits (N, n_classes).

    ``interpret`` only affects the Pallas kernels, so passing it with
    ``use_pallas=False`` is a contradiction (the XLA path would silently
    ignore it and the caller would believe interpret mode was exercised) —
    that combination raises ``ValueError`` instead.
    """
    if not use_pallas and interpret is not None:
        raise ValueError(
            f"interpret={interpret!r} has no effect with use_pallas=False — "
            f"the XLA path would silently ignore it; pass use_pallas=True "
            f"or drop interpret")
    x = x_nhwc
    ci = 0
    for entry in _VGG16:
        if entry == "M":
            x = max_pool2d(x)
            continue
        p, plan = params["convs"][ci], plans[ci]
        x = hybrid_conv2d(
            x, p["w"], p["b"], mode=plan.mode, m=plan.m,
            dataflow=plan.dataflow, relu=True, use_pallas=use_pallas,
            interpret=interpret)
        ci += 1
    n = x.shape[0]
    x = x.reshape(n, -1)
    x = dense(x, params["fc1"]["w"], params["fc1"]["b"], relu=True,
              use_pallas=use_pallas, interpret=interpret)
    x = dense(x, params["fc2"]["w"], params["fc2"]["b"], relu=True,
              use_pallas=use_pallas, interpret=interpret)
    return dense(x, params["fc3"]["w"], params["fc3"]["b"])
