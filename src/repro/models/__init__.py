"""Model zoo: pure-functional JAX models for all assigned architectures."""
