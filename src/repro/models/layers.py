"""Shared transformer building blocks (pure functions over param pytrees).

Everything is written in partitionable jnp/lax so GSPMD can shard it; the
Pallas flash-attention kernel is used on the single-device path (and under
shard_map on real TPU; see tests/test_shardmap_kernels.py for the pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import (
    BATCH, EMBED, EXPERT, HEADS, KV_HEADS, MLP, SEQ, VOCAB, shard,
)

Params = dict[str, Any]


def remat_wrap(fn, cfg: "ModelConfig"):
    """Apply jax.checkpoint with the config's remat policy."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "none": save nothing, recompute in bwd



def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    """fp32 variance reduction, bf16 normalize-multiply.

    Keeping the (B, S, D) tensor in bf16 through the normalize matters for
    TP: an fp32 x at the layer boundary makes XLA run the boundary
    reduce-scatter/all-gather in fp32 — 2x the ICI bytes on the dominant
    collectives (measured on minitron train_4k).
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w


def rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / cross-attention / KV cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": _init(ks[0], (d, h * hd), dtype=dtype),
        "wk": _init(ks[1], (d, kv * hd), dtype=dtype),
        "wv": _init(ks[2], (d, kv * hd), dtype=dtype),
        "wo": _init(ks[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention(p: Params, x, cfg: ModelConfig, *, positions=None,
              causal=True, kv_cache=None, cache_pos=None, xattn_kv=None,
              use_rope=True):
    """General attention.

    x: (B, S, D). kv_cache: optional dict(k=(B, Smax, KV, hd), v=...) —
    decode writes at ``cache_pos`` then attends to the full cache.
    xattn_kv: (B, Skv, D) encoder/image states for cross-attention.
    Returns (out, new_kv_cache).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    # TP constraint on the FLAT projection (h*hd always divides the model
    # axis even when the head count does not, e.g. 40 heads on 16 shards);
    # XLA derives a consistent factorized sharding for the head reshape.
    q = shard(x @ p["wq"], BATCH, None, MLP).reshape(b, s, h, hd)
    kv_src = xattn_kv if xattn_kv is not None else x
    skv = kv_src.shape[1]
    k = shard(kv_src @ p["wk"], BATCH, None, MLP).reshape(b, skv, kv, hd)
    v = shard(kv_src @ p["wv"], BATCH, None, MLP).reshape(b, skv, kv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if use_rope and xattn_kv is None:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        if cache_pos is not None:   # decode: insert new K/V at position
            k_full = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_pos, axis=1)
            v_full = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_pos, axis=1)
            new_cache = {"k": k_full, "v": v_full}
            k, v = k_full, v_full
            skv = k.shape[1]
        else:                        # prefill: cache is being built
            new_cache = {"k": k, "v": v}

    # GQA via grouped einsum — never materialize a repeated KV tensor (a
    # repeat of a 32k decode cache is 8x the cache bytes)
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, hd)

    row_offset = (cache_pos if (kv_cache is not None and cache_pos is not None)
                  else (skv - s if causal else 0))
    if s >= 2048:
        # long sequences: scan-flash (O(S*block) memory, partitionable) —
        # materializing the (B, H, S, Skv) fp32 score tensor at 4k-32k seq
        # is GBs/chip even with remat
        out = _flash_attention_scan(qg, k, v, causal=(causal and
                                                      xattn_kv is None),
                                    row_offset=row_offset)
    else:
        scale = hd ** -0.5
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(
            jnp.float32) * scale
        if causal and xattn_kv is None:
            rows_abs = row_offset + jnp.arange(s)[None, None, None, :, None]
            col = jnp.arange(skv)[None, None, None, None, :]
            logits = jnp.where(col <= rows_abs, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    out = shard(out.reshape(b, s, h * hd), BATCH, None, MLP) @ p["wo"]
    return shard(out, BATCH, SEQ, EMBED), new_cache


def _flash_attention_scan(qg, k, v, *, causal: bool, row_offset=0,
                          block: int = 1024):
    """Online-softmax attention via lax.scan over KV blocks (grouped GQA).

    qg: (B, S, KV, R, D) grouped queries; k/v: (B, Skv, KV, D).
    Pure jnp — GSPMD partitions batch/heads; memory is O(S * block) per head.
    The Pallas kernel (kernels/flash_attention) is the single-device/
    shard_map fast path; this is the pjit-internal equivalent.
    """
    b, s, kv, r, d = qg.shape
    skv = k.shape[1]
    scale = d ** -0.5
    nb = -(-skv // block)
    pad = nb * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block, kv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block, kv, d).transpose(1, 0, 2, 3, 4)
    rows = row_offset + jnp.arange(s)[None, None, None, :, None]  # (...,S,1)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ki, vi, bi = inp
        sc = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ki).astype(
            jnp.float32) * scale
        cols = bi * block + jnp.arange(block)[None, None, None, None, :]
        valid = cols < skv
        if causal:
            valid = valid & (cols <= rows)
        sc = jnp.where(valid, sc, -1e30)
        m_cur = jnp.max(sc, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sc - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p.astype(qg.dtype), vi).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv, r, s, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, r, s, 1), jnp.float32)
    acc0 = jnp.zeros((b, kv, r, s, d), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(nb)))
    out = (acc / l_f).transpose(0, 3, 1, 2, 4)   # (B, S, KV, R, D)
    return out.astype(qg.dtype)


# ---------------------------------------------------------------------------
# FFN: SwiGLU (llama-family) and GELU-MLP (whisper)
# ---------------------------------------------------------------------------

def init_swiglu(key, d, f, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d, f), dtype=dtype),
        "w_up": _init(ks[1], (d, f), dtype=dtype),
        "w_down": _init(ks[2], (f, d), dtype=dtype),
    }


def swiglu(p: Params, x):
    g = shard(x @ p["w_gate"], BATCH, None, MLP)
    u = shard(x @ p["w_up"], BATCH, None, MLP)
    return shard((jax.nn.silu(g) * u) @ p["w_down"], BATCH, SEQ, EMBED)


def init_mlp(key, d, f, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {"w_in": _init(ks[0], (d, f), dtype=dtype),
            "w_out": _init(ks[1], (f, d), dtype=dtype),
            "b_in": jnp.zeros((f,), dtype), "b_out": jnp.zeros((d,), dtype)}


def mlp(p: Params, x):
    h = shard(jax.nn.gelu(x @ p["w_in"] + p["b_in"]), BATCH, None, MLP)
    return shard(h @ p["w_out"] + p["b_out"], BATCH, SEQ, EMBED)


# ---------------------------------------------------------------------------
# MoE: top-1 token-choice routing with capacity + optional shared expert
# (llama4-style). Sort-based dispatch — partitionable, experts shard over
# the model axis (EP).
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), scale=d ** -0.5, dtype=jnp.float32),
        "we_gate": _init(ks[1], (e, d, f), scale=d ** -0.5, dtype=dtype),
        "we_up": _init(ks[2], (e, d, f), scale=d ** -0.5, dtype=dtype),
        "we_down": _init(ks[3], (e, f, d), scale=f ** -0.5, dtype=dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_swiglu(ks[4], d, f, dtype)
    return p


def moe(p: Params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D). Top-1 routing, capacity-dropped overflow.

    Dispatch is BATCH-ROW-LOCAL and built ONLY from argsort/cumsum/
    take_along_axis — GSPMD partitions all of them on the batch dim. (A
    batch-indexed scatter/gather formulation gets REPLICATED by the SPMD
    partitioner: measured 12.8 TB/chip/step of collectives on llama4-scout
    train_4k. This version keeps dispatch local; only the expert einsums
    communicate, via EP over the model axis.)
    """
    b, s, d = x.shape
    e = cfg.n_experts

    gate_logits = x.astype(jnp.float32) @ p["router"]           # (B, S, E)
    expert_idx = jnp.argmax(gate_logits, axis=-1)               # (B, S)
    gate = jax.nn.softmax(gate_logits, axis=-1)
    gate_val = jnp.take_along_axis(gate, expert_idx[..., None],
                                   axis=-1)[..., 0]             # (B, S)

    cap = max(1, int(cfg.capacity_factor * s / e) + 1)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)     # (B, S, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                    # (B, S, E)
    pos = jnp.take_along_axis(pos_all, expert_idx[..., None],
                              axis=-1)[..., 0]                  # (B, S)
    keep = pos < cap
    dest = jnp.where(keep, expert_idx * cap + pos, e * cap)     # (B, S)

    # bucket fill via stable sort: tokens grouped by expert, original order
    counts = jnp.sum(onehot, axis=1)                            # (B, E)
    starts = jnp.cumsum(counts, axis=1) - counts                # exclusive
    sort_idx = jnp.argsort(expert_idx, axis=1, stable=True)     # (B, S)
    cidx = jnp.arange(cap)
    src = starts[:, :, None] + cidx[None, None, :]              # (B, E, cap)
    valid = cidx[None, None, :] < jnp.minimum(counts, cap)[:, :, None]
    src = jnp.clip(src, 0, s - 1).reshape(b, e * cap)
    tok_idx = jnp.take_along_axis(sort_idx, src, axis=1)        # (B, E*cap)
    buckets = jnp.take_along_axis(x, tok_idx[..., None], axis=1)
    buckets = buckets * valid.reshape(b, e * cap, 1).astype(x.dtype)
    buckets = shard(buckets.reshape(b, e, cap, d),
                    BATCH, EXPERT, None, None)

    g = jnp.einsum("becd,edf->becf", buckets, p["we_gate"])
    u = jnp.einsum("becd,edf->becf", buckets, p["we_up"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["we_down"])
    y = shard(y, BATCH, EXPERT, None, None).reshape(b, e * cap, d)

    # combine: token s reads its slot (clipped sentinel -> masked by keep)
    out = jnp.take_along_axis(y, jnp.minimum(dest, e * cap - 1)[..., None],
                              axis=1)
    out = out * (keep & (dest < e * cap))[..., None]
    out = out * gate_val[..., None].astype(x.dtype)
    if "shared" in p:
        out = out + swiglu(p["shared"], x)
    return shard(out, BATCH, SEQ, EMBED)


def moe_ref(p: Params, x, cfg: ModelConfig):
    """Oracle: dense per-expert loop, no capacity drops (cap >= T)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    gate_logits = xf.astype(jnp.float32) @ p["router"]
    idx = jnp.argmax(gate_logits, axis=-1)
    gate = jax.nn.softmax(gate_logits, axis=-1)
    gval = jnp.take_along_axis(gate, idx[:, None], axis=-1)[:, 0]
    out = jnp.zeros_like(xf)
    for ei in range(cfg.n_experts):
        m = (idx == ei)[:, None]
        g = xf @ p["we_gate"][ei]
        u = xf @ p["we_up"][ei]
        y = (jax.nn.silu(g) * u) @ p["we_down"][ei]
        out = out + jnp.where(m, y, 0.0)
    out = out * gval[:, None].astype(x.dtype)
    if "shared" in p:
        out = out + swiglu(p["shared"], xf[None])[0]
    return out.reshape(b, s, d)
