"""Zamba2: Mamba2 backbone + a single *shared* attention block
(arXiv:2411.15242).

One attention+FFN block's parameters are reused every ``shared_attn_every``
mamba layers (the Zamba signature trick: attention quality at ~zero parameter
cost). Layers scan in groups of ``shared_attn_every`` mamba blocks with the
shared block applied between groups; a remainder tail (n_layers %
shared_attn_every) runs unrolled without the shared block.

Decode carries both cache kinds: per-mamba-layer SSM/conv states and one KV
cache per shared-block application.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    remat_wrap,
    Params, _init, attention, init_attention, init_swiglu, rms_norm, swiglu,
)
from repro.models.mamba2 import init_mamba_block, mamba_block
from repro.parallel.sharding import BATCH, EMBED, SEQ, VOCAB, shard


def _geometry(cfg: ModelConfig) -> tuple[int, int, int]:
    per = cfg.shared_attn_every
    n_groups = cfg.n_layers // per
    tail = cfg.n_layers - n_groups * per
    return per, n_groups, tail


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype
    per, n_groups, tail = _geometry(cfg)
    ks = jax.random.split(key, 6)

    def stack(trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    gks = jax.random.split(ks[0], n_groups * per)
    groups = stack([
        stack([init_mamba_block(gks[g * per + i], cfg, dtype)
               for i in range(per)])
        for g in range(n_groups)
    ]) if n_groups else None  # leaves: (n_groups, per, ...)
    tks = jax.random.split(ks[1], max(tail, 1))
    tail_layers = (stack([init_mamba_block(tks[i], cfg, dtype)
                          for i in range(tail)]) if tail else None)

    shared = {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[2], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_swiglu(ks[3], cfg.d_model, cfg.d_ff, dtype),
    }
    p = {
        "embed": _init(ks[4], (cfg.vocab_size, cfg.d_model), scale=1.0,
                       dtype=dtype),
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init(ks[5], (cfg.d_model, cfg.vocab_size), dtype=dtype),
    }
    if groups is not None:
        p["groups"] = groups
    if tail_layers is not None:
        p["tail"] = tail_layers
    return p


def _shared_block(shared: Params, x, cfg: ModelConfig, *, positions=None,
                  kv_cache=None, cache_pos=None):
    h, nc = attention(shared["attn"],
                      rms_norm(x, shared["norm"], cfg.norm_eps), cfg,
                      positions=positions, kv_cache=kv_cache,
                      cache_pos=cache_pos)
    x = x + h
    x = x + swiglu(shared["ffn"], rms_norm(x, shared["norm2"], cfg.norm_eps))
    return x, nc


def forward(params: Params, tokens, cfg: ModelConfig) -> jax.Array:
    per, n_groups, tail = _geometry(cfg)
    x = shard(jnp.take(params["embed"], tokens, axis=0), BATCH, SEQ, EMBED)
    shared = params["shared"]

    def group_body(x, group_p):
        for i in range(per):
            lp = jax.tree.map(lambda l: l[i], group_p)
            x, _ = mamba_block(lp, x, cfg)
        x, _ = _shared_block(shared, x, cfg)
        return x, None

    if cfg.remat:
        group_body = remat_wrap(group_body, cfg)
    if n_groups:
        if cfg.scan_layers:
            x, _ = jax.lax.scan(group_body, x, params["groups"])
        else:
            for g in range(n_groups):
                x, _ = group_body(
                    x, jax.tree.map(lambda l: l[g], params["groups"]))
    for i in range(tail):
        lp = jax.tree.map(lambda l: l[i], params["tail"])
        x, _ = mamba_block(lp, x, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return shard(x @ params["lm_head"], BATCH, None, VOCAB)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    per, n_groups, tail = _geometry(cfg)
    conv_dim = cfg.d_ssm + 2 * cfg.ssm_state
    mk = lambda *shape: jnp.zeros(shape, cfg.jnp_dtype)
    cache = {
        "groups_conv": mk(n_groups, per, batch, cfg.ssm_conv - 1, conv_dim),
        "groups_ssm": jnp.zeros((n_groups, per, batch, cfg.n_ssm_heads,
                                 cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "attn_k": mk(n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
        "attn_v": mk(n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
    }
    if tail:
        cache["tail_conv"] = mk(tail, batch, cfg.ssm_conv - 1, conv_dim)
        cache["tail_ssm"] = jnp.zeros((tail, batch, cfg.n_ssm_heads,
                                       cfg.ssm_state, cfg.ssm_head_dim),
                                      jnp.float32)
    return cache


def decode_step(params: Params, token, cache, pos, cfg: ModelConfig):
    """token (B, s) — s=1 decode or s=prompt prefill-into-cache (pos=0)."""
    per, n_groups, tail = _geometry(cfg)
    x = shard(jnp.take(params["embed"], token, axis=0), BATCH, SEQ, EMBED)
    shared = params["shared"]
    s = token.shape[1]
    positions = pos + jnp.arange(s, dtype=jnp.int32)[None, :]

    def group_body(x, inp):
        group_p, conv_c, ssm_c, k_c, v_c = inp
        new_conv, new_ssm = [], []
        for i in range(per):
            lp = jax.tree.map(lambda l: l[i], group_p)
            if s == 1:
                x, nc = mamba_block(lp, x, cfg,
                                    ssm_cache={"conv": conv_c[i],
                                               "ssm": ssm_c[i]})
            else:  # prefill: run chunked SSD, then carry the final state
                x, nc = mamba_block(lp, x, cfg,
                                    ssm_cache={"conv": conv_c[i] * 0,
                                               "ssm": ssm_c[i] * 0})
            new_conv.append(nc["conv"])
            new_ssm.append(nc["ssm"])
        x, akv = _shared_block(shared, x, cfg, positions=positions,
                               kv_cache={"k": k_c, "v": v_c}, cache_pos=pos)
        return x, (jnp.stack(new_conv), jnp.stack(new_ssm),
                   akv["k"], akv["v"])

    if n_groups:
        x, (gc, gs, ak, av) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["groups_conv"], cache["groups_ssm"],
             cache["attn_k"], cache["attn_v"]))
        new_cache = dict(cache, groups_conv=gc, groups_ssm=gs,
                         attn_k=ak, attn_v=av)
    else:
        new_cache = dict(cache)
    for i in range(tail):
        lp = jax.tree.map(lambda l: l[i], params["tail"])
        x, nc = mamba_block(
            lp, x, cfg,
            ssm_cache={"conv": cache["tail_conv"][i] if s == 1
                       else cache["tail_conv"][i] * 0,
                       "ssm": cache["tail_ssm"][i] if s == 1
                       else cache["tail_ssm"][i] * 0})
        new_cache["tail_conv"] = new_cache["tail_conv"].at[i].set(nc["conv"])
        new_cache["tail_ssm"] = new_cache["tail_ssm"].at[i].set(nc["ssm"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x[:, -1] @ params["lm_head"], BATCH, VOCAB)
    return logits, new_cache
