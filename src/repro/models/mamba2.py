"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Chunked SSD: within-chunk attention-like quadratic form + inter-chunk state
recurrence via ``lax.associative_scan``. All einsum/partitionable; heads shard
over the model axis (SSM_HEADS), batch over data. Decode is a constant-time
state update — the reason the ssm/hybrid archs run the ``long_500k`` cell.

Layout: x (B, L, H, P) with H = d_inner/headdim heads, P = headdim;
B/C (B, L, N) single state-group (G=1), broadcast across heads;
dt (B, L, H) post-softplus; A (H,) negative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _init, remat_wrap, rms_norm
from repro.parallel.sharding import BATCH, EMBED, MLP, SEQ, VOCAB, shard


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, a, b, c, initial_state=None):
    """Sequential-recurrence oracle.

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, N).
    Returns (y (B, L, H, P), final_state (B, H, N, P)).
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    s0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        xt, dtt, bt, ct = inp        # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a)     # (B,H)
        sbar = s * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt.astype(jnp.float32))
        yt = jnp.einsum("bn,bhnp->bhp", ct, sbar)
        return sbar, yt

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), s_final


def _segsum(a_blk):
    """a_blk: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums:
    out[i, j] = sum_{k=j+1..i} a[k] for i >= j, -inf otherwise."""
    q = a_blk.shape[-1]
    cs = jnp.cumsum(a_blk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]    # sum_{j+1..i} = cs[i]-cs[j]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int = 64, initial_state=None):
    """Chunked SSD (the paper-efficient algorithm). Same signature as ref."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // q

    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    adt = dtc * a                                  # (B, nc, Q, H) log-decay
    adt_h = adt.transpose(0, 1, 3, 2)              # (B, nc, H, Q)

    # 1) within-chunk (diagonal blocks): quadratic attention-like form.
    # REASSOCIATED into 2-operand steps: a naive 4-operand einsum lets XLA
    # materialize a (B, nc, H, Q, Q, P) 6-D intermediate (~7.5 GB/layer on
    # the zamba2 train_4k cell); the weight matrix W below is (B,nc,H,Q,Q)
    # and the contraction is a plain batched GEMM.
    lmat = jnp.exp(_segsum(adt_h))                 # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, nc, Q, Q)
    w_diag = scores[:, :, None] * lmat * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", w_diag, xc)

    # 2) chunk-final states: contribution of step j decays by a_{j+1..Q-1}
    cs = jnp.cumsum(adt_h, axis=-1)
    decay_states = jnp.exp(cs[..., -1:] - cs)      # (B, nc, H, Q)
    xw = xc * (decay_states.transpose(0, 1, 3, 2) * dtc)[..., None]
    states = jnp.einsum("bcjn,bcjhp->bchnp", bc, xw)  # (B, nc, H, N, P)

    # 3) inter-chunk recurrence (associative scan over chunks)
    chunk_decay = jnp.exp(jnp.sum(adt_h, axis=-1))  # (B, nc, H)

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s2 + d2[..., None, None] * s1

    if initial_state is not None:
        states = jnp.concatenate(
            [initial_state.astype(jnp.float32)[:, None], states], axis=1)
        chunk_decay = jnp.concatenate(
            [jnp.ones_like(chunk_decay[:, :1]), chunk_decay], axis=1)
        _, states_cum = jax.lax.associative_scan(combine,
                                                 (chunk_decay, states), axis=1)
        prev_states = states_cum[:, :-1]           # state entering chunk c
        final_state = states_cum[:, -1]
    else:
        _, states_cum = jax.lax.associative_scan(combine,
                                                 (chunk_decay, states), axis=1)
        prev_states = jnp.concatenate(
            [jnp.zeros_like(states_cum[:, :1]), states_cum[:, :-1]], axis=1)
        final_state = states_cum[:, -1]

    # 4) off-diagonal contribution: C_i * decay(0..i) * S_prev
    decay_out = jnp.exp(jnp.cumsum(adt_h, axis=-1))          # (B, nc, H, Q)
    y_off = jnp.einsum("bcin,bchi,bchnp->bcihp", cc, decay_out, prev_states)

    y = (y_diag + y_off).reshape(bsz, lp, h, p)[:, :l]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, xt, dtt, a, bt, ct):
    """One-token state update: state (B,H,N,P) -> (y (B,H,P), new state)."""
    decay = jnp.exp(dtt * a)
    new_state = state * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dtt, bt, xt.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", ct, new_state)
    return y.astype(xt.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg: ModelConfig, dtype) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_ssm, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), dtype),
        "in_proj": _init(ks[0], (d, 2 * di + 2 * n + h), dtype=dtype),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm2": jnp.ones((di,), dtype),
        "out_proj": _init(ks[2], (di, d), dtype=dtype),
    }


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv1d. u: (B, L, C); w: (K, C); state: (B, K-1, C)."""
    k = w.shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    new_state = up[:, -(k - 1):] if k > 1 else None
    # windowed sum: sum_t w[t] * u[i - (K-1) + t]
    out = sum(w[t] * up[:, t:t + u.shape[1]] for t in range(k))
    return out + b, new_state


def mamba_block(p: Params, x, cfg: ModelConfig, *, ssm_cache=None,
                chunk: int = 64):
    """x: (B, L, D) -> (B, L, D). ssm_cache: {"conv": (B,K-1,C), "ssm":
    (B,H,N,P)} for decode (L==1); None for training/prefill."""
    bsz, l, d = x.shape
    di, n, h = cfg.d_ssm, cfg.ssm_state, cfg.n_ssm_heads
    pdim = cfg.ssm_head_dim

    res = x
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    proj = shard(xn @ p["in_proj"], BATCH, None, MLP)
    z, xin, b_, c_, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xin, b_, c_], axis=-1)
    conv_state = ssm_cache["conv"] if ssm_cache else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, b_, c_ = jnp.split(conv_out, [di, di + n], axis=-1)

    a = -jnp.exp(p["A_log"])                                   # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xin.reshape(bsz, l, h, pdim)
    xh = shard(xh, BATCH, None, "ssm_heads", None)

    if ssm_cache is not None and l == 1:
        y, new_ssm = ssd_decode_step(
            ssm_cache["ssm"], xh[:, 0], dt[:, 0], a, b_[:, 0], c_[:, 0])
        y = y[:, None]
    else:
        init_s = ssm_cache["ssm"] if ssm_cache else None
        y, new_ssm = ssd_chunked(xh, dt, a, b_, c_, chunk=chunk,
                                 initial_state=init_s)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, l, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm2"], cfg.norm_eps)
    out = shard(y @ p["out_proj"], BATCH, SEQ, EMBED)
    new_cache = ({"conv": new_conv, "ssm": new_ssm}
                 if ssm_cache is not None else None)
    return res + out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int):
    """Stacked per-layer decode cache."""
    conv_dim = cfg.d_ssm + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim),
                          cfg.jnp_dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.n_ssm_heads,
                          cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# full model (mamba2-130m: pure SSM stack)
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = [init_mamba_block(ks[i], cfg, dtype) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": _init(ks[-2], (cfg.vocab_size, cfg.d_model), scale=1.0,
                       dtype=dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init(ks[-1], (cfg.d_model, cfg.vocab_size), dtype=dtype),
    }


def forward(params: Params, tokens, cfg: ModelConfig) -> jax.Array:
    x = shard(jnp.take(params["embed"], tokens, axis=0), BATCH, SEQ, EMBED)

    def body(x, layer_p):
        y, _ = mamba_block(layer_p, x, cfg)
        return y, None

    if cfg.remat:
        body = remat_wrap(body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda l: l[i], params["layers"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return shard(x @ params["lm_head"], BATCH, None, VOCAB)


def decode_step(params: Params, token, cache, pos, cfg: ModelConfig):
    """token (B, s); cache from init_ssm_cache. Returns (logits, cache)."""
    x = shard(jnp.take(params["embed"], token, axis=0), BATCH, SEQ, EMBED)

    def body(x, inp):
        layer_p, layer_cache = inp
        y, nc = mamba_block(layer_p, x, cfg, ssm_cache=layer_cache)
        return y, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x[:, -1] @ params["lm_head"], BATCH, VOCAB)
    return logits, new_cache


