"""Decoder-only transformer LM covering the dense / MoE / VLM families.

One implementation, configured by ``ModelConfig``:

* dense GQA (minitron-8b, internlm2-20b, command-r-35b), qk-norm (qwen3-32b)
* MoE FFN every ``moe_every`` layers with top-1 routing + shared expert
  (llama4-scout: every layer, 16 experts; llama4-maverick: alternating,
  128 experts)
* cross-attention image layers every ``cross_attn_every`` layers
  (llama-3.2-vision; patch embeddings arrive pre-computed — stub frontend)

Layers are scan-stacked in repeating *groups* (the smallest period covering
moe_every / cross_attn_every), with per-layer ``jax.checkpoint`` remat, so a
48-layer model compiles one group body. KV caches are (L, B, Smax, KV, hd)
and shard over (SEQ -> model) for decode.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    remat_wrap,
    Params, _init, attention, init_attention, init_moe, init_swiglu,
    moe, rms_norm, swiglu,
)
from repro.parallel.sharding import BATCH, EMBED, SEQ, VOCAB, shard


# ---------------------------------------------------------------------------
# layer-group structure
# ---------------------------------------------------------------------------

def group_period(cfg: ModelConfig) -> int:
    """Layers per scan group (lcm of the MoE and cross-attn periods)."""
    p = 1
    if cfg.n_experts and cfg.moe_every > 1:
        p = math.lcm(p, cfg.moe_every)
    if cfg.cross_attn_every:
        p = math.lcm(p, cfg.cross_attn_every)
    return p


def _layer_kinds(cfg: ModelConfig) -> list[dict]:
    """Description of each layer within one group."""
    kinds = []
    for i in range(group_period(cfg)):
        layer_no = i  # position within group
        is_moe = bool(cfg.n_experts) and (layer_no % cfg.moe_every
                                          == cfg.moe_every - 1)
        is_cross = bool(cfg.cross_attn_every) and (
            layer_no % cfg.cross_attn_every == cfg.cross_attn_every - 1)
        kinds.append({"moe": is_moe, "cross": is_cross})
    return kinds


def init_layer(key, cfg: ModelConfig, kind: dict, dtype) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
    }
    if kind["moe"]:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if kind["cross"]:
        p["xattn"] = init_attention(ks[2], cfg, dtype)
        p["norm3"] = jnp.ones((cfg.d_model,), dtype)
        p["xattn_gate"] = jnp.zeros((1,), dtype)
    return p


def apply_layer(p: Params, x, cfg: ModelConfig, kind: dict, *,
                positions=None, kv_cache=None, cache_pos=None,
                image_embeds=None, causal=True):
    h, new_cache = attention(
        p["attn"], rms_norm(x, p["norm"], cfg.norm_eps), cfg,
        positions=positions, causal=causal,
        kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + h
    if kind["cross"] and image_embeds is not None:
        xh, _ = attention(
            p["xattn"], rms_norm(x, p["norm3"], cfg.norm_eps), cfg,
            xattn_kv=image_embeds, causal=False, use_rope=False)
        x = x + jnp.tanh(p["xattn_gate"]) * xh
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if kind["moe"]:
        x = x + moe(p["moe"], h2, cfg)
    else:
        x = x + swiglu(p["ffn"], h2)
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype
    kinds = _layer_kinds(cfg)
    period = len(kinds)
    n_groups = cfg.n_layers // period
    assert n_groups * period == cfg.n_layers, \
        f"n_layers {cfg.n_layers} not divisible by group period {period}"
    ks = jax.random.split(key, n_groups + 3)

    def stack(leaves):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    groups = []
    for g in range(n_groups):
        gks = jax.random.split(ks[g], period)
        groups.append([init_layer(gks[i], cfg, kinds[i], dtype)
                       for i in range(period)])
    # params["layers"] is a list (len=period) of stacked (n_groups, ...) trees
    layers = [stack([groups[g][i] for g in range(n_groups)])
              for i in range(period)]

    return {
        "embed": _init(ks[-3], (cfg.vocab_size, cfg.d_model), scale=1.0,
                       dtype=dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init(ks[-2], (cfg.d_model, cfg.vocab_size), dtype=dtype),
    }


def _scan_groups(params, cfg: ModelConfig, x, body):
    """Scan ``body`` over the stacked layer groups (optionally remat)."""
    kinds = _layer_kinds(cfg)
    period = len(kinds)

    def group_body(carry, group_params):
        x = carry
        for i in range(period):
            x = body(group_params[i], x, kinds[i])
        return x, None

    if cfg.remat:
        group_body = remat_wrap(group_body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(group_body, x, tuple(params["layers"]))
    else:
        n_groups = cfg.n_layers // period
        for g in range(n_groups):
            gp = [jax.tree.map(lambda l: l[g], params["layers"][i])
                  for i in range(period)]
            x, _ = group_body(x, tuple(gp))
    return x


def forward(params: Params, tokens, cfg: ModelConfig, *,
            image_embeds=None, positions=None) -> jax.Array:
    """Training/prefill forward: (B, S) -> logits (B, S, V)."""
    x = shard(jnp.take(params["embed"], tokens, axis=0), BATCH, SEQ, EMBED)

    def body(p, x, kind):
        x, _ = apply_layer(p, x, cfg, kind, positions=positions,
                           image_embeds=image_embeds)
        return x

    x = _scan_groups(params, cfg, x, body)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x @ params["lm_head"], BATCH, None, VOCAB)
    return logits


# ---------------------------------------------------------------------------
# KV-cache serving path
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per period-slot stacked cache: list of dicts with (G, B, S, KV, hd)."""
    period = group_period(cfg)
    n_groups = cfg.n_layers // period
    shape = (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return [{"k": jnp.zeros(shape, cfg.jnp_dtype),
             "v": jnp.zeros(shape, cfg.jnp_dtype)} for _ in range(period)]


def shard_kv_cache(cache, rules):
    """Caches shard (SEQ -> model, BATCH -> data): flash-decode style."""
    if rules is None:
        return cache
    spec = rules.sharding(None, BATCH, SEQ, None, None)
    return jax.tree.map(lambda x: jax.lax.with_sharding_constraint(x, spec),
                        cache)


def decode_step(params: Params, token, cache, pos, cfg: ModelConfig, *,
                image_embeds=None):
    """One token for every sequence: token (B, 1) int32; pos scalar int32.

    Returns (logits (B, V), new_cache). The cache covers ALL layers: layer
    (g, i) lives at stacked index g of period-slot i. The same path serves
    prefill: pass token (B, S_prompt) with pos=0 (causality is cache-relative).
    """
    x = shard(jnp.take(params["embed"], token, axis=0), BATCH, SEQ, EMBED)
    kinds = _layer_kinds(cfg)
    period = len(kinds)
    s = token.shape[1]
    positions = pos + jnp.arange(s, dtype=jnp.int32)[None, :]

    def group_body(x, group_in):
        group_params, group_cache = group_in
        new_caches = []
        for i in range(period):
            x, nc = apply_layer(
                group_params[i], x, cfg, kinds[i], positions=positions,
                kv_cache=group_cache[i], cache_pos=pos,
                image_embeds=image_embeds)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(
        group_body, x, (tuple(params["layers"]), tuple(cache)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x[:, -1] @ params["lm_head"], BATCH, VOCAB)
    return logits, list(new_cache)


def prefill(params: Params, tokens, cache, cfg: ModelConfig, *,
            image_embeds=None):
    """Fill the KV cache from a prompt; returns (last-token logits, cache)."""
    return decode_step(params, tokens, cache, jnp.int32(0), cfg,
                       image_embeds=image_embeds)
