"""Whisper-base: encoder-decoder transformer (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed log-mel *frame embeddings* (B, n_frames, d_model); the encoder is
the real 6-layer bidirectional transformer, the decoder the real 6-layer
causal + cross-attention stack. Whisper uses pre-LN blocks, GELU MLPs,
learned positional embeddings, and biasless K in attention — we keep the
structural pieces that matter for systems purposes (shapes, caches, enc-dec
dataflow) and use the shared GQA attention (kv=8 == heads: MHA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    remat_wrap,
    Params, _init, attention, init_attention, init_mlp, mlp, rms_norm,
)
from repro.parallel.sharding import BATCH, EMBED, SEQ, VOCAB, shard


def _init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "norm3": jnp.ones((cfg.d_model,), dtype),
        "xattn": init_attention(ks[1], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype
    ks = jax.random.split(key, 6)
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    eks = jax.random.split(ks[0], cfg.encoder_layers)
    dks = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": _init(ks[2], (cfg.vocab_size, cfg.d_model), scale=1.0,
                       dtype=dtype),
        "pos_embed": _init(ks[3], (4096, cfg.d_model), scale=0.02,
                           dtype=dtype),
        "enc_layers": stack([_init_enc_layer(k, cfg, dtype) for k in eks]),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_layers": stack([_init_dec_layer(k, cfg, dtype) for k in dks]),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _init(ks[4], (cfg.d_model, cfg.vocab_size), dtype=dtype),
    }


def encode(params: Params, frames, cfg: ModelConfig) -> jax.Array:
    """frames: (B, n_frames, d_model) stub frontend output -> encoder states."""
    n = frames.shape[1]
    x = frames + params["pos_embed"][:n][None].astype(frames.dtype)
    x = shard(x, BATCH, SEQ, EMBED)

    def body(x, lp):
        h, _ = attention(lp["attn"], rms_norm(x, lp["norm"], cfg.norm_eps),
                         cfg, causal=False, use_rope=False)
        x = x + h
        x = x + mlp(lp["ffn"], rms_norm(x, lp["norm2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(lp, x, enc_out, cfg, *, positions=None, kv_cache=None,
               cache_pos=None):
    h, nc = attention(lp["attn"], rms_norm(x, lp["norm"], cfg.norm_eps), cfg,
                      positions=positions, kv_cache=kv_cache,
                      cache_pos=cache_pos, use_rope=False)
    x = x + h
    xh, _ = attention(lp["xattn"], rms_norm(x, lp["norm3"], cfg.norm_eps),
                      cfg, xattn_kv=enc_out, causal=False, use_rope=False)
    x = x + xh
    x = x + mlp(lp["ffn"], rms_norm(x, lp["norm2"], cfg.norm_eps))
    return x, nc


def forward(params: Params, tokens, frames, cfg: ModelConfig) -> jax.Array:
    """Training forward: frames (B, F, D) + tokens (B, S) -> logits."""
    enc_out = encode(params, frames, cfg)
    s = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0) \
        + params["pos_embed"][:s][None]
    x = shard(x, BATCH, SEQ, EMBED)

    def body(x, lp):
        x, _ = _dec_layer(lp, x, enc_out, cfg)
        return x, None

    if cfg.remat:
        body = remat_wrap(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return shard(x @ params["lm_head"], BATCH, None, VOCAB)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.jnp_dtype),
            "v": jnp.zeros(shape, cfg.jnp_dtype)}


def decode_step(params: Params, token, cache, pos, enc_out,
                cfg: ModelConfig):
    """token (B, s); enc_out precomputed encoder states. -> (logits, cache)."""
    s = token.shape[1]
    pos_ids = pos + jnp.arange(s, dtype=jnp.int32)
    x = jnp.take(params["embed"], token, axis=0) \
        + jnp.take(params["pos_embed"], pos_ids, axis=0)[None]
    x = shard(x, BATCH, SEQ, EMBED)

    def body(x, inp):
        lp, k_c, v_c = inp
        x, nc = _dec_layer(lp, x, enc_out, cfg,
                           kv_cache={"k": k_c, "v": v_c}, cache_pos=pos)
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x[:, -1] @ params["lm_head"], BATCH, VOCAB)
    return logits, {"k": nk, "v": nv}
