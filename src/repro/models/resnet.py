"""Reduced ResNet-18 — the residual workload the ELTWISE_ADD opcode exists
for (HybridDNN Sec. 4.2's "other networks" claim, exercised for real).

Standard basic-block topology: a 3x3 stem + 2x2 maxpool, four stages of two
basic blocks (stages 2-4 opening with a stride-2 block whose shortcut is a
1x1 projection conv), then flatten -> FC. No global average pool: the ISA
has no reduction opcode, so the classifier consumes the flattened 4x4 map —
fine for the reduced configs this repo benchmarks (the point is the residual
DATAFLOW, not ImageNet accuracy).

The whole network is ONE spec chain — ``resnet18_specs()`` feeds straight
into ``api.Accelerator.build`` / ``compile_network`` and becomes ONE
``Program``. Cross-layer wiring is explicit:

  * a strided block's projection conv AND its first 3x3 conv both read the
    block input via ``ConvSpec.inp_from`` (a dataflow fork),
  * every block's ``EltwiseSpec.skip_from`` names the shortcut producer
    (the block input for identity blocks, the projection conv otherwise),

so the compiler's liveness planner must keep the skip tensor resident in
DRAM across the block body — the exact hazard ELTWISE_ADD's two-source
slot-tag discipline was added to cover.

``reference_forward`` replays any spec chain with plain jax.numpy ops —
an executor-independent oracle for the numerical tests.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hybrid_conv import (
    ConvSpec,
    DepthwiseSpec,
    EltwiseSpec,
    FCSpec,
    PoolSpec,
    dense,
    depthwise_conv2d,
    hybrid_conv2d,
    max_pool2d,
)

# blocks per stage — the "18" in ResNet-18 (2-2-2-2 basic blocks)
_STAGES = (2, 2, 2, 2)


def resnet18_specs(img: int = 64, scale: int = 8, *, n_classes: int = 10
                   ) -> list:
    """Reduced ResNet-18 as one compilable spec chain (30 layers: 20 CONV,
    8 ELTWISE_ADD, 1 POOL, 1 FC for the defaults).

    ``scale`` divides the channel widths (base width 64 // scale); ``img``
    is the input resolution and must be divisible by 16 (one maxpool plus
    three stride-2 stages).
    """
    if img % 16:
        raise ValueError(f"img={img} must be divisible by 16 "
                         f"(2x2 maxpool + three stride-2 stages)")
    w0 = max(4, 64 // scale)
    specs: list = []

    def lid() -> int:
        return len(specs) - 1

    # stem: 3x3 conv + 2x2 maxpool (no 7x7: the ISA's COMP path is 3x3)
    specs.append(ConvSpec("stem", img, img, 3, w0, relu=True))
    specs.append(PoolSpec("stem_pool", img, img, w0))
    hw, c = img // 2, w0

    for si, n_blocks in enumerate(_STAGES):
        width = w0 * (2 ** si)
        for bi in range(n_blocks):
            tag = f"s{si + 1}b{bi + 1}"
            strided = si > 0 and bi == 0
            block_in = lid()
            if strided:
                # shortcut: 1x1 stride-2 projection, fed from the block
                # input — this fork is why the compiler needs liveness, not
                # a linear-chain allocator
                specs.append(ConvSpec(f"{tag}_proj", hw, hw, c, width,
                                      r=1, s=1, stride=2, relu=False,
                                      inp_from=block_in))
                skip = lid()
                specs.append(ConvSpec(f"{tag}_conv1", hw, hw, c, width,
                                      stride=2, relu=True,
                                      inp_from=block_in))
                hw, c = hw // 2, width
            else:
                skip = block_in
                specs.append(ConvSpec(f"{tag}_conv1", hw, hw, c, width,
                                      relu=True))
            specs.append(ConvSpec(f"{tag}_conv2", hw, hw, width, width,
                                  relu=False))
            specs.append(EltwiseSpec(f"{tag}_add", hw, hw, width,
                                     skip_from=skip, relu=True))
    specs.append(FCSpec("fc", hw * hw * c, n_classes, relu=False))
    return specs


def accelerator(*, img: int = 64, scale: int = 8, n_classes: int = 10,
                target=None, batch: int = 4, seed: int = 0,
                backend: str = "xla", interpret: bool | None = None,
                opt_level: int = 1, **kwargs):
    """One-call reduced-ResNet-18 accelerator: ``resnet18_specs`` ->
    ``api.Accelerator.build`` (DSE -> compile -> validate) on the TPU
    target by default. Extra keywords pass straight to ``build``."""
    from repro import api
    from repro.core import perf_model as pm
    specs = resnet18_specs(img, scale, n_classes=n_classes)
    return api.Accelerator.build(
        specs, target if target is not None else pm.V5E, batch=batch,
        seed=seed, backend=backend, interpret=interpret,
        opt_level=opt_level, **kwargs)


def reference_forward(params, x_nhwc, specs):
    """Replay a spec chain with plain ops — no Program, no runtime.

    ``params`` is the ``api.random_params`` layout: one ``(w, b)`` per
    parameterized layer (CONV / FC / DEPTHWISE), in spec order. Handles the
    full wiring vocabulary (``inp_from``, ``skip_from``), so it is the
    oracle for ANY topology the compiler accepts, not just ResNet.
    """
    stash = {-1: x_nhwc}
    y = x_nhwc
    pi = 0
    for i, spec in enumerate(specs):
        if isinstance(spec, ConvSpec):
            src = -1 if spec.inp_from == -1 else (
                spec.inp_from if spec.inp_from is not None else i - 1)
            w, b = params[pi]
            pi += 1
            y = hybrid_conv2d(stash[src], w, b, mode="spat",
                              stride=spec.stride, padding=spec.padding,
                              relu=spec.relu, use_pallas=False)
        elif isinstance(spec, PoolSpec):
            y = max_pool2d(stash[i - 1], spec.window, spec.stride)
        elif isinstance(spec, EltwiseSpec):
            y = stash[i - 1] + stash[spec.skip_from]
            if spec.relu:
                y = jnp.maximum(y, 0)
        elif isinstance(spec, DepthwiseSpec):
            w, b = params[pi]
            pi += 1
            y = depthwise_conv2d(stash[i - 1], w, b, stride=spec.stride,
                                 padding=spec.padding, relu=spec.relu)
        elif isinstance(spec, FCSpec):
            w, b = params[pi]
            pi += 1
            x = stash[i - 1]
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            y = dense(x, w, b, relu=spec.relu)
        else:
            raise TypeError(f"unknown spec kind {type(spec).__name__}")
        stash[i] = y
    return y
