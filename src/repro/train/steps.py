"""Model-family dispatch: train_step / prefill / decode_step builders.

``make_train_step(cfg, opt)`` returns a pure step function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` for any
architecture family; ``make_serve_steps(cfg)`` returns (prefill, decode).
These are what the launcher jits with in/out shardings and what the dry-run
lowers on the production mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2, transformer, whisper, zamba2
from repro.optim import adamw
from repro.parallel.sharding import BATCH, SEQ, VOCAB, shard


# ---------------------------------------------------------------------------
# init / forward dispatch
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_params(key, cfg)
    if cfg.family == "ssm":
        return mamba2.init_params(key, cfg)
    if cfg.family == "hybrid":
        return zamba2.init_params(key, cfg)
    if cfg.family == "audio":
        return whisper.init_params(key, cfg)
    raise ValueError(cfg.family)


def forward_logits(params, batch: dict[str, Any], cfg: ModelConfig):
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe"):
        return transformer.forward(params, tokens, cfg)
    if cfg.family == "vlm":
        return transformer.forward(params, tokens, cfg,
                                   image_embeds=batch["image_embeds"])
    if cfg.family == "ssm":
        return mamba2.forward(params, tokens, cfg)
    if cfg.family == "hybrid":
        return zamba2.forward(params, tokens, cfg)
    if cfg.family == "audio":
        return whisper.forward(params, tokens, batch["frames"], cfg)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def cross_entropy(logits, targets):
    """Mean next-token CE. fp32 accumulation WITHOUT materializing an fp32
    copy of the (B, S, V) logits (the exp/sum runs inside a fused reduction;
    an fp32 logits copy alone is ~4 GB/chip at vocab 202k), and WITHOUT
    take_along_axis over the vocab-sharded axis (which would all-gather the
    logits) — the gold logit comes from a one-hot masked reduction that GSPMD
    keeps local + a tiny all-reduce."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) \
        + m[..., 0].astype(jnp.float32)
    v = logits.shape[-1]
    onehot = (targets[..., None] ==
              jnp.arange(v, dtype=targets.dtype)[None, None, :])
    gold = jnp.sum(jnp.where(onehot, logits.astype(jnp.float32), 0.0), axis=-1)
    return jnp.mean(lse - gold)


def make_train_step(cfg: ModelConfig, opt: adamw.AdamWConfig) -> Callable:
    def loss_fn(params, batch):
        logits = forward_logits(params, batch, cfg)
        return cross_entropy(logits, batch["targets"])

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw.update(opt, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_kv_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return mamba2.init_ssm_cache(cfg, batch)
    if cfg.family == "hybrid":
        return zamba2.init_cache(cfg, batch, max_len)
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch, max_len)
    raise ValueError(cfg.family)


def make_serve_steps(cfg: ModelConfig):
    """Returns (prefill, decode). decode(params, token, cache, pos, extras)."""

    def decode(params, token, cache, pos, extras=None):
        extras = extras or {}
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.decode_step(
                params, token, cache, pos, cfg,
                image_embeds=extras.get("image_embeds"))
        if cfg.family == "ssm":
            return mamba2.decode_step(params, token, cache, pos, cfg)
        if cfg.family == "hybrid":
            return zamba2.decode_step(params, token, cache, pos, cfg)
        if cfg.family == "audio":
            return whisper.decode_step(params, token, cache, pos,
                                       extras["enc_out"], cfg)
        raise ValueError(cfg.family)

    def prefill(params, tokens, cache, extras=None):
        return decode(params, tokens, cache, jnp.int32(0), extras)

    return prefill, decode
