import numpy as np
import pytest


def pytest_configure(config):
    # Registered here as well as in pytest.ini so bare `python -m pytest
    # tests/...` invocations from another rootdir still know the tiers.
    config.addinivalue_line(
        "markers", "slow: heavy integration / per-architecture cases "
        "(full tier; excluded by default)")
    config.addinivalue_line(
        "markers", "multidevice: needs >1 device via a subprocess with "
        "forced host devices (excluded by default)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def flip_first_comp(program, layer_id: int = 0):
    """Invert exactly one COMP block's RELU bit -> a non-uniform stream
    that the lowering optimizer must NOT fuse. Shared by the opt-lowering
    unit tests and the hypothesis property suite so the stream-rewriting
    logic cannot drift between them."""
    import dataclasses

    from repro.core.isa import Opcode

    out, done = [], False
    for ins in program.instructions:
        if (not done and ins.opcode == Opcode.COMP
                and ins.layer_id == layer_id):
            out.append(dataclasses.replace(ins, relu_flag=not ins.relu_flag))
            done = True
        else:
            out.append(ins)
    assert done, f"no COMP instruction for layer {layer_id}"
    return type(program)(out, program.layers, program.dram_size_words)
