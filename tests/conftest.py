import numpy as np
import pytest


def pytest_configure(config):
    # Registered here as well as in pytest.ini so bare `python -m pytest
    # tests/...` invocations from another rootdir still know the tiers.
    config.addinivalue_line(
        "markers", "slow: heavy integration / per-architecture cases "
        "(full tier; excluded by default)")
    config.addinivalue_line(
        "markers", "multidevice: needs >1 device via a subprocess with "
        "forced host devices (excluded by default)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
