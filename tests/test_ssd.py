"""SSD (mamba2) chunked algorithm vs sequential-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import (
    _causal_conv, ssd_chunked, ssd_decode_step, ssd_reference,
)


def _rand(l=40, b=2, h=3, p=8, n=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, l, n)) * 0.3
    c = jax.random.normal(ks[4], (b, l, n)) * 0.3
    return x, dt, a, bb, c


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_vs_reference(chunk):
    x, dt, a, b, c = _rand()
    yref, sref = ssd_reference(x, dt, a, b, c)
    y, s = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sref),
                               rtol=2e-4, atol=2e-4)


def test_initial_state_carry():
    x, dt, a, b, c = _rand()
    s0 = jax.random.normal(jax.random.PRNGKey(9), (2, 3, 16, 8)) * 0.2
    yr, _ = ssd_reference(x, dt, a, b, c, initial_state=s0)
    yc, _ = ssd_chunked(x, dt, a, b, c, chunk=16, initial_state=s0)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)


def test_decode_step_equals_scan():
    """Feeding tokens one-by-one through decode == full-sequence SSD."""
    x, dt, a, b, c = _rand(l=12)
    yref, sref = ssd_reference(x, dt, a, b, c)
    s = jnp.zeros((2, 3, 16, 8), jnp.float32)
    ys = []
    for t in range(12):
        y, s = ssd_decode_step(s, x[:, t], dt[:, t], a, b[:, t], c[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(yref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sref),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_state():
    """Streamed conv with state == full-sequence causal conv."""
    u = jax.random.normal(jax.random.PRNGKey(0), (2, 10, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 6)) * 0.5
    b = jax.random.normal(jax.random.PRNGKey(2), (6,)) * 0.1
    full, _ = _causal_conv(u, w, b)
    state = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(10):
        o, state = _causal_conv(u[:, t:t + 1], w, b, state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)
