"""DSE reproduces the paper's configurations and respects constraints."""
import dataclasses

import pytest

from repro.core import perf_model as pm
from repro.core.dse import (
    enumerate_fpga_candidates, run_fpga_dse, run_tpu_dse,
)
from repro.core.hybrid_conv import ConvSpec
from repro.models.vgg import conv_specs


def test_vu9p_reproduces_paper_config():
    """Paper Sec 6.1: VU9P -> PI=4, PO=4, PT=6, NI=6, all-Winograd VGG16."""
    r = run_fpga_dse(pm.VU9P, conv_specs())
    assert (r.hw.pi, r.hw.po, r.hw.pt, r.hw.ni) == (4, 4, 6, 6)
    assert all(p.mode == "wino" for p in r.plans)


def test_vu9p_gops_matches_table4():
    """Paper Table 4: 3375.7 GOPS on VU9P. Model within 5%."""
    specs = conv_specs()
    r = run_fpga_dse(pm.VU9P, specs)
    gops = sum(2 * s.macs for s in specs) / 1e9 / r.total_latency
    assert abs(gops - 3375.7) / 3375.7 < 0.05


def test_pynq_reproduces_paper_config():
    """Paper Sec 6.1: PYNQ-Z1 -> PI=4, PO=4, PT=4, one instance."""
    r = run_fpga_dse(pm.PYNQ_Z1, conv_specs())
    assert (r.hw.pi, r.hw.po, r.hw.pt, r.hw.ni) == (4, 4, 4, 1)


def test_pynq_gops_near_table4():
    """Paper Table 4: 83.3 GOPS on PYNQ-Z1 (within 10%)."""
    specs = conv_specs()
    r = run_fpga_dse(pm.PYNQ_Z1, specs)
    gops = sum(2 * s.macs for s in specs) / 1e9 / r.total_latency
    assert abs(gops - 83.3) / 83.3 < 0.10


def test_candidates_respect_resources():
    for t in (pm.VU9P, pm.PYNQ_Z1):
        for c in enumerate_fpga_candidates(t):
            assert pm.fpga_fits(t, c.pi, c.po, c.pt, c.m, c.ni)
            assert c.pi >= c.po >= 1 and c.pt in (4, 6)


def test_candidates_deduped():
    """Invariant: the candidate list is duplicate-free (the DSE's
    ``candidates_searched`` count and argmin scan rely on it) — including
    on small devices where growth stalls immediately."""
    small = dataclasses.replace(pm.PYNQ_Z1, name="small", luts=8000,
                                dsps=60, bram_18k=40)
    for t in (pm.VU9P, pm.PYNQ_Z1, small):
        cands = enumerate_fpga_candidates(t)
        assert len(cands) == len(set(cands)), t.name


@pytest.mark.slow
def test_fpga_dse_end_to_end_full_network():
    """The FPGA DSE path end-to-end over the full reduced VGG16 spec chain:
    its plans compile to ONE Program, the cached executor agrees bitwise
    with the per-instruction interpreter, and the network function matches
    the TPU-planned Program to float-associativity tolerance (per-layer
    modes may legitimately differ between the two DSE verdicts)."""
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.models import vgg

    specs = vgg.network_specs(img=32, scale=16, n_classes=10)
    r_fpga = run_fpga_dse(pm.VU9P, specs)
    assert len(r_fpga.plans) == len(specs)
    params = api.random_params(specs, seed=0)
    acc_f = api.Accelerator.build(specs, target=pm.VU9P, batch=2,
                                  params=params)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (2, 32, 32, 3)), jnp.float32)
    y_f = np.asarray(acc_f(x))
    assert y_f.shape == (2, 10)
    np.testing.assert_array_equal(y_f, np.asarray(acc_f.strict_request()(x)))
    acc_t = api.Accelerator.build(specs, target=pm.V5E, batch=2,
                                  params=params)
    np.testing.assert_allclose(y_f, np.asarray(acc_t(x)),
                               atol=5e-3, rtol=1e-3)


def test_bandwidth_starved_prefers_spatial():
    """Paper Sec 6.2: when memory-bound, Spatial outperforms Winograd."""
    starved = dataclasses.replace(pm.PYNQ_Z1, bw=0.05e9)
    r = run_fpga_dse(starved, conv_specs())
    n_spat = sum(p.mode == "spat" for p in r.plans)
    assert n_spat > len(r.plans) // 2


def test_wino_stride_ineligible():
    spec = ConvSpec("s2", 16, 16, 4, 8, stride=2)
    r = run_fpga_dse(pm.VU9P, [spec])
    assert r.plans[0].mode == "spat"


def test_wino_kernel_ineligible_1x1_projection():
    """Regression: ``wino_eligible`` used to ignore its ``m`` argument AND
    the kernel size (a vacuous ``r >= 1`` check), so the DSE would plan
    ``wino`` for a ResNet 1x1 projection conv — whose F(m, 3) transform
    does not exist. A 1x1 (or 5x5) conv must plan ``spat`` on every target,
    and ``wino_eligible`` must reject unsupported tile sizes."""
    proj = ConvSpec("proj", 16, 16, 8, 16, r=1, s=1, stride=2, relu=False)
    five = ConvSpec("k5", 16, 16, 4, 8, r=5, s=5)
    for spec in (proj, five):
        assert not spec.wino_eligible(2) and not spec.wino_eligible(4)
        for target in (pm.VU9P, pm.PYNQ_Z1):
            r = run_fpga_dse(target, [spec])
            assert r.plans[0].mode == "spat", (spec.name, target.name)
        rt = run_tpu_dse([spec], batch=2)
        assert rt.plans[0].mode == "spat", spec.name
    # m outside the implemented transform set {2, 4} is ineligible even for
    # the canonical 3x3 stride-1 layer
    ok = ConvSpec("c3", 16, 16, 4, 8)
    assert ok.wino_eligible(2) and ok.wino_eligible(4)
    assert not ok.wino_eligible(3) and not ok.wino_eligible(6)


def test_dse_plans_residual_specs():
    """EltwiseSpec/DepthwiseSpec ride through both DSE paths: NO_PLAN rows,
    nonzero latency contribution (candidates rank on the FULL network)."""
    from repro.core.hybrid_conv import DepthwiseSpec, EltwiseSpec
    specs = [ConvSpec("c1", 16, 16, 3, 8),
             EltwiseSpec("e1", 16, 16, 8, skip_from=-1),
             DepthwiseSpec("d1", 16, 16, 8)]
    for run in (lambda s: run_fpga_dse(pm.VU9P, s),
                lambda s: run_tpu_dse(s, batch=2)):
        r = run(specs)
        assert len(r.plans) == 3
        assert r.plans[1].mode != "wino" and r.plans[2].mode != "wino"
        assert all(lat > 0 for lat in r.layer_latencies)
        conv_only = run([specs[0]])
        assert r.total_latency > conv_only.total_latency


def test_tpu_dse_vmem_constraint():
    r = run_tpu_dse(conv_specs(), batch=8)
    from repro.core.dse import enumerate_tpu_candidates
    cands = enumerate_tpu_candidates()
    assert r.hw in cands
    assert r.total_latency > 0
    # VMEM working-set bound (Eq. 4 analog) holds for the winner
    working = 4 * 2 * (r.hw.bm * r.hw.bk + r.hw.bk * r.hw.bn
                       + r.hw.bm * r.hw.bn)
    assert working <= pm.V5E.vmem_bytes // 2


def test_estimated_latency_monotone_in_bandwidth():
    specs = conv_specs()
    lats = []
    for bw in (5e9, 20e9, 80e9):
        t = dataclasses.replace(pm.VU9P, bw=bw)
        lats.append(run_fpga_dse(t, specs).total_latency)
    assert lats[0] >= lats[1] >= lats[2]
