"""Integration: training loop convergence, checkpoint/restart bit-exactness,
crash recovery, elastic restore, data determinism, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.checkpoint.fault_tolerance import (
    HeartbeatMonitor, run_with_recovery,
)
from repro.data.pipeline import DataConfig, PrefetchingLoader, batch_for_step
from repro.launch.train import train


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    losses = train("minitron-8b", reduced=True, steps=25, batch=4, seq=32,
                   ckpt_dir=None, lr=3e-3, log_every=100)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


@pytest.mark.slow
def test_vgg_runtime_training_signal():
    """VGG16 (reduced) forward through hybrid engine produces gradients."""
    from repro.core.compiler import LayerPlan
    from repro.models import vgg
    key = jax.random.PRNGKey(0)
    params = vgg.init_params(key, img=32, scale=16, n_classes=10)
    specs = vgg.conv_specs(img=32, scale=16)
    plans = [LayerPlan("wino", "is", m=2) for _ in specs]
    x = jax.random.normal(key, (2, 32, 32, 3), jnp.float32)
    y = jnp.array([1, 3])

    def loss_fn(p):
        logits = vgg.forward(p, x, plans)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(2), y])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_vgg_single_program_matches_segmented():
    """The full reduced VGG16 (13 CONV + 5 POOL + 3 FC) built through the
    ``repro.api`` façade as ONE Program produces the same logits as the
    legacy multi-Program path (``Accelerator.build(..., segmented=True)``:
    per-segment Programs + host-side maxpool glue + FC tail outside the
    runtime) — and the one-Program strict interpreter matches the cached
    jitted executor bitwise."""
    from repro import api
    from repro.core.compiler import LayerPlan
    from repro.core.hybrid_conv import ConvSpec
    from repro.models import vgg

    img, scale = 32, 16
    specs = vgg.network_specs(img=img, scale=scale, n_classes=10)
    # alternate wino/spat CONV plans so the one-Program path exercises the
    # POOL->WINO layout reorder and the U-space weight path, not just spat
    ci = 0
    plans = []
    for s in specs:
        if isinstance(s, ConvSpec):
            plans.append(LayerPlan("wino" if ci % 2 == 0 else "spat",
                                   "is" if ci % 2 else "ws", m=2))
            ci += 1
        else:
            plans.append(None)
    acc = api.Accelerator.build(specs, plans=plans, seed=0, batch=2)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, img, img, 3)), jnp.float32)

    y_single = acc(x)
    assert y_single.shape == (2, 10)

    # acceptance: strict interpreter == cached jitted executor, bitwise
    y_strict = acc.strict_request()(x)
    np.testing.assert_array_equal(np.asarray(y_single), np.asarray(y_strict))

    # compatibility: segmented path numerically identical (the old
    # build_segmented_request glue, now behind the façade)
    acc_seg = api.Accelerator.build(specs, plans=plans, params=acc.params,
                                    batch=2, segmented=True)
    y_seg = acc_seg(x)
    np.testing.assert_array_equal(np.asarray(y_single), np.asarray(y_seg))


@pytest.mark.slow
def test_serve_cnn_segmented_flag_matches_default():
    """serve_cnn's --segmented compatibility path end-to-end (DSE plans,
    program cache, random params) agrees with the single-Program default."""
    from repro.launch.serve import serve_cnn
    y1 = serve_cnn("vgg16", reduced=True, batch=2, iters=1, seed=3)
    y2 = serve_cnn("vgg16", reduced=True, batch=2, iters=1, seed=3,
                   segmented=True)
    np.testing.assert_array_equal(y1, y2)


@pytest.mark.slow
def test_serve_cnn_matches_direct_accelerator_build():
    """The serve entrypoint is a thin driver over the façade: a direct
    ``Accelerator.build(...)(x)`` with the same seed/batch reproduces
    serve_cnn's logits bitwise."""
    from repro import api
    from repro.core import perf_model as pm
    from repro.launch.serve import serve_cnn
    from repro.models import vgg

    y = serve_cnn("vgg16", reduced=True, batch=2, iters=1, seed=5)
    specs = vgg.network_specs(img=64, scale=8, n_classes=10)
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=2, seed=5)
    x = jnp.asarray(np.random.default_rng(6).standard_normal(
        (2, 64, 64, 3)), jnp.float32)
    np.testing.assert_array_equal(y, np.asarray(acc(x)))


@pytest.mark.slow
def test_checkpoint_restart_bitexact(tmp_path):
    """Train 10; vs train 5 -> restore -> train 5: identical params."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    train("mamba2-130m", reduced=True, steps=10, batch=2, seq=16,
          ckpt_dir=d1, ckpt_every=100, log_every=100, total_steps=10)
    train("mamba2-130m", reduced=True, steps=5, batch=2, seq=16,
          ckpt_dir=d2, ckpt_every=5, log_every=100, total_steps=10)
    train("mamba2-130m", reduced=True, steps=10, batch=2, seq=16,
          ckpt_dir=d2, ckpt_every=5, resume=True, log_every=100,
          total_steps=10)
    a = np.load(os.path.join(d1, "step_00000010", "arrays.npz"))
    b = np.load(os.path.join(d2, "step_00000010", "arrays.npz"))
    assert set(a.files) == set(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_crash_recovery(tmp_path):
    """A step that dies mid-run resumes from the last checkpoint."""
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 7 and calls["n"] == 8:    # fail once at step 7
            raise RuntimeError("simulated node failure")
        return {"x": state["x"] + 1.0}

    state, log = run_with_recovery(
        step_fn, {"x": jnp.zeros(())}, n_steps=10,
        ckpt_dir=str(tmp_path), ckpt_every=5)
    assert log["restarts"] == 1
    assert float(state["x"]) == 10.0   # every step applied exactly once


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written on one mesh restores onto a different mesh."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt_lib.save(str(tmp_path), 3, tree)
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("model",))
    sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("model"))}
    restored, step = ckpt_lib.restore(str(tmp_path), tree, shardings=sh)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    b1 = batch_for_step(cfg, 5, shard=0, n_shards=2)
    b2 = batch_for_step(cfg, 5, shard=0, n_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_for_step(cfg, 5, shard=1, n_shards=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are next-token shifted
    full = batch_for_step(cfg, 0)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["targets"][:, :-1])


def test_prefetching_loader():
    cfg = DataConfig(vocab_size=50, seq_len=4, global_batch=4)
    loader = PrefetchingLoader(cfg, prefetch=2)
    seen = [next(loader) for _ in range(3)]
    loader.close()
    assert [s for s, _ in seen] == [0, 1, 2]
    ref = batch_for_step(cfg, 1)
    np.testing.assert_array_equal(seen[1][1]["tokens"], ref["tokens"])


def test_straggler_detection():
    mon = HeartbeatMonitor(n_workers=8, window=8, zscore_threshold=3.0)
    for step in range(8):
        for w in range(8):
            mon.report(w, 1.0 + (5.0 if w == 3 else 0.0), now=float(step))
    assert mon.stragglers() == [3]
    assert mon.dead(now=1000.0) == list(range(8))


def test_async_checkpoint(tmp_path):
    tree = {"a": jnp.ones((128, 128))}
    t = ckpt_lib.save(str(tmp_path), 1, tree, blocking=False)
    t.join()
    restored, step = ckpt_lib.restore(str(tmp_path), tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
