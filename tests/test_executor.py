"""Validate-once/trace-many executor: parity with the interpreter across
every (mode x dataflow x padding) cell, program-cache hit behavior, and
schedule-key identity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compiler import LayerPlan, Program, compile_network
from repro.core.executor import (
    compile_executor,
    lower_program,
    to_dram_params,
    validate_schedule,
)
from repro.core.hybrid_conv import ConvSpec
from repro.core.program_cache import ProgramCache
from repro.core.runtime import HybridRuntime, run_program

# atol/rtol per dtype: the jitted executor may fuse/reassociate what the
# interpreter dispatched op-by-op
_TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _net(padding="SAME", dtype=jnp.float32):
    h = 12
    specs = [
        ConvSpec("c1", h, h, 3, 8, padding=padding, relu=True),
        ConvSpec("c2", h - (2 if padding == "VALID" else 0),
                 h - (2 if padding == "VALID" else 0), 8, 12,
                 padding=padding, relu=False),
    ]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        params.append((
            (jax.random.normal(kw, (s.r, s.s, s.c, s.k)) * 0.2).astype(dtype),
            (jax.random.normal(kb, (s.k,)) * 0.1).astype(dtype)))
    x = jax.random.normal(jax.random.PRNGKey(99), (2, h, h, 3)).astype(dtype)
    return specs, params, x


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("dataflow", ["is", "ws"])
@pytest.mark.parametrize("mode", ["spat", "wino"])
def test_executor_matches_interpreter(mode, dataflow, padding):
    """Jitted executor == per-instruction interpreter on a 2-layer net with
    mixed modes between layers (exercising the WINO<->SPAT reorders)."""
    specs, params, x = _net(padding)
    other = "spat" if mode == "wino" else "wino"
    plans = [LayerPlan(mode, dataflow, 2, 2, 2),
             LayerPlan(other, dataflow, 2, 1, 2)]
    prog = compile_network(specs, plans)
    y_interp = run_program(prog, params, x, strict=True)
    y_jit = run_program(prog, params, x)
    assert y_jit.shape == y_interp.shape and y_jit.dtype == y_interp.dtype
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_interp),
                               **_TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_executor_dtype_parity(dtype):
    specs, params, x = _net("SAME", dtype)
    plans = [LayerPlan("wino", "is", 2, 2, 2), LayerPlan("spat", "ws", 2, 2, 2)]
    prog = compile_network(specs, plans)
    y_interp = run_program(prog, params, x, strict=True)
    y_jit = run_program(prog, params, x)
    assert y_jit.dtype == dtype
    np.testing.assert_allclose(np.asarray(y_jit, np.float32),
                               np.asarray(y_interp, np.float32), **_TOL[dtype])


def test_executor_stats_match_interpreter():
    """Schedule validation produces the interpreter's pipeline counters."""
    specs, params, x = _net()
    plans = [LayerPlan("wino", "is", 2, 2, 2), LayerPlan("spat", "ws", 2, 3, 2)]
    prog = compile_network(specs, plans)
    rt_i = HybridRuntime(prog, strict=True)
    rt_i.load_params(params)
    rt_i.run(x)
    rt_j = HybridRuntime(prog)
    rt_j.load_params(params)
    rt_j.run(x)
    assert rt_i.stats == rt_j.stats
    assert rt_j.stats == validate_schedule(prog)


def test_cache_hit_same_program_no_retrace():
    """Same Program + batch + dtype -> the same compiled fn, traced once."""
    specs, params, x = _net()
    plans = [LayerPlan("spat", "is", 2, 2, 2), LayerPlan("wino", "is", 2, 2, 2)]
    prog = compile_network(specs, plans)
    cache = ProgramCache()
    dram = to_dram_params(prog, params)
    e1 = cache.get(prog, batch=2, dtype=jnp.float32)
    e1(dram, x)
    e2 = cache.get(prog, batch=2, dtype=jnp.float32)
    e2(dram, x)
    assert e1 is e2
    assert e1.trace_count == 1
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_cache_recompiled_program_shares_entry():
    """compile_network twice from the same specs/plans -> same schedule key
    -> one cache entry (validate-once survives recompiles)."""
    specs, params, x = _net()
    plans = [LayerPlan("spat", "is", 2, 2, 2), LayerPlan("wino", "is", 2, 2, 2)]
    p1 = compile_network(specs, plans)
    p2 = compile_network(specs, plans)
    assert p1 is not p2 and p1.schedule_key() == p2.schedule_key()
    cache = ProgramCache()
    assert cache.get(p1, batch=2, dtype=jnp.float32) \
        is cache.get(p2, batch=2, dtype=jnp.float32)


def test_cache_key_separates_batch_and_dtype():
    specs, params, x = _net()
    plans = [LayerPlan("spat", "is", 2, 1, 1), LayerPlan("spat", "is", 2, 1, 1)]
    prog = compile_network(specs, plans)
    cache = ProgramCache()
    a = cache.get(prog, batch=2, dtype=jnp.float32)
    b = cache.get(prog, batch=4, dtype=jnp.float32)
    c = cache.get(prog, batch=2, dtype=jnp.bfloat16)
    assert a is not b and a is not c and b is not c
    assert cache.stats.misses == 3 and len(cache) == 3


def test_cache_lru_eviction():
    specs, params, x = _net()
    plans = [LayerPlan("spat", "is", 2, 1, 1), LayerPlan("spat", "is", 2, 1, 1)]
    prog = compile_network(specs, plans)
    cache = ProgramCache(maxsize=2)
    for batch in (1, 2, 3):
        cache.get(prog, batch=batch, dtype=jnp.float32)
    assert len(cache) == 2 and cache.stats.evictions == 1


def test_schedule_key_changes_with_stream():
    specs, params, x = _net()
    plans = [LayerPlan("spat", "is", 2, 2, 2), LayerPlan("spat", "is", 2, 2, 2)]
    p1 = compile_network(specs, plans)
    p2 = compile_network(specs, [LayerPlan("wino", "is", 2, 2, 2),
                                 LayerPlan("spat", "is", 2, 2, 2)])
    assert p1.schedule_key() != p2.schedule_key()


def test_lowered_fn_is_jittable_and_gradable():
    """The lowered executor is a pure jax function: grads flow through it."""
    specs, params, x = _net()
    plans = [LayerPlan("wino", "is", 2, 2, 2), LayerPlan("spat", "is", 2, 2, 2)]
    prog = compile_network(specs, plans)
    validate_schedule(prog)
    execute = lower_program(prog)

    def loss(params):
        # differentiate through the raw->U-space transform AND the executor
        return jnp.sum(execute(to_dram_params(prog, params), x) ** 2)

    g = jax.grad(loss)(params)
    total = sum(float(jnp.sum(jnp.abs(leaf))) for leaf in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


def test_executor_honors_comp_relu_bit():
    """The stream's RELU bits are authoritative: a hand-flipped COMP relu
    flag must change the executor's output exactly like the interpreter's."""
    from repro.core.isa import Opcode
    specs, params, x = _net()
    plans = [LayerPlan("spat", "is", 2, 2, 2), LayerPlan("spat", "is", 2, 2, 2)]
    prog = compile_network(specs, plans)
    flipped = Program(
        [dataclasses.replace(i, relu_flag=False) if i.opcode == Opcode.COMP
         else i for i in prog.instructions],
        prog.layers, prog.dram_size_words)
    y_interp = run_program(flipped, params, x, strict=True)
    y_jit = run_program(flipped, params, x)
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_interp),
                               **_TOL[jnp.float32])
    # and the flip actually matters: relu-on vs relu-off streams differ
    y_relu = run_program(prog, params, x, strict=True)
    assert not np.allclose(np.asarray(y_interp), np.asarray(y_relu))


def test_run_with_input_then_replay_from_dram():
    """run(x) persists the input in DRAM like strict mode, so run() replays."""
    specs, params, x = _net()
    plans = [LayerPlan("wino", "is", 2, 2, 2), LayerPlan("spat", "is", 2, 2, 2)]
    prog = compile_network(specs, plans)
    rt = HybridRuntime(prog)
    rt.load_params(params)
    y1 = rt.run(x)
    y2 = rt.run()          # no input: replay from DRAM, as strict mode does
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=0)


def test_fc_first_program_replay_both_paths():
    """An FC-only Program (no spatial first layer) runs and replays from
    DRAM identically on the jitted and strict paths."""
    from repro.core.hybrid_conv import FCSpec
    specs = [FCSpec("f1", 8, 6, relu=True), FCSpec("f2", 6, 4)]
    prog = compile_network(specs, [None, None])
    params = [
        (jax.random.normal(jax.random.PRNGKey(0), (8, 6)) * 0.3,
         jnp.zeros((6,))),
        (jax.random.normal(jax.random.PRNGKey(1), (6, 4)) * 0.3,
         jnp.zeros((4,))),
    ]
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8))
    rt = HybridRuntime(prog)
    rt.load_params(params)
    y1 = rt.run(x)
    y2 = rt.run()                      # replay from DRAM, FC-first
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    y3 = run_program(prog, params, x, strict=True)
    assert y3.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y3))


def test_compile_executor_reports_stats():
    specs, params, x = _net()
    plans = [LayerPlan("spat", "ws", 2, 2, 2), LayerPlan("spat", "is", 2, 2, 2)]
    prog = compile_network(specs, plans)
    ex = compile_executor(prog)
    assert ex.stats["comp"] == sum(
        len(cl.row_groups) * len(cl.k_groups) for cl in prog.layers)
    y = ex(params, x)
    assert y.shape == (2, 12, 12, specs[-1].k)
