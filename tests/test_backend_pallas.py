"""The Pallas PE backend: numerical parity with the XLA lowering and the
strict interpreter, cache-key separation, and the interpret-mode fallback.

Tolerance contract (documented in docs/ARCHITECTURE.md): both backends
compute the same blocked schedule in fp32 accumulation, but the Pallas
kernels pad to MXU block multiples and the XLA path may reassociate
differently, so outputs agree to ~1e-4 abs/rel on fp32 — the same budget
``tests/test_executor.py`` grants the executor-vs-interpreter comparison.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.compiler import LayerPlan, compile_network
from repro.core.executor import resolve_backend
from repro.core.hybrid_conv import ConvSpec
from repro.core.program_cache import ProgramCache
from repro.core.runtime import HybridRuntime, run_program
from repro.models import vgg

TOL = dict(rtol=1e-4, atol=1e-4)


def _reduced_vgg(img=32, scale=32, batch=2, n_classes=10, seed=0):
    """Full 21-layer reduced VGG16 (13 CONV + 5 POOL + 3 FC), tiny widths.

    The first two CONVs get multi-group plans (2x2 row/k blocks) so the
    blocked Pallas lowering is exercised; the tail runs single-block to keep
    interpret-mode trace time inside the fast-tier budget (every extra block
    is three more Pallas calls in the trace).
    """
    specs = vgg.network_specs(img=img, scale=scale, n_classes=n_classes)
    plans = []
    ci = 0
    for s in specs:
        if isinstance(s, ConvSpec):
            g = 2 if ci < 2 else 1
            plans.append(LayerPlan("wino" if ci % 2 == 0 else "spat",
                                   "is" if ci % 2 else "ws", m=2,
                                   g_k=g, g_h=g))
            ci += 1
        else:
            plans.append(None)
    params = api.random_params(specs, seed)
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(
        (batch, img, img, 3)), jnp.float32)
    return specs, plans, params, x


@pytest.fixture(scope="module")
def vgg_pallas_setup():
    """One shared build of the reduced-VGG accelerators (both backends share
    one ProgramCache, so the key-separation assertions are real)."""
    specs, plans, params, x = _reduced_vgg()
    cache = ProgramCache()
    acc_xla = api.Accelerator.build(specs, plans=plans, params=params,
                                    batch=2, cache=cache)
    acc_pal = api.Accelerator.build(specs, plans=plans, params=params,
                                    batch=2, cache=cache, backend="pallas")
    return cache, acc_xla, acc_pal, x


def test_resolve_backend_contract():
    assert resolve_backend("xla", None) == ("xla", None)
    # interpret= on the XLA backend would be silently meaningless — reject
    # it, mirroring the vgg.forward use_pallas/interpret guard
    with pytest.raises(ValueError, match="backend='pallas'"):
        resolve_backend("xla", True)
    backend, interp = resolve_backend("pallas", None)
    assert backend == "pallas"
    # off-TPU the auto-selection must fall back to interpret mode
    if jax.default_backend() != "tpu":
        assert interp is True
    assert resolve_backend("pallas", False) == ("pallas", False)
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda", None)


def test_accelerator_pallas_matches_xla_and_interpreter(vgg_pallas_setup):
    """The acceptance gate: Accelerator.build(backend="pallas") over the full
    reduced VGG16 == the XLA backend == the strict interpreter, with the
    interpret-mode fallback (CPU CI) exercised by default."""
    cache, acc_xla, acc_pal, x = vgg_pallas_setup
    y_xla = np.asarray(acc_xla(x))
    y_pal = np.asarray(acc_pal(x))
    y_strict = np.asarray(acc_pal.strict_request()(x))
    assert y_pal.shape == y_xla.shape == y_strict.shape
    np.testing.assert_allclose(y_pal, y_xla, **TOL)
    np.testing.assert_allclose(y_pal, y_strict, **TOL)
    # both backends live side by side in ONE cache under distinct keys
    assert acc_pal.runtime.cache is cache
    assert cache.stats.misses == 2
    ent = acc_pal.runtime.executor_entry(2, jnp.float32)[0]
    assert ent.backend == "pallas"
    if jax.default_backend() != "tpu":
        assert ent.interpret is True    # the CPU fallback actually engaged


def test_strict_interpreter_pallas_backend_small_net():
    """backend= applies to the per-instruction interpreter too (runtime.py's
    COMP/FC handlers share conv_block_forward/fc_forward with the executor)."""
    h = 12
    specs = [ConvSpec("c1", h, h, 3, 8, relu=True),
             ConvSpec("c2", h, h, 8, 12, relu=False)]
    plans = [LayerPlan("wino", "is", 2, 2, 2), LayerPlan("spat", "ws", 2, 1, 2)]
    params = api.random_params(specs, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, h, h, 3))
    prog = compile_network(specs, plans)
    y_ref = run_program(prog, params, x, strict=True)
    y_pal = run_program(prog, params, x, strict=True, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref), **TOL)


def test_cache_key_separates_backends():
    h = 12
    specs = [ConvSpec("c", h, h, 3, 8)]
    plans = [LayerPlan("spat", "is", 2, 1, 1)]
    prog = compile_network(specs, plans)
    cache = ProgramCache()
    e_xla = cache.get(prog, batch=1, dtype=jnp.float32)
    e_pal = cache.get(prog, batch=1, dtype=jnp.float32, backend="pallas")
    assert e_xla is not e_pal and len(cache) == 2
    # auto-resolved interpret and the equivalent explicit value share a key
    _, resolved = resolve_backend("pallas", None)
    e_pal2 = cache.get(prog, batch=1, dtype=jnp.float32, backend="pallas",
                       interpret=resolved)
    assert e_pal2 is e_pal
    assert cache.stats.hits == 1
    with pytest.raises(ValueError, match="unknown backend"):
        cache.get(prog, batch=1, dtype=jnp.float32, backend="tpu")


def test_runtime_backend_spellings_agree():
    """backend="pallas" and the legacy use_pallas=True are the same knob."""
    h = 12
    specs = [ConvSpec("c", h, h, 3, 8)]
    prog = compile_network(specs, [LayerPlan("spat", "is", 2, 1, 1)])
    rt_a = HybridRuntime(prog, backend="pallas")
    rt_b = HybridRuntime(prog, use_pallas=True)
    assert rt_a.backend == rt_b.backend == "pallas"
    assert rt_a.use_pallas and rt_b.use_pallas
    assert HybridRuntime(prog).backend == "xla"
    with pytest.raises(ValueError, match="unknown backend"):
        HybridRuntime(prog, backend="mps")


def test_serving_session_inherits_pallas_backend(vgg_pallas_setup):
    """A session over a pallas accelerator serves through pallas entries."""
    _, _, acc, x = vgg_pallas_setup
    y_direct = np.asarray(acc(x))
    with acc.serve(max_batch=2, buckets=(2,)) as s:
        assert all(e.backend == "pallas" for e in s._entries.values())
        outs = s.run_many([np.asarray(x[0]), np.asarray(x[1])])
    np.testing.assert_allclose(np.asarray(outs[0]), y_direct[0], **TOL)
    np.testing.assert_allclose(np.asarray(outs[1]), y_direct[1], **TOL)


def test_vgg_forward_rejects_interpret_without_pallas():
    """models/vgg.py: interpret= with use_pallas=False used to be silently
    ignored — now it raises instead of faking an interpret-mode run.

    The guard fires before any parameter access, so placeholder params
    suffice (and prove the error isn't raised lazily mid-network)."""
    specs = vgg.conv_specs(img=32, scale=32)
    plans = [LayerPlan("spat", "is", 2, 1, 1) for _ in specs]
    x = jnp.zeros((1, 32, 32, 3))
    with pytest.raises(ValueError, match="use_pallas"):
        vgg.forward({}, x, plans, use_pallas=False, interpret=True)
    with pytest.raises(ValueError, match="use_pallas"):
        vgg.forward({}, x, plans, interpret=False)
