"""The fault-tolerant serving tier: deterministic fault injection
(``repro.serving.faults``), per-request deadlines, bounded admission,
poisoned-batch isolation, thread supervision, and graceful degradation.

The load-bearing property is the **liveness invariant**: under every
seeded :class:`FaultPlan` — including plans that kill a pipeline thread —
every submitted request's future resolves (result or typed error) and the
session counters balance exactly::

    stats.submitted == stats.requests + stats.errors + stats.shed

Isolation is held to a bitwise standard: when one poisoned request fails
a batch, every innocent co-batched request must return **bit-identical**
results to a fault-free run (the bisection retries re-run the same
compiled executor at the same bucket size and row offsets).
"""
import logging
import time

import numpy as np
import pytest

from repro import api
from repro.checkpoint import HeartbeatMonitor
from repro.core import aot
from repro.core import perf_model as pm
from repro.core.hybrid_conv import ConvSpec, FCSpec
from repro.core.program_cache import ProgramCache
from repro.serving import (
    DeadlineExceeded,
    DeadlineTable,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    NumericsError,
    Overloaded,
    PipelineCrashed,
    ThreadKilled,
    ThreadSupervisor,
    chaos_soak,
)

SPECS = [ConvSpec("c1", 16, 16, 3, 8), FCSpec("fc", 16 * 16 * 8, 10,
                                              relu=False)]


@pytest.fixture(scope="module")
def acc():
    return api.Accelerator.build(SPECS, target=pm.V5E, batch=4, seed=0)


@pytest.fixture(scope="module")
def acc_pallas():
    return api.Accelerator.build(SPECS, target=pm.V5E, batch=4, seed=0,
                                 backend="pallas")


def _x(seed=0, n=1):
    xs = np.random.default_rng(seed).standard_normal(
        (n, 16, 16, 3)).astype(np.float32)
    return xs[0] if n == 1 else xs


def _balanced(st):
    return st.submitted == st.requests + st.errors + st.shed


# -- the FaultPlan itself ----------------------------------------------------

def test_fault_plan_is_deterministic_and_validated():
    a = FaultPlan.seeded(7, n_faults=12, n_requests=32)
    b = FaultPlan.seeded(7, n_faults=12, n_requests=32)
    assert a.specs == b.specs                       # byte-identical schedule
    assert a.specs != FaultPlan.seeded(8, n_faults=12, n_requests=32).specs
    for s in a.specs:                               # corruption needs payload
        if s.kind in ("nan", "inf"):
            assert s.site in ("staging", "execute")
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="warp-core")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="dispatch", kind="gamma-ray")


def test_fault_plan_matching_ordinals_requests_and_ctx():
    plan = FaultPlan([
        FaultSpec(site="dispatch", kind="error", at=(1,), message="ordinal"),
        FaultSpec(site="execute", kind="error", requests=(5,),
                  message="cursed"),
        FaultSpec(site="execute", kind="error",
                  match=(("backend", "pallas"),), message="ctx"),
    ])
    plan.visit("dispatch")                          # ordinal 0: no match
    with pytest.raises(InjectedFault, match="ordinal"):
        plan.visit("dispatch")                      # ordinal 1 fires
    plan.visit("execute", requests=[1, 2], backend="xla")   # innocent batch
    with pytest.raises(InjectedFault, match="cursed"):
        plan.visit("execute", requests=[4, 5], backend="xla")
    with pytest.raises(InjectedFault, match="ctx"):
        plan.visit("execute", requests=[9], backend="pallas")
    assert plan.counts()["dispatch"] == 2 and plan.counts()["execute"] == 3
    assert [e["message"] for e in plan.fired()] == ["ordinal", "cursed",
                                                    "ctx"]


def test_fault_plan_corruption_scoped_and_int_safe():
    plan = FaultPlan([FaultSpec(site="execute", kind="nan", requests=(3,))])
    buf = np.ones((4, 2), np.float32)
    plan.visit("execute", payload=buf, requests=[2, 3],
               rows={2: (0, 2), 3: (2, 2)})
    assert np.isfinite(buf[:2]).all()               # innocent rows untouched
    assert np.isnan(buf[2:]).all()                  # cursed rows poisoned
    ibuf = np.ones((4, 2), np.int8)                 # int8 has no NaN: no-op
    plan.visit("execute", payload=ibuf, requests=[3], rows={3: (2, 2)})
    assert (ibuf == 1).all()


def test_fault_plan_kill_is_base_exception():
    # ThreadKilled must slip through `except Exception` recovery blocks —
    # that is what makes it model abrupt thread death, not a batch failure
    assert not issubclass(ThreadKilled, Exception)
    with pytest.raises(BaseException):
        FaultPlan([FaultSpec(site="drain", kind="kill")]).visit("drain")


# -- liveness under seeded chaos ---------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_soak_liveness_and_exact_accounting(acc, seed):
    plan = FaultPlan.seeded(seed, n_faults=6, horizon=12, n_requests=24)
    report = chaos_soak(acc, plan=plan, n_requests=24, timeout_s=90.0,
                        raise_on_failure=True)
    assert report["unresolved"] == 0 and report["balanced"]


def test_chaos_soak_survives_killed_worker_thread(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="kill", at=(2,))])
    report = chaos_soak(acc, plan=plan, n_requests=12, timeout_s=90.0,
                        raise_on_failure=True, max_batch=2, buckets=(2,))
    assert report["watchdog_restarts"] >= 1


def test_chaos_soak_survives_killed_drain_thread(acc):
    plan = FaultPlan([FaultSpec(site="drain", kind="kill", at=(1,))])
    report = chaos_soak(acc, plan=plan, n_requests=12, timeout_s=90.0,
                        raise_on_failure=True, max_batch=2, buckets=(2,))
    assert report["watchdog_restarts"] >= 1


def test_watchdog_restart_fails_inflight_with_causal_exception(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="kill", at=(1,))])
    with acc.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0, warmup=True,
                   fault_plan=plan) as s:
        assert s.submit(_x()).result(timeout=60) is not None
        doomed = s.submit(_x())
        with pytest.raises(PipelineCrashed) as ei:
            doomed.result(timeout=60)
        assert isinstance(ei.value.__cause__, ThreadKilled)   # causal chain
        # the restarted pipeline serves new traffic
        assert s.submit(_x()).result(timeout=60) is not None
        st = s.stats
        assert st.watchdog_restarts >= 1 and _balanced(st)


# -- poisoned-batch isolation ------------------------------------------------

def test_innocent_requests_bitwise_identical_after_isolation(acc):
    xs = _x(seed=3, n=4)
    with acc.serve(max_batch=4, buckets=(4,), max_wait_ms=20.0,
                   warmup=True) as s:
        ref = [np.asarray(f.result(timeout=60))
               for f in s.submit_many(xs)]
    plan = FaultPlan([FaultSpec(site="execute", kind="error", requests=(2,),
                                message="cursed")])
    with acc.serve(max_batch=4, buckets=(4,), max_wait_ms=20.0, warmup=True,
                   fault_plan=plan) as s:
        futs = s.submit_many(xs)
        for i in (0, 1, 3):                         # innocents: bitwise
            np.testing.assert_array_equal(
                np.asarray(futs[i].result(timeout=60)), ref[i])
        with pytest.raises(InjectedFault, match="cursed"):
            futs[2].result(timeout=60)              # offender: causal error
        st = s.stats
    assert st.isolated == 1 and st.retries >= 2 and _balanced(st)


def test_numerics_guard_quarantines_poisoned_rows(acc):
    plan = FaultPlan([FaultSpec(site="execute", kind="nan", requests=(1,))])
    with acc.serve(max_batch=2, buckets=(2,), max_wait_ms=20.0, warmup=True,
                   fault_plan=plan, guard_numerics=True) as s:
        futs = s.submit_many(_x(seed=4, n=2))
        assert np.isfinite(np.asarray(futs[0].result(timeout=60))).all()
        with pytest.raises(NumericsError):
            futs[1].result(timeout=60)
        st = s.stats
    assert st.isolated >= 1 and _balanced(st)


# -- deadlines and bounded admission ----------------------------------------

def test_deadline_exceeded_while_queued(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="delay", at=(0,),
                                delay_ms=400.0)])
    with acc.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0, warmup=True,
                   fault_plan=plan) as s:
        f = s.submit(_x(), deadline_ms=100.0)
        with pytest.raises(DeadlineExceeded, match="deadline"):
            f.result(timeout=60)
        st = s.stats
    assert st.deadline_exceeded == 1 and _balanced(st)


def test_session_default_deadline_applies(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="delay", at=(0,),
                                delay_ms=400.0)])
    with acc.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0, warmup=True,
                   fault_plan=plan, deadline_ms=100.0) as s:
        with pytest.raises(DeadlineExceeded):
            s.submit(_x()).result(timeout=60)


def test_queue_limit_sheds_with_overloaded(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="delay",
                                delay_ms=250.0)])
    with acc.serve(max_batch=1, buckets=(1,), max_wait_ms=1.0, warmup=True,
                   fault_plan=plan, queue_limit=2, on_overload="shed") as s:
        futs = [s.submit(_x()) for _ in range(8)]
        shed = [f for f in futs if f.done()
                and isinstance(f.exception(), Overloaded)]
        assert shed                                 # overflow shed instantly
        for f in futs:
            if f not in shed:
                f.result(timeout=120)               # admitted ones complete
        st = s.stats
    assert st.shed == len(shed) and _balanced(st)


def test_queue_limit_block_admits_everything(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="delay",
                                delay_ms=100.0)])
    with acc.serve(max_batch=1, buckets=(1,), max_wait_ms=1.0, warmup=True,
                   fault_plan=plan, queue_limit=2, on_overload="block") as s:
        futs = [s.submit(_x()) for _ in range(6)]   # submit blocks, not sheds
        for f in futs:
            f.result(timeout=120)
        st = s.stats
    assert st.shed == 0 and st.requests == 6 and _balanced(st)


def test_serve_rejects_bad_failure_kwargs(acc):
    with pytest.raises(ValueError, match="on_overload"):
        acc.serve(max_batch=2, queue_limit=2, on_overload="explode")
    with pytest.raises(ValueError, match="queue_limit"):
        acc.serve(max_batch=2, queue_limit=0)


# -- graceful degradation ----------------------------------------------------

def test_pallas_failure_degrades_to_xla_whole_batch(acc_pallas):
    plan = FaultPlan([FaultSpec(site="execute", kind="error", at=(0,),
                                match=(("backend", "pallas"),))])
    cache = acc_pallas.runtime.cache
    fb0 = cache.stats.fallbacks
    with acc_pallas.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0,
                          warmup=True, fault_plan=plan) as s:
        y = np.asarray(s.submit(_x()).result(timeout=60))
        st = s.stats
    # the whole batch succeeded on the XLA lowering: degradation, not
    # isolation — and the cache counted the degraded-entry request
    assert st.degraded == 1 and st.isolated == 0 and _balanced(st)
    assert cache.stats.fallbacks > fb0
    with acc_pallas.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0,
                          warmup=True) as s:
        y_clean = np.asarray(s.submit(_x()).result(timeout=60))
    np.testing.assert_allclose(y, y_clean, atol=1e-5, rtol=1e-5)


def test_aot_load_fault_takes_warn_and_recompile_path(acc, tmp_path, caplog):
    bundle = str(tmp_path / "bundle")
    acc.save_program(bundle, aot=True, buckets=(2,))
    y_ref = np.asarray(acc(_x(n=2)))
    plan = FaultPlan([FaultSpec(site="aot_load", kind="error")])
    prev = aot.set_fault_hook(plan.aot_hook())
    try:
        cache = ProgramCache()
        acc2 = api.Accelerator.from_program(bundle, params=acc.params,
                                            cache=cache)
        with caplog.at_level(logging.WARNING, logger="repro.aot"):
            with acc2.serve(max_batch=2, buckets=(2,), warmup=True) as s:
                y = np.asarray(s.run_many(list(_x(n=2)))[0])
    finally:
        assert aot.set_fault_hook(prev) is not None
    assert plan.fired("aot_load")                  # the hook really ran
    assert cache.stats.aot_loads == 0              # no artifact served
    assert any("falling back to fresh compile" in r.getMessage()
               for r in caplog.records)
    np.testing.assert_array_equal(y, y_ref[0])     # recompile is bit-exact


# -- run_many under faults (satellite: swallowed-error fix) ------------------

def test_run_many_reports_suppressed_secondary_errors(acc, caplog):
    plan = FaultPlan([
        FaultSpec(site="execute", kind="error", requests=(1,),
                  message="first"),
        FaultSpec(site="execute", kind="error", requests=(6,),
                  message="second"),
    ])
    xs = list(_x(seed=5, n=8))
    with acc.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0, warmup=True,
                   fault_plan=plan) as s:
        with caplog.at_level(logging.ERROR, logger="repro.serving"):
            with pytest.raises(InjectedFault, match="first") as ei:
                s.run_many(xs)
        st = s.stats
    # the second batch's failure is attached AND logged, never swallowed
    assert [str(e) for e in ei.value.secondary_errors] == ["second"]
    assert any("suppressed" in r.getMessage() for r in caplog.records)
    assert _balanced(st)


def test_run_many_isolates_cursed_request_bitwise(acc):
    xs = list(_x(seed=6, n=4))
    with acc.serve(max_batch=4, buckets=(4,), warmup=True) as s:
        ref = [np.asarray(y) for y in s.run_many(xs)]
    plan = FaultPlan([FaultSpec(site="execute", kind="error", requests=(0,),
                                message="cursed")])
    with acc.serve(max_batch=4, buckets=(4,), warmup=True,
                   fault_plan=plan) as s:
        with pytest.raises(InjectedFault, match="cursed"):
            s.run_many(xs)
        st = s.stats
    assert st.isolated == 1 and _balanced(st)
    # innocents in the same poisoned device batch still match bitwise
    with acc.serve(max_batch=4, buckets=(4,), warmup=True) as s:
        again = [np.asarray(y) for y in s.run_many(xs)]
    for a, b in zip(again, ref):
        np.testing.assert_array_equal(a, b)


# -- lifecycle edge cases (satellite) ---------------------------------------

def test_close_with_requests_in_flight_resolves_everything(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="delay",
                                delay_ms=150.0)])
    s = acc.serve(max_batch=1, buckets=(1,), max_wait_ms=1.0, warmup=True,
                  fault_plan=plan)
    futs = [s.submit(_x()) for _ in range(4)]
    s.close()                                      # while batches in flight
    for f in futs:                                 # liveness: all resolved,
        assert f.done()                            # result or typed error
        try:
            f.result(timeout=0)
        except Exception:  # noqa: BLE001 — typed error is a resolution too
            pass
    assert _balanced(s.stats)


def test_double_close_is_idempotent_even_after_crash(acc):
    plan = FaultPlan([FaultSpec(site="dispatch", kind="kill", at=(0,))])
    s = acc.serve(max_batch=2, buckets=(2,), max_wait_ms=1.0, warmup=True,
                  fault_plan=plan, supervise=False)   # no watchdog rescue
    f = s.submit(_x())
    time.sleep(0.3)                                # let the worker die
    s.close()
    s.close()                                      # second close: no-op
    with pytest.raises(PipelineCrashed):
        f.result(timeout=0)
    assert _balanced(s.stats)


def test_run_many_empty_and_zero_max_wait(acc):
    with acc.serve(max_batch=2, buckets=(2,), max_wait_ms=0.0,
                   warmup=False) as s:
        assert s.run_many([]) == []                # no work: no batches
        y = s.submit(_x()).result(timeout=60)      # zero-wait admitter cuts
        assert np.asarray(y).shape == (10,)        # singleton batches
        assert s.stats.batches >= 1
    assert _balanced(s.stats)


def test_submit_after_close_still_raises(acc):
    s = acc.serve(max_batch=2, buckets=(2,), warmup=False)
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(_x())


# -- the supervision primitives (satellite: checkpoint wiring) ---------------

def test_heartbeat_monitor_detects_stragglers_and_dead():
    mon = HeartbeatMonitor(n_workers=3, window=8, zscore_threshold=3.0,
                           dead_after_s=5.0)
    now = 100.0
    for step in range(8):
        for w in range(3):
            slow = 4.0 if w == 2 else 1.0          # worker 2 is 4x slower
            mon.report(w, step_time=slow, now=now)
        now += 1.0
    assert mon.stragglers() == [2]
    assert mon.dead(now=now) == []                 # everyone reported
    assert mon.dead(now=now + 10.0) == [0, 1, 2]   # silence kills them all


def test_thread_supervisor_only_flags_hung_when_busy():
    sup = ThreadSupervisor(("dispatch", "drain"), hang_after_s=1.0)
    sup.beat("dispatch", now=0.0)
    sup.beat("drain", now=0.0)
    assert sup.hung(now=10.0) == []                # idle: silence is normal
    sup.update_busy(True, now=10.0)                # arming re-reports all
    assert sup.hung(now=10.5) == []
    assert sorted(sup.hung(now=20.0)) == ["dispatch", "drain"]
    sup.beat("drain", now=20.0)
    assert sup.hung(now=20.5) == ["dispatch"]


def test_deadline_table_orders_and_pops_due():
    t = DeadlineTable()
    assert t.next_at() is None
    assert t.add(5.0, "b") and t.add(3.0, "a")     # new-min flags
    assert not t.add(9.0, "c")
    assert t.next_at() == 3.0 and len(t) == 3
    assert t.pop_due(6.0) == ["a", "b"]
    assert t.pop_due(6.0) == [] and len(t) == 1


# -- Fleet passthrough -------------------------------------------------------

def test_fleet_sessions_share_failure_model(acc):
    plan = FaultPlan([FaultSpec(site="execute", kind="error", requests=(0,),
                                message="cursed")])
    fleet = api.Fleet({"m": acc}, max_batch=2, buckets=(2,),
                      max_wait_ms=1.0, warmup=True, fault_plan=plan,
                      deadline_ms=30_000.0)
    try:
        with pytest.raises(InjectedFault, match="cursed"):
            fleet.submit("m", _x()).result(timeout=60)
        assert fleet.submit("m", _x()).result(timeout=60) is not None
        st = fleet.sessions["m"].stats
        assert st.isolated == 1 and _balanced(st)
    finally:
        fleet.close()
