"""The ``repro.api`` façade: Accelerator.build through the unified Target
protocol, save/load of compiled Programs, and the batching ServingSession."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import perf_model as pm
from repro.core.compiler import LayerPlan, compile_network
from repro.core.dse import DSEError, run_tpu_dse
from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
from repro.core.runtime import HybridRuntime

# small enough that every jit compile stays cheap in the fast tier
SPECS = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
         PoolSpec("p1", 16, 16, 16), FCSpec("fc", 8 * 8 * 16, 10, relu=False)]


def _x(batch=2, seed=1):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(
        (batch, 16, 16, 3)), jnp.float32)


def test_targets_satisfy_protocol():
    assert isinstance(pm.V5E, api.Target)
    assert isinstance(pm.VU9P, api.Target)
    assert isinstance(pm.PYNQ_Z1, api.Target)
    with pytest.raises(TypeError, match="Target"):
        api.Accelerator.build(SPECS, target="not-a-target")


def test_build_matches_manual_pipeline():
    """The façade is glue, not new math: bitwise-equal to hand-stitching
    run_tpu_dse -> compile_network -> HybridRuntime (the pre-API flow)."""
    x = _x()
    acc = api.Accelerator.build(SPECS, target=pm.V5E, batch=2, seed=0)
    dse = run_tpu_dse(SPECS, batch=2)
    program = compile_network(SPECS, dse.plans)
    rt = HybridRuntime(program)
    rt.load_params(api.random_params(SPECS, seed=0))
    np.testing.assert_array_equal(np.asarray(acc(x)), np.asarray(rt.run(x)))
    assert acc.n_instructions == len(program.instructions)


def test_fpga_target_through_unified_protocol():
    """An FPGATarget instance drives the same build path; its planned
    Program executes bitwise-identically on the strict interpreter, and
    matches the TPU-planned network function numerically."""
    x = _x()
    acc_t = api.Accelerator.build(SPECS, target=pm.V5E, batch=2, seed=0)
    acc_f = api.Accelerator.build(SPECS, target=pm.PYNQ_Z1, batch=2, seed=0)
    y_t, y_f = np.asarray(acc_t(x)), np.asarray(acc_f(x))
    # same network, possibly different per-layer modes -> float tolerance
    np.testing.assert_allclose(y_f, y_t, atol=5e-3, rtol=1e-3)
    # executor vs per-instruction interpreter on the FPGA-planned Program
    np.testing.assert_array_equal(y_f, np.asarray(acc_f.strict_request()(x)))


def test_plans_override_skips_dse():
    plans = [LayerPlan("wino", "is", m=2), LayerPlan("spat", "ws"),
             None, None]
    acc = api.Accelerator.build(SPECS, plans=plans, seed=0)
    assert acc.dse is None
    assert acc(_x()).shape == (2, 10)
    assert "plans supplied" in acc.summary()


def test_summary_layer_table():
    acc = api.Accelerator.build(SPECS, target=pm.V5E, batch=2)
    s = acc.summary()
    for token in ("c1", "c2", "p1", "fc", "pool", "conv", "est. total",
                  "candidates", "ONE Program"):
        assert token in s, f"summary missing {token!r}:\n{s}"


def test_save_program_roundtrip(tmp_path):
    x = _x()
    acc = api.Accelerator.build(SPECS, target=pm.V5E, batch=2, seed=0)
    path = acc.save_program(str(tmp_path / "prog.json"))
    acc2 = api.Accelerator.from_program(path, params=acc.params)
    np.testing.assert_array_equal(np.asarray(acc(x)), np.asarray(acc2(x)))
    # the DSE verdict travels with the program (summary still works)
    assert acc2.dse is not None
    assert acc2.dse.candidates_searched == acc.dse.candidates_searched
    assert dataclasses.asdict(acc2.dse.hw) == dataclasses.asdict(acc.dse.hw)
    assert "est. total" in acc2.summary()
    # the target name survives the roundtrip (and a re-save)
    assert "Accelerator[v5e]" in acc2.summary()
    path2 = acc2.save_program(str(tmp_path / "prog2.json"))
    assert json.load(open(path2))["target"] == "v5e"


def test_from_program_rejects_drifted_stream(tmp_path):
    acc = api.Accelerator.build(SPECS, target=pm.V5E, batch=2)
    path = acc.save_program(str(tmp_path / "prog.json"))
    with open(path) as f:
        doc = json.load(f)
    # omitting params is an error (saved programs carry no weights)
    with pytest.raises(ValueError, match="carry no weights"):
        api.Accelerator.from_program(path)
    doc["instructions"][0][2] ^= 1          # flip a DRAM_BASE bit
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="does not match"):
        api.Accelerator.from_program(path, params=acc.params)
    doc["format"] = "something-else"
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="format"):
        api.Accelerator.from_program(path, params=acc.params)


def test_serving_session_batches_and_preserves_order():
    acc = api.Accelerator.build(SPECS, plans=[LayerPlan("spat", "is"),
                                              LayerPlan("spat", "is"),
                                              None, None], seed=0)
    x = _x(batch=6, seed=3)
    y_ref = np.asarray(acc(x))
    with acc.serve(max_batch=4, warmup=True) as s:
        # a full-bucket request runs through the SAME cached executor entry
        # as the direct call -> bitwise
        np.testing.assert_array_equal(np.asarray(s(x[:4])),
                                      np.asarray(acc(x[:4])))
        # mixed single-item and batched requests, submitted together; the
        # coalesced device batches may differ in shape from the reference
        # batch-6 call, so rows agree to float tolerance, in order
        futs = [s.submit(x[0]), s.submit(x[1:4]), s.submit(x[4]),
                s.submit(x[5])]
        outs = [np.asarray(f.result()) for f in futs]
    np.testing.assert_allclose(outs[0], y_ref[0], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(outs[1], y_ref[1:4], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(outs[2], y_ref[4], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(outs[3], y_ref[5], atol=1e-5, rtol=1e-5)
    assert s.stats.requests == 5
    # 6 items over max_batch=4 -> at least two coalesced device batches
    assert s.stats.batches >= 3


def test_serving_session_rejects_oversized_and_closed():
    acc = api.Accelerator.build(SPECS, plans=[LayerPlan("spat", "is"),
                                              LayerPlan("spat", "is"),
                                              None, None], seed=0)
    s = acc.serve(max_batch=2)
    with pytest.raises(ValueError, match="max_batch"):
        s.submit(_x(batch=3))
    with pytest.raises(ValueError, match="max_batch"):
        s.submit(np.empty((0, 16, 16, 3), np.float32))   # empty request
    with pytest.raises(ValueError, match="rank"):
        s.submit(np.zeros((16, 16)))        # neither item nor batch rank
    with pytest.raises(ValueError, match="input shape"):
        s.submit(np.zeros((17, 16, 3)))     # right rank, wrong item shape
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(_x(batch=1))


def test_segmented_rejects_non_vgg_chains():
    """segmented=True requires the (CONV+ POOL)+ FC* layout its host-side
    maxpool glue assumes; anything else gets a descriptive error."""
    plans = [LayerPlan("spat", "is")] * 2 + [None, None]
    with pytest.raises(ValueError, match="trailing CONV"):
        api.Accelerator.build(
            [ConvSpec("c1", 16, 16, 3, 8), PoolSpec("p", 16, 16, 8),
             ConvSpec("c2", 8, 8, 8, 8), FCSpec("fc", 8 * 8 * 8, 4)],
            plans=plans, segmented=True)
    with pytest.raises(ValueError, match="without a preceding CONV"):
        api.Accelerator.build(
            [PoolSpec("p", 16, 16, 3), ConvSpec("c1", 8, 8, 3, 8),
             PoolSpec("p2", 8, 8, 8), FCSpec("fc", 4 * 4 * 8, 4)],
            plans=plans, segmented=True)


def test_dse_error_when_nothing_fits():
    tiny_tpu = dataclasses.replace(pm.V5E, vmem_bytes=1024)
    with pytest.raises(DSEError, match="VMEM"):
        tiny_tpu.run_dse(SPECS, batch=1)
    tiny_fpga = dataclasses.replace(pm.PYNQ_Z1, name="tiny", luts=100,
                                    dsps=4, bram_18k=2)
    with pytest.raises(DSEError, match="no hardware candidate"):
        tiny_fpga.run_dse(SPECS)
    with pytest.raises(DSEError, match="empty layer list"):
        pm.V5E.run_dse([], batch=1)
    with pytest.raises(DSEError, match="empty layer list"):
        pm.VU9P.run_dse([])
