"""Multi-device behaviors that need >1 device: run in a subprocess with
--xla_force_host_platform_device_count=8 so the main test process keeps its
single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_shardmap_pallas_gemm():
    """The Pallas GEMM PE under shard_map over a 2x4 mesh — the real-TPU
    distribution pattern for the kernels."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.kernels.gemm import batched_matmul
        from repro.kernels.gemm.ref import batched_matmul_ref
        mesh = make_mesh((2, 4), ("data", "model"))
        a = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 64))
        b = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128))

        def local_mm(a, b):  # batch sharded over data, N sharded over model
            return batched_matmul(a, b)

        mm = shard_map(local_mm, mesh=mesh,
                       in_specs=(P("data", None, None),
                                 P("data", None, "model")),
                       out_specs=P("data", None, "model"),
                       check_vma=False)  # pallas_call outputs carry no vma
        out = mm(a, b)
        ref = batched_matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("shard_map pallas gemm ok")
    """)


def test_sharded_train_step_runs():
    """A reduced model trains on a real 2x4 device mesh with the production
    sharding rules (params sharded, batch sharded, loss finite)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.parallel.sharding import (make_rules, param_shardings,
                                             use_rules)
        from repro.optim import adamw
        from repro.train import steps as steps_lib
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        cfg = get_config("minitron-8b").reduced()
        with use_rules(rules):
            params = steps_lib.init_params(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, param_shardings(params, rules))
        opt_state = adamw.init(params)
        step = steps_lib.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
        def wrapped(p, o, b):
            with use_rules(rules):
                return step(p, o, b)
        rng = np.random.default_rng(0)
        batch = {k: jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                jnp.int32) for k in ("tokens", "targets")}
        p2, o2, m = jax.jit(wrapped, donate_argnums=(0, 1))(
            params, opt_state, batch)
        assert np.isfinite(float(m["loss"]))
        print("sharded train step ok, loss", float(m["loss"]))
    """)


def test_compressed_psum_matches_mean():
    """int8 error-feedback all-reduce ~= exact mean over the DP axis."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.optim.compression import compressed_psum, init_error_state
        mesh = make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def body(g):
            grads = {"w": g[0]}
            err = init_error_state(grads)
            mean, new_err = compressed_psum(grads, err, ("data",))
            return mean["w"]

        out = shard_map(body, mesh=mesh, in_specs=P("data", None),
                        out_specs=P())(g)
        ref = jnp.mean(g, axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0.05, atol=0.02)
        print("compressed psum ok")
    """)


def test_sharded_serving_session_parity():
    """The shard_map'd executor variant inside a ServingSession: full
    buckets split over a 4-device fleet mesh, stragglers stay local —
    outputs match the unsharded session to fp accumulation noise."""
    _run("""
        import numpy as np
        from repro import api
        from repro.core import perf_model as pm
        from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
        from repro.launch.mesh import make_fleet_mesh
        SPECS = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
                 PoolSpec("p1", 16, 16, 16),
                 FCSpec("fc", 8 * 8 * 16, 10, relu=False)]
        acc = api.Accelerator.build(SPECS, target=pm.V5E, batch=8, seed=0)
        mesh = make_fleet_mesh(4)
        rng = np.random.default_rng(0)
        reqs = [rng.standard_normal((16, 16, 3)).astype(np.float32)
                for _ in range(19)]            # 2 full buckets + straggler
        with acc.serve(max_batch=8, buckets=(4, 8)) as s:
            ref = [np.asarray(o) for o in s.run_many(reqs)]
        with acc.serve(max_batch=8, buckets=(4, 8), mesh=mesh) as s:
            got = [np.asarray(o) for o in s.run_many(reqs)]
            st = s.stats
        d = max(float(np.abs(a - b).max()) for a, b in zip(ref, got))
        assert d <= 1e-4, d
        # full 8-buckets counted on EVERY mesh device, stragglers on one
        assert len(st.device_batches) == 4, st.device_batches
        assert st.dispatched_rows == 19
        print("sharded session parity ok, max diff", d)
    """)


def test_pallas_backend_under_sharding_matches_xla():
    """backend="pallas" serves sharded: each shard is an ordinary
    single-device trace, so the Pallas PE kernels run per-shard inside the
    shard_map region — matching the XLA lowering to <= 1e-4."""
    _run("""
        import numpy as np
        from repro import api
        from repro.core import perf_model as pm
        from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
        from repro.launch.mesh import make_fleet_mesh
        SPECS = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
                 PoolSpec("p1", 16, 16, 16),
                 FCSpec("fc", 8 * 8 * 16, 10, relu=False)]
        acc_x = api.Accelerator.build(SPECS, target=pm.V5E, batch=8, seed=0)
        acc_p = api.Accelerator.build(SPECS, target=pm.V5E, batch=8,
                                      params=acc_x.params, backend="pallas")
        mesh = make_fleet_mesh(4)
        rng = np.random.default_rng(0)
        reqs = [rng.standard_normal((16, 16, 3)).astype(np.float32)
                for _ in range(8)]
        with acc_x.serve(max_batch=8, buckets=(8,), mesh=mesh) as s:
            ref = [np.asarray(o) for o in s.run_many(reqs)]
        with acc_p.serve(max_batch=8, buckets=(8,), mesh=mesh) as s:
            got = [np.asarray(o) for o in s.run_many(reqs)]
        d = max(float(np.abs(a - b).max()) for a, b in zip(ref, got))
        assert d <= 1e-4, d
        print("pallas-under-sharding parity ok, max diff", d)
    """)


def test_fleet_multi_model_bitwise_stable():
    """Two models co-tenanting one Fleet (shared slot pool, shared program
    cache, shared mesh) produce BITWISE the outputs of their standalone
    sessions — tenancy changes scheduling, never computation."""
    _run("""
        import numpy as np
        from repro import api
        from repro.core import perf_model as pm
        from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
        from repro.launch.mesh import make_fleet_mesh
        SPECS_A = [ConvSpec("c1", 16, 16, 3, 8),
                   ConvSpec("c2", 16, 16, 8, 16),
                   PoolSpec("p1", 16, 16, 16),
                   FCSpec("fc", 8 * 8 * 16, 10, relu=False)]
        SPECS_B = [ConvSpec("c1", 16, 16, 3, 12),
                   PoolSpec("p1", 16, 16, 12),
                   FCSpec("fc", 8 * 8 * 12, 10, relu=False)]
        acc_a = api.Accelerator.build(SPECS_A, target=pm.V5E, batch=8, seed=0)
        acc_b = api.Accelerator.build(SPECS_B, target=pm.V5E, batch=8, seed=1)
        mesh = make_fleet_mesh(4)
        rng = np.random.default_rng(0)
        reqs = [rng.standard_normal((16, 16, 3)).astype(np.float32)
                for _ in range(8)]
        with acc_a.serve(max_batch=8, buckets=(8,), mesh=mesh) as s:
            ref_a = [np.asarray(o) for o in s.run_many(reqs)]
        with acc_b.serve(max_batch=8, buckets=(8,), mesh=mesh) as s:
            ref_b = [np.asarray(o) for o in s.run_many(reqs)]
        with api.Fleet({"a": acc_a, "b": acc_b}, mesh=mesh,
                       max_batch=8, buckets=(8,)) as fleet:
            pairs = ([("a", r) for r in reqs] + [("b", r) for r in reqs])
            res = fleet.run_many(pairs)
        assert all(np.array_equal(g, r)
                   for g, r in zip(res[:8], ref_a)), "model a not bitwise"
        assert all(np.array_equal(g, r)
                   for g, r in zip(res[8:], ref_b)), "model b not bitwise"
        print("fleet multi-model bitwise ok")
    """)


def test_sharded_executor_cache_keying():
    """Mesh topology joins the program-cache key: sharded and unsharded
    executors of one Program coexist, a 1-device mesh aliases to the
    unsharded entry, and a non-dividing batch is refused."""
    _run("""
        import pytest
        from repro import api
        from repro.core import perf_model as pm
        from repro.core.hybrid_conv import ConvSpec, FCSpec
        from repro.core.program_cache import ProgramCache
        from repro.launch.mesh import make_fleet_mesh
        SPECS = [ConvSpec("c1", 16, 16, 3, 8),
                 FCSpec("fc", 16 * 16 * 8, 10, relu=False)]
        acc = api.Accelerator.build(SPECS, target=pm.V5E, batch=8, seed=0)
        cache = ProgramCache()
        prog = acc.program
        e0 = cache.get(prog, batch=8, dtype="float32")
        e4 = cache.get(prog, batch=8, dtype="float32",
                       mesh=make_fleet_mesh(4))
        e1 = cache.get(prog, batch=8, dtype="float32",
                       mesh=make_fleet_mesh(1))
        assert e4 is not e0, "mesh must join the cache key"
        assert e1 is e0, "1-device mesh must alias the unsharded entry"
        assert e4.mesh_key is not None and e0.mesh_key is None
        try:
            cache.get(prog, batch=6, dtype="float32",
                      mesh=make_fleet_mesh(4))
        except ValueError as e:
            assert "divide" in str(e)
        else:
            raise AssertionError("non-dividing batch must be refused")
        print("sharded cache keying ok")
    """)
