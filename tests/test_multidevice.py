"""Multi-device behaviors that need >1 device: run in a subprocess with
--xla_force_host_platform_device_count=8 so the main test process keeps its
single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_shardmap_pallas_gemm():
    """The Pallas GEMM PE under shard_map over a 2x4 mesh — the real-TPU
    distribution pattern for the kernels."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.kernels.gemm import batched_matmul
        from repro.kernels.gemm.ref import batched_matmul_ref
        mesh = make_mesh((2, 4), ("data", "model"))
        a = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 64))
        b = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128))

        def local_mm(a, b):  # batch sharded over data, N sharded over model
            return batched_matmul(a, b)

        mm = shard_map(local_mm, mesh=mesh,
                       in_specs=(P("data", None, None),
                                 P("data", None, "model")),
                       out_specs=P("data", None, "model"),
                       check_vma=False)  # pallas_call outputs carry no vma
        out = mm(a, b)
        ref = batched_matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("shard_map pallas gemm ok")
    """)


def test_sharded_train_step_runs():
    """A reduced model trains on a real 2x4 device mesh with the production
    sharding rules (params sharded, batch sharded, loss finite)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.parallel.sharding import (make_rules, param_shardings,
                                             use_rules)
        from repro.optim import adamw
        from repro.train import steps as steps_lib
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        cfg = get_config("minitron-8b").reduced()
        with use_rules(rules):
            params = steps_lib.init_params(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, param_shardings(params, rules))
        opt_state = adamw.init(params)
        step = steps_lib.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
        def wrapped(p, o, b):
            with use_rules(rules):
                return step(p, o, b)
        rng = np.random.default_rng(0)
        batch = {k: jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                jnp.int32) for k in ("tokens", "targets")}
        p2, o2, m = jax.jit(wrapped, donate_argnums=(0, 1))(
            params, opt_state, batch)
        assert np.isfinite(float(m["loss"]))
        print("sharded train step ok, loss", float(m["loss"]))
    """)


def test_compressed_psum_matches_mean():
    """int8 error-feedback all-reduce ~= exact mean over the DP axis."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.optim.compression import compressed_psum, init_error_state
        mesh = make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def body(g):
            grads = {"w": g[0]}
            err = init_error_state(grads)
            mean, new_err = compressed_psum(grads, err, ("data",))
            return mean["w"]

        out = shard_map(body, mesh=mesh, in_specs=P("data", None),
                        out_specs=P())(g)
        ref = jnp.mean(g, axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0.05, atol=0.02)
        print("compressed psum ok")
    """)
