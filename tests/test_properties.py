"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import layouts
from repro.core.compiler import LayerPlan, compile_network
from repro.core.hybrid_conv import ConvSpec
from repro.core.isa import Instruction, Opcode, decode, decode_stream, encode_stream
from repro.core.winograd import winograd_conv2d_reference
from repro.kernels.winograd.ref import conv2d_ref
from repro.optim.compression import compress_grad, dequantize_int8

_SETTINGS = dict(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
# Winograd == Spatial for arbitrary shapes (the hybrid-PE core invariant)
# --------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(
    h=st.integers(4, 20), w=st.integers(4, 20),
    c=st.integers(1, 6), k=st.integers(1, 6),
    m=st.sampled_from([2, 4]), r=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2 ** 16),
)
def test_winograd_equals_direct(h, w, c, k, m, r, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (1, h, w, c), jnp.float32)
    g = jax.random.normal(kw, (r, r, c, k), jnp.float32) * 0.3
    y = winograd_conv2d_reference(x, g, m=m)
    yref = conv2d_ref(x, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=5e-3, atol=5e-3)


# --------------------------------------------------------------------------
# ISA encode/decode round-trip is bit-exact
# --------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(
    opcode=st.sampled_from(list(Opcode)),
    wino=st.booleans(), ws=st.booleans(), lw=st.booleans(),
    relu=st.booleans(),
    m=st.integers(0, 255), layer=st.integers(0, 2 ** 16 - 1),
    pw=st.integers(0, 15), ps=st.integers(0, 15),
    buff=st.integers(0, 2 ** 32 - 1), dram=st.integers(0, 2 ** 32 - 1),
    size=st.integers(0, 2 ** 32 - 1),
)
def test_isa_roundtrip(opcode, wino, ws, lw, relu, m, layer, pw, ps,
                       buff, dram, size):
    """Bit-exact across all 9 opcodes. POOL reuses the m_tile byte for
    window/stride, so the pool fields only exist on POOL instructions and
    m_tile only on the others."""
    is_pool = opcode == Opcode.POOL
    ins = Instruction(opcode, wino_flag=wino, dataflow_ws=ws,
                      layout_out_wino=lw, relu_flag=relu,
                      m_tile=0 if is_pool else m,
                      pool_window=pw if is_pool else 0,
                      pool_stride=ps if is_pool else 0,
                      layer_id=layer, buff_base=buff, dram_base=dram,
                      size=size)
    assert decode(ins.encode()) == ins


@settings(**_SETTINGS)
@given(
    d_in=st.integers(0, 2 ** 16 - 1), d_out=st.integers(0, 2 ** 16 - 1),
    relu=st.booleans(), layer=st.integers(0, 2 ** 16 - 1),
)
def test_isa_fc_dims_roundtrip(d_in, d_out, relu, layer):
    """FC packs (d_in, d_out) into word3; pack/unpack and the 128-bit
    round-trip both preserve them exactly."""
    from repro.core.isa import pack_fc_dims, unpack_fc_dims
    assert unpack_fc_dims(pack_fc_dims(d_in, d_out)) == (d_in, d_out)
    ins = Instruction(Opcode.FC, relu_flag=relu, layer_id=layer,
                      size=pack_fc_dims(d_in, d_out))
    back = decode(ins.encode())
    assert back == ins
    assert unpack_fc_dims(back.size) == (d_in, d_out)


@settings(**_SETTINGS)
@given(
    r=st.integers(0, 255), s=st.integers(0, 255), stride=st.integers(0, 255),
    relu=st.booleans(), layer=st.integers(0, 2 ** 16 - 1),
)
def test_isa_dw_geom_roundtrip(r, s, stride, relu, layer):
    """DEPTHWISE_CONV packs (r, s, stride) into word3; pack/unpack and the
    128-bit round-trip both preserve them exactly."""
    from repro.core.isa import pack_dw_geom, unpack_dw_geom
    assert unpack_dw_geom(pack_dw_geom(r, s, stride)) == (r, s, stride)
    ins = Instruction(Opcode.DEPTHWISE_CONV, relu_flag=relu, layer_id=layer,
                      size=pack_dw_geom(r, s, stride))
    back = decode(ins.encode())
    assert back == ins
    assert unpack_dw_geom(back.size) == (r, s, stride)


@settings(**_SETTINGS)
@given(
    pslot=st.booleans(), sslot=st.booleans(), relu=st.booleans(),
    skip_addr=st.integers(0, 2 ** 32 - 1), n_el=st.integers(0, 2 ** 32 - 1),
    layer=st.integers(0, 2 ** 16 - 1),
)
def test_isa_eltwise_two_source_roundtrip(pslot, sslot, relu, skip_addr,
                                          n_el, layer):
    """ELTWISE_ADD is the only two-DRAM-operand word: BUFF_BASE bits [0]/[1]
    name the primary/skip input slots and word2 carries the SKIP operand's
    DRAM base — all of it survives the 128-bit round-trip bit-exactly."""
    buff = (int(pslot) << 0) | (int(sslot) << 1)
    ins = Instruction(Opcode.ELTWISE_ADD, relu_flag=relu, buff_base=buff,
                      dram_base=skip_addr, size=n_el, layer_id=layer)
    back = decode(ins.encode())
    assert back == ins
    assert (back.buff_base & 1, (back.buff_base >> 1) & 1) \
        == (int(pslot), int(sslot))
    assert back.dram_base == skip_addr and back.size == n_el


@settings(**_SETTINGS)
@given(n=st.integers(0, 12), seed=st.integers(0, 999))
def test_isa_stream_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    instrs = []
    for _ in range(n):
        op = Opcode(int(rng.integers(1, 10)))
        is_pool = op == Opcode.POOL
        instrs.append(
            Instruction(op,
                        wino_flag=bool(rng.integers(2)),
                        m_tile=0 if is_pool else int(rng.integers(0, 8)),
                        pool_window=int(rng.integers(0, 16)) if is_pool else 0,
                        pool_stride=int(rng.integers(0, 16)) if is_pool else 0,
                        layer_id=int(rng.integers(0, 100)),
                        buff_base=int(rng.integers(0, 2 ** 32)),
                        dram_base=int(rng.integers(0, 2 ** 32)),
                        size=int(rng.integers(0, 2 ** 32))))
    assert decode_stream(encode_stream(instrs)) == instrs


# --------------------------------------------------------------------------
# Layout transforms invert (Sec. 4.3)
# --------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(h=st.integers(1, 6), w=st.integers(1, 6), c=st.integers(1, 5),
       m=st.sampled_from([2, 4]), seed=st.integers(0, 99))
def test_layout_roundtrip(h, w, c, m, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, h * m, w * m, c))
    tiled = layouts.spat_to_wino(x, m)
    assert tiled.shape == (2, h, w, m, m, c)
    np.testing.assert_array_equal(np.asarray(layouts.wino_to_spat(tiled)),
                                  np.asarray(x))


@settings(**_SETTINGS)
@given(h=st.integers(3, 17), w=st.integers(3, 17), m=st.sampled_from([2, 4]),
       seed=st.integers(0, 99))
def test_save_load_roundtrip_nondivisible(h, w, m, seed):
    """SAVE pads to tile multiples; LOAD's view crops exactly."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, h, w, 3))
    stored = layouts.save_transform(x, layouts.WINO, m)
    back = layouts.load_view(stored, layouts.WINO, hw=(h, w))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# --------------------------------------------------------------------------
# Compiler invariants
# --------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(
    n_layers=st.integers(1, 4),
    gk=st.integers(1, 3), gh=st.integers(1, 3),
    modes=st.lists(st.sampled_from(["spat", "wino"]), min_size=4, max_size=4),
    flows=st.lists(st.sampled_from(["is", "ws"]), min_size=4, max_size=4),
)
def test_compiler_group_coverage(n_layers, gk, gh, modes, flows):
    """Every layer's COMP instructions cover all (row, k) group pairs."""
    specs = [ConvSpec(f"c{i}", 16, 16, 4, 8) for i in range(n_layers)]
    plans = [LayerPlan(modes[i], flows[i], m=4, g_k=gk, g_h=gh)
             for i in range(n_layers)]
    prog = compile_network(specs, plans)
    for lid, cl in enumerate(prog.layers):
        comps = set()
        for ins in prog.instructions:
            if ins.layer_id == lid and ins.opcode == Opcode.COMP:
                comps.add((ins.size & 0xFFF, (ins.size >> 12) & 0xFFF))
        expect = {(i, j) for i in range(len(cl.row_groups))
                  for j in range(len(cl.k_groups))}
        assert comps == expect


# --------------------------------------------------------------------------
# Gradient compression: error feedback telescopes (convergence invariant)
# --------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(seed=st.integers(0, 999), steps=st.integers(1, 8))
def test_error_feedback_telescopes(seed, steps):
    """sum(decoded_t) + err_T == sum(g_t): no information is lost."""
    rng = np.random.default_rng(seed)
    err = jnp.zeros((32,), jnp.float32)
    total_g = jnp.zeros((32,), jnp.float32)
    total_dec = jnp.zeros((32,), jnp.float32)
    for t in range(steps):
        g = jnp.asarray(rng.standard_normal(32), jnp.float32)
        q, scale, err = compress_grad(g, err)
        total_g = total_g + g
        total_dec = total_dec + dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(total_dec + err),
                               np.asarray(total_g), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# Lowering optimizer: opt_level=1 == opt_level=0 == strict interpreter on
# randomized block structures; non-uniform RELU streams must not fuse
# --------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    h=st.sampled_from([6, 8, 10, 12]), c=st.integers(1, 4),
    k=st.integers(2, 10),
    g_h=st.integers(1, 4), g_k=st.integers(1, 4),
    mode=st.sampled_from(["spat", "wino"]),
    dataflow=st.sampled_from(["is", "ws"]),
    flip=st.booleans(), seed=st.integers(0, 2 ** 16),
)
def test_opt_levels_agree_on_random_block_structures(
        h, c, k, g_h, g_k, mode, dataflow, flip, seed):
    """For randomized geometry/grouping (and randomly non-uniform RELU
    streams via one flipped COMP bit), the fused/stacked lowering equals
    the literal per-block reference and the strict interpreter; a stream
    with mixed RELU bits never reports 'fused' for the touched layer."""
    from conftest import flip_first_comp
    from repro.core.executor import (
        analyze_program,
        lower_program,
        to_dram_params,
        validate_schedule,
    )
    from repro.core.runtime import run_program

    spec = ConvSpec("c1", h, h, c, k, relu=True)
    prog = compile_network([spec], [LayerPlan(mode, dataflow, 2, g_k, g_h)])
    if flip:
        prog = flip_first_comp(prog)
    key = jax.random.PRNGKey(seed)
    kw, kb, kx = jax.random.split(key, 3)
    params = [(jax.random.normal(kw, (3, 3, c, k)) * 0.2,
               jax.random.normal(kb, (k,)) * 0.1)]
    x = jax.random.normal(kx, (1, h, h, c))
    verdict = analyze_program(prog)[0]
    n_blocks = (len(prog.layers[0].row_groups)
                * len(prog.layers[0].k_groups))
    if flip and n_blocks > 1:
        assert verdict.kind != "fused"
    else:
        assert verdict.kind == "fused"
    dram = to_dram_params(prog, params)
    validate_schedule(prog)
    y1 = lower_program(prog, opt_level=1)(dram, x)
    y0 = lower_program(prog, opt_level=0)(dram, x)
    ys = run_program(prog, params, x, strict=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ys),
                               rtol=1e-4, atol=1e-4)
