"""Instruction-stream compiler + runtime: end-to-end equivalence and
hazard discipline (the paper's Sec. 4.1/4.2 contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compiler import LayerPlan, Program, compile_network
from repro.core.hybrid_conv import ConvSpec, hybrid_conv2d
from repro.core.isa import Opcode
from repro.core.runtime import HazardError, HybridRuntime, run_program


def _net():
    specs = [
        ConvSpec("c1", 16, 16, 3, 8, relu=True),
        ConvSpec("c2", 16, 16, 8, 12, relu=True),
        ConvSpec("c3", 16, 16, 12, 8, relu=False),
    ]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        params.append((
            jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
            jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
    x = jax.random.normal(jax.random.PRNGKey(99), (2, 16, 16, 3), jnp.float32)
    return specs, params, x


def _direct(specs, params, plans, x):
    y = x
    for s, (w, b), p in zip(specs, params, plans):
        y = hybrid_conv2d(y, w, b, mode=p.mode, m=p.m, relu=s.relu,
                          use_pallas=False)
    return y


PLAN_SETS = [
    [LayerPlan("wino", "is", 4, 2, 2), LayerPlan("spat", "ws", 4, 3, 2),
     LayerPlan("wino", "is", 2, 1, 4)],
    [LayerPlan("spat", "is", 4, 1, 1), LayerPlan("spat", "is", 4, 1, 1),
     LayerPlan("spat", "is", 4, 1, 1)],
    [LayerPlan("wino", "ws", 4, 2, 1), LayerPlan("wino", "is", 4, 1, 2),
     LayerPlan("spat", "ws", 4, 2, 3)],
]


@pytest.mark.parametrize("plans", PLAN_SETS)
def test_runtime_equals_direct(plans):
    """Mixed modes/dataflows/groups through the ISA == direct execution,
    including the WINO<->SPAT layout reorders between layers."""
    specs, params, x = _net()
    prog = compile_network(specs, plans)
    y = run_program(prog, params, x)
    ref = _direct(specs, params, plans, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_wino_weight_traffic_matches_eq9():
    """LOAD_WGT sizes: Winograd asks PT^2/(R*S) more words (Eq. 8 vs 9)."""
    specs = [ConvSpec("c", 16, 16, 8, 8)]
    spat = compile_network(specs, [LayerPlan("spat", "is")])
    wino = compile_network(specs, [LayerPlan("wino", "is", m=4)])

    def wgt_words(prog):
        return sum(i.size for i in prog.instructions
                   if i.opcode == Opcode.LOAD_WGT)
    assert wgt_words(wino) == wgt_words(spat) * 36 // 9


def test_hazard_missing_load():
    specs, params, x = _net()
    prog = compile_network(specs, PLAN_SETS[0])
    bad = [i for i in prog.instructions if i.opcode != Opcode.LOAD_WGT]
    rt = HybridRuntime(Program(bad, prog.layers, prog.dram_size_words))
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


def test_hazard_save_before_comp():
    specs, params, x = _net()
    prog = compile_network(specs, PLAN_SETS[0])
    bad = [i for i in prog.instructions if i.opcode != Opcode.COMP]
    rt = HybridRuntime(Program(bad, prog.layers, prog.dram_size_words))
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


def test_pipeline_stats():
    specs, params, x = _net()
    prog = compile_network(specs, PLAN_SETS[0])
    rt = HybridRuntime(prog)
    rt.load_params(params)
    rt.run(x)
    assert rt.stats["comp"] == sum(
        len(cl.row_groups) * len(cl.k_groups) for cl in prog.layers)
    assert rt.stats["load_inp"] > 0 and rt.stats["save"] > 0


def test_decode_reserved_opcode_names_bad_word():
    """Reserved/out-of-range opcodes raise a ValueError that names the
    offending word, not a bare enum error."""
    import numpy as np
    from repro.core.isa import decode
    for bad in (0, 10, 15):
        w0 = bad | (3 << 16)
        with pytest.raises(ValueError, match=f"word0=0x{w0:08x}"):
            decode(np.array([w0, 0, 0, 0], np.uint32))
    # encoded valid streams still decode
    from repro.core.isa import Instruction, Opcode as Op, decode_stream, \
        encode_stream
    ins = [Instruction(Op.POOL, pool_window=2, pool_stride=2, layer_id=5)]
    assert decode_stream(encode_stream(ins)) == ins


def test_full_network_roundtrip_through_encoded_stream():
    """A conv+pool+fc Program survives encode->decode bit-exactly, and the
    decoded stream drives the interpreter to the same logits."""
    from repro.core.hybrid_conv import FCSpec, PoolSpec
    from repro.core.isa import decode_stream, encode_stream
    specs = [ConvSpec("c1", 8, 8, 3, 6, relu=True),
             PoolSpec("p1", 8, 8, 6),
             FCSpec("f1", 4 * 4 * 6, 5)]
    plans = [LayerPlan("wino", "is", m=2), None, None]
    prog = compile_network(specs, plans)
    decoded = decode_stream(encode_stream(prog.instructions))
    assert decoded == prog.instructions
    params = [
        (jax.random.normal(jax.random.PRNGKey(0), (3, 3, 3, 6)) * 0.2,
         jnp.zeros((6,))),
        (jax.random.normal(jax.random.PRNGKey(1), (4 * 4 * 6, 5)) * 0.2,
         jnp.zeros((5,))),
    ]
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 3))
    y1 = run_program(prog, params, x, strict=True)
    y2 = run_program(Program(decoded, prog.layers, prog.dram_size_words),
                     params, x, strict=True)
    assert y1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
