"""Lowering optimizer (``opt_level``): fused/stacked lowering equivalence
vs the literal per-block reference and the strict interpreter, analysis
verdicts (non-uniform RELU streams must NOT fuse), cache-key separation and
retrace behavior, the bounded validation side table, and the pipelined
``ServingSession`` stats."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compiler import LayerPlan, compile_network
from repro.core.executor import (
    analyze_program,
    lower_program,
    resolve_opt_level,
    to_dram_params,
    validate_schedule,
)
from repro.core.hybrid_conv import ConvSpec
from repro.core.program_cache import ProgramCache
from repro.core.runtime import HybridRuntime, run_program

_TOL = dict(rtol=1e-4, atol=1e-4)
# fused vs blocked: same math, but XLA may pick a different convolution
# algorithm for small row slabs (documented in ARCHITECTURE.md; the bench
# row records ~6.5e-9 on reduced VGG16). Bitwise-equal on this container,
# but CI installs the latest jaxlib — assert a tight tolerance instead of
# pinning the algorithm choice.
_FUSE_TOL = dict(rtol=1e-6, atol=1e-6)


def _net(h=12, c=3, k=8, k2=12, padding="SAME"):
    specs = [ConvSpec("c1", h, h, c, k, padding=padding, relu=True),
             ConvSpec("c2", h - (2 if padding == "VALID" else 0),
                      h - (2 if padding == "VALID" else 0), k, k2,
                      padding=padding, relu=False)]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        params.append((
            jax.random.normal(kw, (s.r, s.s, s.c, s.k)) * 0.2,
            jax.random.normal(kb, (s.k,)) * 0.1))
    x = jax.random.normal(jax.random.PRNGKey(99), (2, h, h, c))
    return specs, params, x


from conftest import flip_first_comp as _flip_first_comp  # noqa: E402


# ---------------------------------------------------------------------------
# Analysis verdicts
# ---------------------------------------------------------------------------

def test_compiler_streams_analyze_fused():
    """Compiler-emitted streams have uniform RELU bits and contiguous
    groups -> every CONV layer fuses."""
    specs, _, _ = _net()
    for mode, df in (("spat", "is"), ("wino", "ws")):
        prog = compile_network(specs, [LayerPlan(mode, df, 2, 2, 2),
                                       LayerPlan("spat", df, 2, 3, 2)])
        verdicts = analyze_program(prog)
        assert [v.kind for v in verdicts.values()] == ["fused", "fused"]
        assert verdicts[0].relu is True and verdicts[1].relu is False


def test_nonuniform_relu_stream_does_not_fuse():
    """A hand-flipped COMP RELU bit makes the layer non-fusible: equal-size
    k-groups fall back to the stacked form, never 'fused'."""
    specs, _, _ = _net()
    prog = _flip_first_comp(compile_network(
        specs, [LayerPlan("spat", "is", 2, 2, 2),
                LayerPlan("spat", "is", 2, 2, 2)]))
    verdicts = analyze_program(prog)
    assert verdicts[0].kind == "stacked"       # must NOT fuse
    assert verdicts[1].kind == "fused"         # untouched layer still does


def test_nonuniform_relu_unequal_kgroups_stays_blocked():
    """Mixed RELU bits over unequal k-group sizes (k=10 into 3 groups ->
    4/4/2) cannot stack either: the literal blocked lowering is kept."""
    specs, params, x = _net(k=10)
    prog = _flip_first_comp(compile_network(
        specs, [LayerPlan("spat", "is", 2, 3, 2),
                LayerPlan("spat", "is", 2, 2, 2)]))
    assert [len(g) for g in [prog.layers[0].k_groups]] == [3]
    verdicts = analyze_program(prog)
    assert verdicts[0].kind == "block"
    # and the blocked fallback still matches the reference + interpreter
    y1 = run_program(prog, params, x)                       # opt_level=1
    y0 = jax.jit(lower_program(prog, opt_level=0))(
        to_dram_params(prog, params), x)
    ys = run_program(prog, params, x, strict=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), **_FUSE_TOL)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ys), **_TOL)


def test_pallas_backend_never_stacks():
    """The Pallas PE is not vmapped: mixed-RELU layers stay blocked."""
    specs, _, _ = _net()
    prog = _flip_first_comp(compile_network(
        specs, [LayerPlan("spat", "is", 2, 2, 2),
                LayerPlan("spat", "is", 2, 2, 2)]))
    verdicts = analyze_program(prog, backend="pallas")
    assert verdicts[0].kind == "block"
    assert "Pallas" in verdicts[0].reason


def test_resolve_opt_level_rejects_unknown():
    specs, _, _ = _net()
    prog = compile_network(specs, [LayerPlan(), LayerPlan()])
    with pytest.raises(ValueError, match="opt_level"):
        resolve_opt_level(2)
    with pytest.raises(ValueError, match="opt_level"):
        HybridRuntime(prog, opt_level=7)
    with pytest.raises(ValueError, match="opt_level"):
        lower_program(prog, opt_level="fast")


# ---------------------------------------------------------------------------
# Numerical equivalence: opt_level=1 == opt_level=0 == strict interpreter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,dataflow", [("spat", "is"), ("spat", "ws"),
                                           ("wino", "is"), ("wino", "ws")])
def test_fused_matches_blocked_and_interpreter(mode, dataflow):
    specs, params, x = _net()
    other = "wino" if mode == "spat" else "spat"
    prog = compile_network(specs, [LayerPlan(mode, dataflow, 2, 2, 2),
                                   LayerPlan(other, dataflow, 2, 2, 2)])
    dram = to_dram_params(prog, params)
    validate_schedule(prog)
    y1 = jax.jit(lower_program(prog, opt_level=1))(dram, x)
    y0 = jax.jit(lower_program(prog, opt_level=0))(dram, x)
    ys = run_program(prog, params, x, strict=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), **_FUSE_TOL)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ys), **_TOL)


def test_stacked_matches_blocked_and_interpreter():
    specs, params, x = _net()
    prog = _flip_first_comp(compile_network(
        specs, [LayerPlan("spat", "ws", 2, 2, 2),
                LayerPlan("wino", "is", 2, 2, 2)]))
    assert analyze_program(prog)[0].kind == "stacked"
    dram = to_dram_params(prog, params)
    validate_schedule(prog)
    y1 = jax.jit(lower_program(prog, opt_level=1))(dram, x)
    y0 = jax.jit(lower_program(prog, opt_level=0))(dram, x)
    ys = run_program(prog, params, x, strict=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), **_TOL)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ys), **_TOL)
    # the flipped bit actually matters: relu-on reference differs
    ref = run_program(compile_network(
        specs, [LayerPlan("spat", "ws", 2, 2, 2),
                LayerPlan("wino", "is", 2, 2, 2)]), params, x)
    assert not np.allclose(np.asarray(y1), np.asarray(ref))


# The randomized-block-structure property test (opt_level=1 == opt_level=0
# == strict interpreter, non-uniform RELU streams never fuse) lives in
# tests/test_properties.py with the other hypothesis suites — this module
# stays importable without the optional dev dep.


# ---------------------------------------------------------------------------
# Cache behavior: opt_level keys entries, retrace probe, bounded validation
# ---------------------------------------------------------------------------

def test_opt_level_keys_cache_and_no_retrace():
    """Fused and blocked executors of one Program are separate cache
    entries, each traced exactly once across repeated fixed-shape calls."""
    specs, params, x = _net()
    prog = compile_network(specs, [LayerPlan("spat", "is", 2, 2, 2),
                                   LayerPlan("spat", "is", 2, 2, 2)])
    dram = to_dram_params(prog, params)
    cache = ProgramCache()
    e1 = cache.get(prog, batch=2, dtype=jnp.float32, opt_level=1)
    e0 = cache.get(prog, batch=2, dtype=jnp.float32, opt_level=0)
    assert e1 is not e0
    assert cache.stats.misses == 2
    for _ in range(3):
        e1(dram, x)
        e0(dram, x)
    assert e1.trace_count == 1 and e0.trace_count == 1
    assert e1.opt_level == 1 and e0.opt_level == 0
    # same key -> same entry, counted as a hit
    assert cache.get(prog, batch=2, dtype=jnp.float32, opt_level=1) is e1
    assert cache.stats.hits == 1


def test_donate_input_keys_cache_separately():
    specs, params, x = _net()
    prog = compile_network(specs, [LayerPlan("spat", "is", 2, 1, 1),
                                   LayerPlan("spat", "is", 2, 1, 1)])
    cache = ProgramCache()
    a = cache.get(prog, batch=2, dtype=jnp.float32)
    b = cache.get(prog, batch=2, dtype=jnp.float32, donate_input=True)
    assert a is not b and b.donate_input
    assert cache.stats.misses == 2


def test_validated_table_bounded_with_eviction_stats():
    """The validation side table is LRU-bounded and follows entry eviction:
    a stream of distinct programs cannot grow it without limit."""
    base_specs, _, _ = _net()
    programs = []
    for k2 in range(4, 12):      # 8 distinct schedules
        specs = [dataclasses.replace(base_specs[0], k=k2)]
        programs.append(compile_network(
            specs, [LayerPlan("spat", "is", 2, 1, 1)]))
    cache = ProgramCache(maxsize=2, validated_maxsize=3)
    for p in programs:
        cache.get(p, batch=1, dtype=jnp.float32)
    assert len(cache) == 2
    assert cache.validated_size <= 3
    assert cache.stats.evictions == len(programs) - 2
    assert cache.stats.validated_evictions >= len(programs) - 3
    # live entries' schedules keep their validation stats: a re-validate of
    # the most recent program is a side-table hit (counters unchanged)
    before = cache.stats.validated_evictions
    cache.validate(programs[-1])
    assert cache.stats.validated_evictions == before


def test_validate_only_callers_are_bounded():
    base_specs, _, _ = _net()
    cache = ProgramCache(maxsize=2, validated_maxsize=3)
    for k2 in range(4, 12):
        specs = [dataclasses.replace(base_specs[0], k=k2)]
        cache.validate(compile_network(
            specs, [LayerPlan("spat", "is", 2, 1, 1)]))
    assert cache.validated_size <= 3
    assert cache.stats.validated_evictions >= 5


# ---------------------------------------------------------------------------
# Pipelined session: stats + end-to-end inheritance of opt_level
# ---------------------------------------------------------------------------

def test_session_pipeline_stats_and_opt_level_inheritance():
    from repro import api

    specs, _, _ = _net(h=8)
    acc = api.Accelerator.build(
        specs, plans=[LayerPlan("spat", "is", 2, 2, 2),
                      LayerPlan("spat", "is", 2, 2, 2)], batch=4, seed=0)
    acc0 = api.Accelerator.build(
        specs, plans=[LayerPlan("spat", "is", 2, 2, 2),
                      LayerPlan("spat", "is", 2, 2, 2)], batch=4, seed=0,
        params=acc.params, opt_level=0)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (8, 8, 8, 3)),
                   np.float32)
    y_direct = np.asarray(acc(x[:4]))
    with acc.serve(max_batch=4, buckets=(4,), warmup=True) as s:
        assert s.stats.compile_ms > 0          # warmup trace+compile timed
        outs = s.run_many(list(x))
        np.testing.assert_allclose(np.asarray(outs[0]), y_direct[0],
                                   atol=1e-5, rtol=1e-5)
        assert s.stats.requests == 8 and s.stats.batches >= 2
        assert len(s.stats.latencies_ms) == 8
        assert 0 < s.stats.p50_ms() <= s.stats.p95_ms()
    # opt_level=0 session serves the reference lowering from its own entry
    with acc0.serve(max_batch=4, buckets=(4,), warmup=True) as s0:
        y0 = np.asarray(s0(x[0]))
    np.testing.assert_allclose(y0, y_direct[0], atol=1e-5, rtol=1e-5)


class _BoomOnMaterialize:
    """Stands in for an async device result whose error only surfaces at
    host materialization — np.asarray(...) in the drain thread."""

    def __array__(self, dtype=None):
        raise RuntimeError("device boom")


def test_session_error_isolation_pipelined():
    """Failures at every pipeline stage surface on the affected futures
    only, and the session keeps serving afterwards: a malformed request is
    rejected at submit, and a device-side error that only materializes in
    the drain thread fails that batch's futures without killing either
    worker thread (close() must still join cleanly)."""
    from repro import api

    specs, _, _ = _net(h=8)
    acc = api.Accelerator.build(
        specs, plans=[LayerPlan("spat", "is", 2, 1, 1),
                      LayerPlan("spat", "is", 2, 1, 1)], batch=2, seed=0)
    with acc.serve(max_batch=2, buckets=(2,)) as s:
        good = s.submit(np.zeros((8, 8, 3), np.float32))
        assert good.result(timeout=30).shape == (8, 8, specs[-1].k)
        with pytest.raises(ValueError):
            s.submit(np.zeros((4, 4, 3), np.float32))   # rejected at submit
        # inject a drain-side failure: the dispatched "result" raises only
        # when the drain thread tries to materialize it
        real_entry = s._entries[2]
        s._entries[2] = lambda params, x: _BoomOnMaterialize()
        doomed = s.submit(np.ones((8, 8, 3), np.float32))
        with pytest.raises(RuntimeError, match="device boom"):
            doomed.result(timeout=30)
        s._entries[2] = real_entry
        again = s.submit(np.ones((8, 8, 3), np.float32))
        assert again.result(timeout=30).shape == (8, 8, specs[-1].k)
    # close() returned -> both threads joined after the injected failure
