"""Per-architecture smoke tests: REDUCED config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.optim import adamw
from repro.train import steps as steps_lib

LM_ARCHS = [a for a in list_archs() if a != "vgg16"]


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                               jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_image_tokens, cfg.d_model)),
            cfg.jnp_dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_audio_frames, cfg.d_model)),
            cfg.jnp_dtype)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = steps_lib.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = steps_lib.forward_logits(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    params = steps_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    step = steps_lib.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
    batch = _batch(cfg)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(opt_state2["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["minitron-8b", "qwen3-32b", "mamba2-130m",
                                  "zamba2-7b", "whisper-base",
                                  "llama4-scout-17b-16e",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_forward(arch):
    """prefill+decode through the serving path == train-forward logits."""
    cfg = get_config(arch).reduced()
    # no-drop MoE capacity: capacity-based top-1 drops depend on how many
    # tokens are routed together, so batched-forward and decode only agree
    # when no token overflows
    cfg = dataclasses.replace(cfg, remat=False, capacity_factor=64.0)
    params = steps_lib.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b=2, s=8)
    logits_fwd = steps_lib.forward_logits(params, batch, cfg)

    prefill_fn, decode_fn = steps_lib.make_serve_steps(cfg)
    cache = steps_lib.init_cache(cfg, 2, 12)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = batch["image_embeds"]
    if cfg.family == "audio":
        from repro.models import whisper
        extras["enc_out"] = whisper.encode(params, batch["frames"], cfg)
    lg, cache = prefill_fn(params, batch["tokens"], cache, extras)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(logits_fwd[:, -1], np.float32), rtol=2e-3, atol=2e-3)
    # one decode step == forward on extended sequence
    nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    lg2, _ = decode_fn(params, nxt, cache, jnp.int32(8), extras)
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    logits2 = steps_lib.forward_logits(params, batch2, cfg)
    np.testing.assert_allclose(
        np.asarray(lg2, np.float32),
        np.asarray(logits2[:, -1], np.float32), rtol=2e-3, atol=2e-3)


def test_param_counts_sane():
    """Full-config param counts are in the right ballpark per arch name."""
    expect = {
        "minitron-8b": (6e9, 11e9),
        "internlm2-20b": (15e9, 25e9),
        "qwen3-32b": (25e9, 40e9),
        "command-r-35b": (28e9, 45e9),
        "llama4-scout-17b-16e": (80e9, 130e9),     # total (not active)
        "llama4-maverick-400b-a17b": (330e9, 480e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "zamba2-7b": (5e9, 9e9),
        "whisper-base": (0.04e9, 0.12e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("llama4-scout-17b-16e")
    active = cfg.active_param_count()
    assert 12e9 <= active <= 25e9   # ~17B active
    cfg = get_config("llama4-maverick-400b-a17b")
    active = cfg.active_param_count()
    assert 12e9 <= active <= 25e9   # ~17B active
