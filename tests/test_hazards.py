"""Hazard discipline: mis-scheduled instruction streams must raise
``HazardError`` in BOTH execution paths — the per-instruction interpreter
(``strict=True``) and the one-shot schedule-validation pass that guards the
jitted executor."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.compiler import LayerPlan, Program, compile_network
from repro.core.executor import validate_schedule
from repro.core.hybrid_conv import ConvSpec
from repro.core.isa import Opcode
from repro.core.runtime import HazardError, HybridRuntime


def _net():
    specs = [ConvSpec("c1", 16, 16, 3, 8, relu=True),
             ConvSpec("c2", 16, 16, 8, 12, relu=False)]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        params.append((
            jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
            jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 16, 3), jnp.float32)
    # 4 row groups so ping-pong slots are reused (needed for the live-slot clobber)
    plans = [LayerPlan("spat", "is", 2, 2, 4), LayerPlan("spat", "ws", 2, 2, 4)]
    return specs, plans, params, x


def _mutate(prog: Program, name: str) -> Program:
    ins = list(prog.instructions)
    if name == "load_over_live_slot":
        # hoist the ih=2 LOAD_INP (slot 0) to right after the ih=0 LOAD_INP:
        # it clobbers slot 0 while ih=0 is still live, so COMP(ih=0) sees a
        # stale tag — the classic ping-pong overrun the handshake FIFO stops.
        idx2 = next(i for i, s in enumerate(ins)
                    if s.opcode == Opcode.LOAD_INP and s.layer_id == 0
                    and s.buff_base == (2 << 1 | 0))
        idx0 = next(i for i, s in enumerate(ins)
                    if s.opcode == Opcode.LOAD_INP and s.layer_id == 0
                    and s.buff_base == (0 << 1 | 0))
        hoisted = ins.pop(idx2)
        ins.insert(idx0 + 1, hoisted)
    elif name == "comp_before_load_inp":
        ins = [s for s in ins if s.opcode != Opcode.LOAD_INP]
    elif name == "comp_before_load_wgt":
        ins = [s for s in ins if s.opcode != Opcode.LOAD_WGT]
    elif name == "comp_with_stale_bias":
        ins = [s for s in ins if s.opcode != Opcode.LOAD_BIAS]
    elif name == "save_before_comp":
        ins = [s for s in ins if s.opcode != Opcode.COMP]
    elif name == "missing_final_save":
        last_save = max(i for i, s in enumerate(ins)
                        if s.opcode == Opcode.SAVE)
        ins = ins[:last_save] + ins[last_save + 1:]
    elif name == "no_save_at_all":
        ins = [s for s in ins if s.opcode != Opcode.SAVE]
    else:
        raise ValueError(name)
    return Program(ins, prog.layers, prog.dram_size_words)


HAZARDS = ["load_over_live_slot", "comp_before_load_inp",
           "comp_before_load_wgt", "comp_with_stale_bias",
           "save_before_comp", "missing_final_save", "no_save_at_all"]


@pytest.mark.parametrize("hazard", HAZARDS)
def test_interpreter_raises(hazard):
    specs, plans, params, x = _net()
    bad = _mutate(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad, strict=True)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


@pytest.mark.parametrize("hazard", HAZARDS)
def test_validation_pass_raises(hazard):
    specs, plans, params, x = _net()
    bad = _mutate(compile_network(specs, plans), hazard)
    with pytest.raises(HazardError):
        validate_schedule(bad)


@pytest.mark.parametrize("hazard", HAZARDS)
def test_jitted_path_raises_before_compute(hazard):
    """The default HybridRuntime path validates before it compiles/executes,
    so a bad stream never reaches the executor or poisons the cache."""
    specs, plans, params, x = _net()
    bad = _mutate(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


def test_good_stream_passes_both_paths():
    specs, plans, params, x = _net()
    prog = compile_network(specs, plans)
    validate_schedule(prog)            # no raise
    rt = HybridRuntime(prog, strict=True)
    rt.load_params(params)
    rt.run(x)                          # no raise


# ---------------------------------------------------------------------------
# POOL / FC hazard discipline (full-network ISA)
# ---------------------------------------------------------------------------

def _full_net():
    """conv -> pool -> conv -> fc: every new-opcode block in one stream."""
    from repro.core.hybrid_conv import FCSpec, PoolSpec
    specs = [ConvSpec("c1", 8, 8, 3, 4, relu=True),
             PoolSpec("p1", 8, 8, 4),
             ConvSpec("c2", 4, 4, 4, 4, relu=True),
             FCSpec("f1", 4 * 4 * 4, 6, relu=False)]
    plans = [LayerPlan("spat", "is"), None, LayerPlan("spat", "is"), None]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        if isinstance(s, ConvSpec):
            params.append((
                jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
                jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
        elif isinstance(s, FCSpec):
            params.append((
                jax.random.normal(kw, (s.d_in, s.d_out), jnp.float32) * 0.2,
                jax.random.normal(kb, (s.d_out,), jnp.float32) * 0.1))
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 8, 8, 3), jnp.float32)
    return specs, plans, params, x


def _mutate_full(prog: Program, name: str) -> Program:
    import dataclasses

    from repro.core.isa import pack_fc_dims

    ins = list(prog.instructions)
    if name == "fc_wrong_word3_dims":
        # the stream's packed FC (d_in, d_out) must agree with the compiled
        # spec — a hand-edited word3 is a malformed stream, not silent math
        ins = [dataclasses.replace(s, size=pack_fc_dims(6 * 6 * 4, 6))
               if s.opcode == Opcode.FC else s for s in ins]
    elif name == "pool_wrong_word0_cfg":
        # same contract for POOL's window/stride in the m_tile byte
        ins = [dataclasses.replace(s, pool_window=1, pool_stride=2)
               if s.opcode == Opcode.POOL else s for s in ins]
    elif name == "pool_before_load_inp":
        # drop the pool layer's LOAD_INP: POOL sees a stale input slot
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_INP and s.layer_id == 1)]
    elif name == "pool_save_before_pool":
        ins = [s for s in ins if s.opcode != Opcode.POOL]
    elif name == "fc_before_load_inp":
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_INP and s.layer_id == 3)]
    elif name == "fc_before_load_wgt":
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_WGT and s.layer_id == 3)]
    elif name == "fc_with_stale_bias":
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_BIAS and s.layer_id == 3)]
    elif name == "fc_save_before_fc":
        ins = [s for s in ins if s.opcode != Opcode.FC]
    else:
        raise ValueError(name)
    return Program(ins, prog.layers, prog.dram_size_words)


POOL_FC_HAZARDS = ["pool_before_load_inp", "pool_save_before_pool",
                   "fc_before_load_inp", "fc_before_load_wgt",
                   "fc_with_stale_bias", "fc_save_before_fc",
                   "fc_wrong_word3_dims", "pool_wrong_word0_cfg"]


@pytest.mark.parametrize("hazard", POOL_FC_HAZARDS)
def test_pool_fc_interpreter_raises(hazard):
    specs, plans, params, x = _full_net()
    bad = _mutate_full(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad, strict=True)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


@pytest.mark.parametrize("hazard", POOL_FC_HAZARDS)
def test_pool_fc_validation_pass_raises(hazard):
    specs, plans, params, x = _full_net()
    bad = _mutate_full(compile_network(specs, plans), hazard)
    with pytest.raises(HazardError):
        validate_schedule(bad)


@pytest.mark.parametrize("hazard", POOL_FC_HAZARDS)
def test_pool_fc_jitted_path_raises_before_compute(hazard):
    specs, plans, params, x = _full_net()
    bad = _mutate_full(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


def test_pool_fc_good_stream_passes_both_paths():
    specs, plans, params, x = _full_net()
    prog = compile_network(specs, plans)
    stats = validate_schedule(prog)    # no raise
    assert stats["pool"] == 1 and stats["fc"] == 1
    rt = HybridRuntime(prog, strict=True)
    rt.load_params(params)
    y = rt.run(x)                      # no raise
    assert y.shape == (1, 6)
    assert rt.stats == stats


# ---------------------------------------------------------------------------
# ELTWISE_ADD / DEPTHWISE_CONV hazard discipline (residual-workload ISA)
# ---------------------------------------------------------------------------

def _residual_net():
    """conv -> conv -> eltwise(skip=conv0) -> depthwise: both new opcodes,
    including the two-source ELTWISE block whose skip operand the DRAM
    planner keeps live past the intervening conv."""
    from repro.core.hybrid_conv import DepthwiseSpec, EltwiseSpec
    specs = [ConvSpec("c1", 8, 8, 3, 4, relu=True),
             ConvSpec("c2", 8, 8, 4, 4, relu=False),
             EltwiseSpec("e1", 8, 8, 4, skip_from=0),
             DepthwiseSpec("d1", 8, 8, 4)]
    plans = [LayerPlan("spat", "is"), LayerPlan("spat", "is"), None, None]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        if isinstance(s, ConvSpec):
            params.append((
                jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
                jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
        elif isinstance(s, DepthwiseSpec):
            params.append((
                jax.random.normal(kw, (s.r, s.s, 1, s.c), jnp.float32) * 0.2,
                jax.random.normal(kb, (s.c,), jnp.float32) * 0.1))
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 8, 8, 3), jnp.float32)
    return specs, plans, params, x


def _mutate_residual(prog: Program, name: str) -> Program:
    import dataclasses

    from repro.core.isa import pack_dw_geom

    ins = list(prog.instructions)
    if name == "eltwise_before_primary_load":
        # drop the primary-operand LOAD_INP (slot 0, tag (2, 0))
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_INP and s.layer_id == 2
                       and s.buff_base == (0 << 1 | 0))]
    elif name == "eltwise_before_skip_load":
        # drop the skip-operand LOAD_INP (slot 1, tag (2, 1))
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_INP and s.layer_id == 2
                       and s.buff_base == (1 << 1 | 1))]
    elif name == "eltwise_wrong_word3_count":
        ins = [dataclasses.replace(s, size=s.size + 1)
               if s.opcode == Opcode.ELTWISE_ADD else s for s in ins]
    elif name == "eltwise_wrong_skip_base":
        # word2 must name the compiled skip operand's DRAM base — pointing
        # it elsewhere is a malformed stream, not a silent wrong add
        ins = [dataclasses.replace(s, dram_base=s.dram_base + 1)
               if s.opcode == Opcode.ELTWISE_ADD else s for s in ins]
    elif name == "eltwise_save_before_add":
        ins = [s for s in ins if s.opcode != Opcode.ELTWISE_ADD]
    elif name == "dw_before_load_inp":
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_INP and s.layer_id == 3)]
    elif name == "dw_before_load_wgt":
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_WGT and s.layer_id == 3)]
    elif name == "dw_with_stale_bias":
        ins = [s for s in ins
               if not (s.opcode == Opcode.LOAD_BIAS and s.layer_id == 3)]
    elif name == "dw_wrong_word3_geom":
        ins = [dataclasses.replace(s, size=pack_dw_geom(5, 5, 1))
               if s.opcode == Opcode.DEPTHWISE_CONV else s for s in ins]
    elif name == "dw_save_before_dw":
        ins = [s for s in ins if s.opcode != Opcode.DEPTHWISE_CONV]
    else:
        raise ValueError(name)
    return Program(ins, prog.layers, prog.dram_size_words)


RESIDUAL_HAZARDS = ["eltwise_before_primary_load", "eltwise_before_skip_load",
                    "eltwise_wrong_word3_count", "eltwise_wrong_skip_base",
                    "eltwise_save_before_add", "dw_before_load_inp",
                    "dw_before_load_wgt", "dw_with_stale_bias",
                    "dw_wrong_word3_geom", "dw_save_before_dw"]


@pytest.mark.parametrize("hazard", RESIDUAL_HAZARDS)
def test_residual_interpreter_raises(hazard):
    specs, plans, params, x = _residual_net()
    bad = _mutate_residual(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad, strict=True)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


@pytest.mark.parametrize("hazard", RESIDUAL_HAZARDS)
def test_residual_validation_pass_raises(hazard):
    specs, plans, params, x = _residual_net()
    bad = _mutate_residual(compile_network(specs, plans), hazard)
    with pytest.raises(HazardError):
        validate_schedule(bad)


@pytest.mark.parametrize("hazard", RESIDUAL_HAZARDS)
def test_residual_jitted_path_raises_before_compute(hazard):
    specs, plans, params, x = _residual_net()
    bad = _mutate_residual(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


def test_residual_good_stream_passes_both_paths():
    specs, plans, params, x = _residual_net()
    prog = compile_network(specs, plans)
    stats = validate_schedule(prog)    # no raise
    assert stats["eltwise"] == 1 and stats["dw"] == 1
    rt = HybridRuntime(prog, strict=True)
    rt.load_params(params)
    y = rt.run(x)                      # no raise
    assert y.shape == (1, 8, 8, 4)
    assert rt.stats == stats
