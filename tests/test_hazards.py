"""Hazard discipline: mis-scheduled instruction streams must raise
``HazardError`` in BOTH execution paths — the per-instruction interpreter
(``strict=True``) and the one-shot schedule-validation pass that guards the
jitted executor."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.compiler import LayerPlan, Program, compile_network
from repro.core.executor import validate_schedule
from repro.core.hybrid_conv import ConvSpec
from repro.core.isa import Opcode
from repro.core.runtime import HazardError, HybridRuntime


def _net():
    specs = [ConvSpec("c1", 16, 16, 3, 8, relu=True),
             ConvSpec("c2", 16, 16, 8, 12, relu=False)]
    params = []
    for i, s in enumerate(specs):
        kw, kb = jax.random.split(jax.random.PRNGKey(i), 2)
        params.append((
            jax.random.normal(kw, (s.r, s.s, s.c, s.k), jnp.float32) * 0.2,
            jax.random.normal(kb, (s.k,), jnp.float32) * 0.1))
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 16, 3), jnp.float32)
    # 4 row groups so ping-pong slots are reused (needed for the live-slot clobber)
    plans = [LayerPlan("spat", "is", 2, 2, 4), LayerPlan("spat", "ws", 2, 2, 4)]
    return specs, plans, params, x


def _mutate(prog: Program, name: str) -> Program:
    ins = list(prog.instructions)
    if name == "load_over_live_slot":
        # hoist the ih=2 LOAD_INP (slot 0) to right after the ih=0 LOAD_INP:
        # it clobbers slot 0 while ih=0 is still live, so COMP(ih=0) sees a
        # stale tag — the classic ping-pong overrun the handshake FIFO stops.
        idx2 = next(i for i, s in enumerate(ins)
                    if s.opcode == Opcode.LOAD_INP and s.layer_id == 0
                    and s.buff_base == (2 << 1 | 0))
        idx0 = next(i for i, s in enumerate(ins)
                    if s.opcode == Opcode.LOAD_INP and s.layer_id == 0
                    and s.buff_base == (0 << 1 | 0))
        hoisted = ins.pop(idx2)
        ins.insert(idx0 + 1, hoisted)
    elif name == "comp_before_load_inp":
        ins = [s for s in ins if s.opcode != Opcode.LOAD_INP]
    elif name == "comp_before_load_wgt":
        ins = [s for s in ins if s.opcode != Opcode.LOAD_WGT]
    elif name == "comp_with_stale_bias":
        ins = [s for s in ins if s.opcode != Opcode.LOAD_BIAS]
    elif name == "save_before_comp":
        ins = [s for s in ins if s.opcode != Opcode.COMP]
    elif name == "missing_final_save":
        last_save = max(i for i, s in enumerate(ins)
                        if s.opcode == Opcode.SAVE)
        ins = ins[:last_save] + ins[last_save + 1:]
    elif name == "no_save_at_all":
        ins = [s for s in ins if s.opcode != Opcode.SAVE]
    else:
        raise ValueError(name)
    return Program(ins, prog.layers, prog.dram_size_words)


HAZARDS = ["load_over_live_slot", "comp_before_load_inp",
           "comp_before_load_wgt", "comp_with_stale_bias",
           "save_before_comp", "missing_final_save", "no_save_at_all"]


@pytest.mark.parametrize("hazard", HAZARDS)
def test_interpreter_raises(hazard):
    specs, plans, params, x = _net()
    bad = _mutate(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad, strict=True)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


@pytest.mark.parametrize("hazard", HAZARDS)
def test_validation_pass_raises(hazard):
    specs, plans, params, x = _net()
    bad = _mutate(compile_network(specs, plans), hazard)
    with pytest.raises(HazardError):
        validate_schedule(bad)


@pytest.mark.parametrize("hazard", HAZARDS)
def test_jitted_path_raises_before_compute(hazard):
    """The default HybridRuntime path validates before it compiles/executes,
    so a bad stream never reaches the executor or poisons the cache."""
    specs, plans, params, x = _net()
    bad = _mutate(compile_network(specs, plans), hazard)
    rt = HybridRuntime(bad)
    rt.load_params(params)
    with pytest.raises(HazardError):
        rt.run(x)


def test_good_stream_passes_both_paths():
    specs, plans, params, x = _net()
    prog = compile_network(specs, plans)
    validate_schedule(prog)            # no raise
    rt = HybridRuntime(prog, strict=True)
    rt.load_params(params)
    rt.run(x)                          # no raise
