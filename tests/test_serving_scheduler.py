"""Serving-scheduler invariants under randomized arrival patterns.

Three properties, checked over arbitrary request traces:

* **routing** — every request's result is the accelerator's output for THAT
  request, whatever batch it was coalesced into (futures never swap rows);
* **no starvation** — every submitted request completes, including a lone
  straggler co-tenanting with a model that keeps the shared slot pool busy
  (the continuous admitter's hard cap);
* **exact accounting** — ``SessionStats`` row counters balance to the row:
  ``dispatched_rows`` equals the rows submitted, ``padded_rows`` equals the
  bucket slack, ``device_batches`` sums to ``batches``.

The randomized-trace tests run under hypothesis when available (CI installs
it via requirements-dev.txt); seeded fallbacks cover the same invariants
with fixed traces so the file is never skipped wholesale.
"""
import concurrent.futures
import functools
import threading
import time

import numpy as np
import pytest

from repro import api
from repro.core import perf_model as pm
from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # optional dev dep; seeded fallbacks still run
    HAVE_HYPOTHESIS = False

SPECS = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
         PoolSpec("p1", 16, 16, 16), FCSpec("fc", 8 * 8 * 16, 10, relu=False)]
MAX_BATCH = 4
BUCKETS = (2, 4)


@pytest.fixture(scope="module")
def acc():
    return api.Accelerator.build(SPECS, target=pm.V5E, batch=MAX_BATCH,
                                 seed=0)


def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((16, 16, 3)).astype(np.float32)
            for _ in range(n)]


def _reference(acc, reqs):
    """Per-request reference outputs via the direct accelerator."""
    y = np.asarray(acc(np.stack(reqs)))
    return [y[i] for i in range(len(reqs))]


def _check_routing(results, refs):
    """Each result matches ITS request's reference — distinct gaussian
    inputs give outputs ~1e-2 apart, so atol=1e-4 catches any row swap."""
    for got, ref in zip(results, refs):
        np.testing.assert_allclose(np.asarray(got), ref, atol=1e-4)


def _check_accounting(stats, total_rows):
    assert stats.dispatched_rows == total_rows
    assert stats.requests == total_rows       # single-image requests
    assert stats.padded_rows >= 0
    # every dispatched batch is one bucket: total staged rows must split
    # into exactly `batches` bucket sizes
    staged = stats.dispatched_rows + stats.padded_rows
    assert stats.batches * min(BUCKETS) <= staged <= stats.batches * max(BUCKETS)
    assert sum(stats.device_batches.values()) == stats.batches
    assert stats.occupancy() == pytest.approx(
        stats.dispatched_rows / staged)
    assert stats.wait_p50_ms() >= 0.0
    assert stats.wait_p95_ms() >= stats.wait_p50_ms()


# Future-deadline for result waits. The timing-sensitive tests run through
# _retry_timing_flake below: on a first red the deadline widens 4x and the
# body reruns once — the 1-core CI container occasionally stalls a drain
# thread long enough to blow the tight window without any real bug.
_DEADLINE_S = 60.0


def _retry_timing_flake(test_fn):
    """Retry ONCE with a wider deadline before declaring a timing red.

    Guards only the nondeterministic failure modes of a loaded host —
    future timeouts and window-dependent assertion trips. The retry reruns
    the full body (fresh session, fresh stats), so a genuine routing or
    accounting bug still fails twice and stays red.
    """
    @functools.wraps(test_fn)
    def wrapper(*args, **kwargs):
        global _DEADLINE_S
        try:
            return test_fn(*args, **kwargs)
        except (AssertionError, TimeoutError,
                concurrent.futures.TimeoutError):
            _DEADLINE_S = 240.0
            try:
                return test_fn(*args, **kwargs)
            finally:
                _DEADLINE_S = 60.0
    return wrapper


def _run_trace(acc, trace, scheduler, seed=1):
    """Submit a (burst_size, gap_ms) trace; return (results, stats)."""
    n = sum(b for b, _ in trace)
    reqs = _requests(n, seed)
    refs = _reference(acc, reqs)
    futs, i = [], 0
    with acc.serve(max_batch=MAX_BATCH, buckets=BUCKETS, max_wait_ms=2.0,
                   scheduler=scheduler) as s:
        for burst, gap_ms in trace:
            futs += s.submit_many(reqs[i:i + burst])
            i += burst
            if gap_ms:
                time.sleep(gap_ms / 1e3)
        results = [f.result(timeout=_DEADLINE_S) for f in futs]  # no
        stats = s.stats                                          # starvation
    return results, refs, stats


# --------------------------------------------------------------------------
# seeded fallbacks — always run, no hypothesis needed
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", api.ServingSession.SCHEDULERS)
@_retry_timing_flake
def test_bursty_trace_routing_and_accounting(acc, scheduler):
    trace = [(3, 1.0), (1, 0.0), (4, 2.0), (2, 1.0), (1, 3.0), (4, 0.0),
             (2, 0.0)]
    results, refs, stats = _run_trace(acc, trace, scheduler)
    _check_routing(results, refs)
    _check_accounting(stats, sum(b for b, _ in trace))


def test_deterministic_bulk_padding_exact(acc):
    """A deep pre-staged backlog groups deterministically: full buckets
    then one padded straggler — byte-exact padded_rows/batches."""
    reqs = _requests(7)
    refs = _reference(acc, reqs)
    with acc.serve(max_batch=MAX_BATCH, buckets=BUCKETS) as s:
        results = s.run_many(reqs)
        stats = s.stats
    _check_routing(results, refs)
    # 7 rows -> one full 4-batch + 3 rows padded into the 4-bucket
    assert stats.batches == 2
    assert stats.dispatched_rows == 7
    assert stats.padded_rows == 1
    assert stats.occupancy() == pytest.approx(7 / 8)
    assert sum(stats.device_batches.values()) == 2


@_retry_timing_flake
def test_mixed_submit_paths_route_correctly(acc):
    """submit / submit_many / run_many interleaved from the caller thread
    all resolve to their own rows (the inline bulk path and the worker
    share the slot pool but never each other's staging)."""
    reqs = _requests(10, seed=3)
    refs = _reference(acc, reqs)
    with acc.serve(max_batch=MAX_BATCH, buckets=BUCKETS) as s:
        f0 = s.submit(reqs[0])
        bulk = s.run_many(reqs[1:6])
        fs = s.submit_many(reqs[6:])
        results = [f0.result(timeout=_DEADLINE_S)] + list(bulk) + [
            f.result(timeout=_DEADLINE_S) for f in fs]
        stats = s.stats
    _check_routing(results, refs)
    _check_accounting(stats, 10)


@_retry_timing_flake
def test_no_starvation_under_co_tenant_flood(acc):
    """A lone request on model B completes while model A floods the shared
    pool — the continuous admitter's hard cap forces B's straggler out
    even though the device never goes idle."""
    acc_b = api.Accelerator.build(SPECS, target=pm.V5E, batch=MAX_BATCH,
                                  seed=7)
    reqs = _requests(40, seed=4)
    lone = _requests(1, seed=5)[0]
    lone_ref = _reference(acc_b, [lone])[0]
    with api.Fleet({"a": acc, "b": acc_b}, max_batch=MAX_BATCH,
                   buckets=BUCKETS, max_wait_ms=2.0) as fleet:
        flood = [fleet.submit("a", r) for r in reqs]
        lone_fut = fleet.submit("b", lone)
        got = lone_fut.result(timeout=_DEADLINE_S)   # must not starve
        for f in flood:
            f.result(timeout=_DEADLINE_S)
    np.testing.assert_allclose(np.asarray(got), lone_ref, atol=1e-4)


def test_scheduler_validation(acc):
    with pytest.raises(ValueError, match="scheduler"):
        acc.serve(scheduler="adaptive")
    with pytest.raises(ValueError, match="capacity"):
        api._SlotPool(0)


def test_fleet_validation(acc):
    with pytest.raises(ValueError, match="at least one"):
        api.Fleet({})
    with api.Fleet({"m": acc}, max_batch=MAX_BATCH, buckets=BUCKETS) as f:
        with pytest.raises(ValueError, match="unknown model"):
            f.submit("nope", _requests(1)[0])
        assert f.models == ("m",)
        assert set(f.stats()) == {"m"}


def test_fleet_round_robin_accounting(acc):
    """Two tenants, interleaved requests: per-model stats stay exact and
    per-model outputs match each model's own reference."""
    acc_b = api.Accelerator.build(SPECS, target=pm.V5E, batch=MAX_BATCH,
                                  seed=11)
    reqs_a, reqs_b = _requests(9, seed=6), _requests(5, seed=8)
    refs_a, refs_b = _reference(acc, reqs_a), _reference(acc_b, reqs_b)
    with api.Fleet({"a": acc, "b": acc_b}, max_batch=MAX_BATCH,
                   buckets=BUCKETS) as fleet:
        pairs = [("a", r) for r in reqs_a] + [("b", r) for r in reqs_b]
        results = fleet.run_many(pairs)
        st_a, st_b = fleet.stats()["a"], fleet.stats()["b"]
    _check_routing(results[:9], refs_a)
    _check_routing(results[9:], refs_b)
    assert st_a.dispatched_rows == 9
    assert st_b.dispatched_rows == 5
    assert sum(st_a.device_batches.values()) == st_a.batches
    assert sum(st_b.device_batches.values()) == st_b.batches


# --------------------------------------------------------------------------
# hypothesis: randomized arrival patterns (CI; optional locally)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(
        trace=st.lists(
            st.tuples(st.integers(1, MAX_BATCH), st.sampled_from(
                [0.0, 0.5, 1.5, 3.0])),
            min_size=1, max_size=8),
        scheduler=st.sampled_from(api.ServingSession.SCHEDULERS),
        seed=st.integers(0, 2 ** 16),
    )
    def test_random_arrivals_route_and_balance(trace, scheduler, seed):
        acc = _hyp_acc()
        results, refs, stats = _run_trace(acc, trace, scheduler, seed=seed)
        _check_routing(results, refs)
        _check_accounting(stats, sum(b for b, _ in trace))

    _HYP_ACC = None

    def _hyp_acc():
        """Module-cached accelerator (fixtures don't reach @given bodies)."""
        global _HYP_ACC
        if _HYP_ACC is None:
            _HYP_ACC = api.Accelerator.build(SPECS, target=pm.V5E,
                                             batch=MAX_BATCH, seed=0)
        return _HYP_ACC
