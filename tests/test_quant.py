"""The ``repro.quant`` subsystem: calibration observers, the versioned
``QuantSidecar``, per-channel weight quantization, the int8 PE paths
(executor == strict interpreter == literal lowering == Pallas, BITWISE),
quantized save/load roundtrips, quant-aware DSE, and the compression
utilities wired in through ``repro.optim``."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import perf_model as pm
from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
from repro.optim.compression import quantize_int8
from repro.quant import (LayerQuant, QuantSidecar, calibrate,
                         quantize_params)
from repro.quant.observers import make_observer

# small CONV->CONV->POOL->FC chain: cheap jits, still exercises the pool
# scale-passthrough and the FC tail
SPECS = [ConvSpec("c1", 16, 16, 3, 8), ConvSpec("c2", 16, 16, 8, 16),
         PoolSpec("p1", 16, 16, 16), FCSpec("fc", 8 * 8 * 16, 10, relu=False)]


def _data(n=4, seed=1, img=16):
    return np.random.default_rng(seed).standard_normal(
        (n, img, img, 3)).astype(np.float32)


def _build_pair(specs=SPECS, img=16, **kw):
    a32 = api.Accelerator.build(specs, target=pm.V5E, batch=2, seed=0)
    a8 = api.Accelerator.build(specs, target=pm.V5E, batch=2, seed=0,
                               params=a32.params, dtype="int8",
                               calib=_data(8, seed=2, img=img), **kw)
    return a32, a8


# ---------------------------------------------------------------------------
# observers
# ---------------------------------------------------------------------------

def test_minmax_observer_covers_every_sample():
    obs = make_observer("minmax")
    obs.observe(np.array([0.5, -3.0, 1.0]))
    obs.observe(np.array([2.0]))
    # scale maps the largest observed |x| to the int8 edge
    assert obs.scale == pytest.approx(3.0 / 127.0, rel=1e-5)


def test_percentile_observer_clips_outliers():
    xs = np.concatenate([np.linspace(-1, 1, 10_000), [1000.0]])
    obs = make_observer("percentile")
    obs.observe(xs)
    mm = make_observer("minmax")
    mm.observe(xs)
    assert obs.scale < mm.scale            # the outlier got clipped
    assert obs.scale < 10.0 / 127.0        # nowhere near the 1000 spike


def test_unknown_observer_rejected():
    with pytest.raises(ValueError, match="observer"):
        make_observer("entropy")


# ---------------------------------------------------------------------------
# calibration + per-channel weight scales
# ---------------------------------------------------------------------------

def test_calibrate_per_channel_weight_scales():
    params = api.random_params(SPECS, seed=0)
    sc = calibrate(SPECS, params, _data())
    conv_lq = sc.layers[0]
    assert isinstance(conv_lq.wgt_scale, tuple)
    assert len(conv_lq.wgt_scale) == SPECS[0].k      # one scale per filter
    fc_lq = sc.layers[3]
    assert len(fc_lq.wgt_scale) == SPECS[3].d_out
    # each channel's scale reconstructs that channel's |w|_max at 127
    w = np.asarray(params[0][0], np.float32)
    amax = np.abs(w).reshape(-1, w.shape[-1]).max(axis=0)
    np.testing.assert_allclose(np.asarray(conv_lq.wgt_scale) * 127.0,
                               amax, rtol=1e-5)


def test_pool_layer_is_scale_passthrough():
    sc = calibrate(SPECS, api.random_params(SPECS, seed=0), _data())
    lq = sc.layers[2]
    assert not lq.requantize
    assert lq.in_scale == lq.out_scale == sc.layers[1].out_scale


def test_quantize_params_shapes_and_range():
    params = api.random_params(SPECS, seed=0)
    sc = calibrate(SPECS, params, _data())
    qp = quantize_params(SPECS, params, sc)
    assert len(qp) == len(params)
    for (w, b), (qw, qb) in zip(params, qp):
        assert qw.shape == w.shape and qw.dtype == jnp.int8
        assert qb.shape == b.shape and qb.dtype == jnp.int32
        assert int(jnp.max(jnp.abs(qw))) <= 127
    # per-channel: every output channel independently reaches the int8
    # edge (the whole point — no filter is crushed by its neighbors)
    qw0 = np.asarray(qp[0][0])
    assert (np.abs(qw0).reshape(-1, qw0.shape[-1]).max(axis=0) == 127).all()


def test_multiplier_scalar_vs_vector():
    lq_t = LayerQuant("dw", 0.5, 0.25, wgt_scale=0.1)
    assert lq_t.multiplier == pytest.approx(0.5 * 0.1 / 0.25)
    lq_c = LayerQuant("conv", 0.5, 0.25, wgt_scale=(0.1, 0.2))
    m = lq_c.multiplier
    assert m.shape == (2,)
    np.testing.assert_allclose(m, [0.2, 0.4], rtol=1e-6)


# ---------------------------------------------------------------------------
# sidecar (de)serialization + digest
# ---------------------------------------------------------------------------

def test_sidecar_roundtrip_preserves_digest():
    sc = calibrate(SPECS, api.random_params(SPECS, seed=0), _data())
    sc2 = QuantSidecar.from_dict(json.loads(json.dumps(sc.to_dict())))
    assert sc2 == sc
    assert sc2.digest("key") == sc.digest("key")


def test_sidecar_digest_binds_schedule():
    sc = calibrate(SPECS, api.random_params(SPECS, seed=0), _data())
    assert sc.digest("schedule-a") != sc.digest("schedule-b")


def test_sidecar_rejects_unknown_format():
    sc = calibrate(SPECS, api.random_params(SPECS, seed=0), _data())
    doc = sc.to_dict()
    doc["format"] = "hybriddnn-quant/v99"
    with pytest.raises(ValueError, match="format"):
        QuantSidecar.from_dict(doc)


# ---------------------------------------------------------------------------
# end-to-end int8 builds
# ---------------------------------------------------------------------------

def test_int8_build_float_in_float_out():
    a32, a8 = _build_pair()
    x = _data(2)
    y = np.asarray(a8(x))
    assert y.dtype == np.float32 and y.shape == (2, 10)
    # dequantized logits track fp32 within the quantization design point
    assert np.max(np.abs(y - np.asarray(a32(x)))) < 0.5


def test_int8_executor_matches_strict_interpreter_bitwise():
    _, a8 = _build_pair()
    q = a8.quant.quantize_input(jnp.asarray(_data(2)))
    np.testing.assert_array_equal(np.asarray(a8._request(q)),
                                  np.asarray(a8.strict_request()(q)))


def test_int8_literal_lowering_bitwise():
    """opt_level=0 (literal per-block) == opt_level=1 (fused) on int8:
    integer accumulation is exact, so the lowering rewrite must be
    invisible bit for bit — including the per-channel multiplier slicing
    on k-grouped blocks."""
    _, a8 = _build_pair()
    a8_0 = api.Accelerator.build(SPECS, target=pm.V5E, batch=2, seed=0,
                                 params=api.random_params(SPECS, seed=0),
                                 dtype="int8", calib=_data(8, seed=2),
                                 opt_level=0)
    q = a8.quant.quantize_input(jnp.asarray(_data(2)))
    np.testing.assert_array_equal(np.asarray(a8._request(q)),
                                  np.asarray(a8_0._request(q)))


def test_int8_pallas_backend_bitwise():
    _, a8 = _build_pair()
    a8_pl = api.Accelerator.build(SPECS, target=pm.V5E, batch=2, seed=0,
                                  params=api.random_params(SPECS, seed=0),
                                  dtype="int8", calib=_data(8, seed=2),
                                  backend="pallas")
    q = a8.quant.quantize_input(jnp.asarray(_data(2)))
    np.testing.assert_array_equal(np.asarray(a8._request(q)),
                                  np.asarray(a8_pl._request(q)))


def test_int8_rejects_segmented_and_bad_dtype():
    with pytest.raises(ValueError, match="fp32-only"):
        api.Accelerator.build(SPECS, target=pm.V5E, dtype="int8",
                              segmented=True)
    with pytest.raises(ValueError, match="dtype"):
        api.Accelerator.build(SPECS, target=pm.V5E, dtype="int4")


def test_int8_dse_gates_winograd_off():
    _, a8 = _build_pair()
    assert all(p.mode != "wino" for p in a8.plans)
    assert "int8" in a8.dse.hw.name if hasattr(a8.dse.hw, "name") else True


def test_summary_shows_dtype_column():
    a32, a8 = _build_pair()
    assert "int8+rq" in a8.summary()
    assert "int8+rq" not in a32.summary()
    assert "fp32" in a32.summary()


# ---------------------------------------------------------------------------
# save / load roundtrip
# ---------------------------------------------------------------------------

def test_quantized_program_roundtrip(tmp_path):
    _, a8 = _build_pair()
    path = str(tmp_path / "prog_int8.json")
    a8.save_program(path)
    # fp32 params: the loader re-quantizes deterministically per sidecar
    a8b = api.Accelerator.from_program(
        path, params=api.random_params(SPECS, seed=0))
    x = _data(2)
    np.testing.assert_array_equal(np.asarray(a8(x)), np.asarray(a8b(x)))
    # pre-quantized int8 params pass straight through
    a8c = api.Accelerator.from_program(path, params=a8.params)
    np.testing.assert_array_equal(np.asarray(a8(x)), np.asarray(a8c(x)))


def test_tampered_sidecar_rejected(tmp_path):
    _, a8 = _build_pair()
    path = str(tmp_path / "prog_int8.json")
    a8.save_program(path)
    doc = json.load(open(path))
    doc["quant"]["sidecar"]["input_scale"] *= 2.0
    json.dump(doc, open(path, "w"))
    with pytest.raises(ValueError, match="digest"):
        api.Accelerator.from_program(
            path, params=api.random_params(SPECS, seed=0))


def test_fp32_artifacts_unaffected(tmp_path):
    a32, _ = _build_pair()
    path = str(tmp_path / "prog_fp32.json")
    a32.save_program(path)
    doc = json.load(open(path))
    assert doc["quant"] is None
    a32b = api.Accelerator.from_program(
        path, params=api.random_params(SPECS, seed=0))
    assert a32b.quant is None
    x = _data(2)
    np.testing.assert_array_equal(np.asarray(a32(x)), np.asarray(a32b(x)))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_int8_serving_session_matches_direct():
    _, a8 = _build_pair()
    x = _data(4)
    with a8.serve(max_batch=4, max_wait_ms=1.0) as sess:
        ys = sess.run_many(list(x))
    direct = np.asarray(a8(x))
    for i, y in enumerate(ys):
        np.testing.assert_array_equal(np.asarray(y), direct[i])


# ---------------------------------------------------------------------------
# reduced-model bitwise parity (the acceptance checks, fast-tier sized)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["vgg16", "resnet18"])
def test_reduced_model_int8_bitwise(model):
    from repro.models import resnet, vgg
    specs = (vgg.network_specs(img=32, scale=16, n_classes=10)
             if model == "vgg16"
             else resnet.resnet18_specs(img=32, scale=16, n_classes=10))
    params = api.random_params(specs, seed=3)
    a8 = api.Accelerator.build(specs, target=pm.V5E, batch=2, params=params,
                               dtype="int8", calib=_data(4, seed=2, img=32))
    q = a8.quant.quantize_input(jnp.asarray(_data(2, img=32)))
    np.testing.assert_array_equal(np.asarray(a8._request(q)),
                                  np.asarray(a8.strict_request()(q)))


@pytest.mark.slow
def test_top1_agreement_thresholds():
    """The bench acceptance criterion, at the bench's agreement configs:
    >= 0.98 top-1 agreement vs fp32 on reduced VGG16 (scale=4) and
    ResNet-18 (scale=8), minmax observer, eval-distribution calibration."""
    from repro.models import resnet, vgg
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, 32, 32, 3)).astype(np.float32)
    xe = rng.standard_normal((256, 32, 32, 3)).astype(np.float32)
    for specs in (vgg.network_specs(img=32, scale=4, n_classes=10),
                  resnet.resnet18_specs(img=32, scale=8, n_classes=10)):
        a32 = api.Accelerator.build(specs, target=pm.V5E, batch=2, seed=0)
        a8 = api.Accelerator.build(specs, target=pm.V5E, batch=2, seed=0,
                                   params=a32.params, dtype="int8",
                                   calib=calib, observer="minmax")
        agree = float(np.mean(np.argmax(np.asarray(a8(xe)), -1)
                              == np.argmax(np.asarray(a32(xe)), -1)))
        assert agree >= 0.98, (specs[0].name, agree)


# ---------------------------------------------------------------------------
# satellite: repro.optim package wiring
# ---------------------------------------------------------------------------

def test_optim_package_exports_compression():
    import repro.optim
    assert repro.optim.quantize_int8 is quantize_int8


def test_quantize_int8_roundtrip_error_bounded():
    w = np.random.default_rng(0).standard_normal((64,)).astype(np.float32)
    q, scale = quantize_int8(w)
    assert q.dtype == np.int8 and np.abs(q).max() <= 127
    assert np.max(np.abs(q.astype(np.float32) * scale - w)) <= scale / 2 + 1e-7
