"""AOT executable export (``repro.core.aot``): the artifact-integrity tier.

Four property groups:

* **bitwise warm-load** — an executor deserialized from a saved bundle
  produces outputs bitwise identical to the fresh trace+compile path,
  across {xla, pallas-interpret} x {fp32, int8} x {opt_level 0, 1}, with
  ``SessionStats.compile_ms`` exactly zero (nothing compiled);
* **stale-key fallback** — every key dimension that can drift (device
  kind, jax version, schedule, quant digest) triggers a fresh-compile
  fallback with the stale dimension named in the ``repro.aot`` log, never
  a wrong answer;
* **negative load paths** — truncated JSON, unknown format version and a
  quant sidecar spliced from a different schedule each raise
  ``api.ProgramLoadError``;
* **key stability** — the program-cache key and the AOT artifact digest
  are deterministic across process restarts for randomized Programs, and
  any single key-dimension change produces a distinct digest (hypothesis
  when installed, seeded sweep otherwise).

Run as a script (``python tests/test_aot_export.py digests <seed>...``) the
file prints artifact digests for generated programs — the cross-process
determinism test execs itself that way under a fresh interpreter.
"""
import json
import logging
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import aot
from repro.core import perf_model as pm
from repro.core.compiler import LayerPlan, compile_network
from repro.core.hybrid_conv import ConvSpec, FCSpec, PoolSpec
from repro.core.program_cache import ProgramCache, cache_key

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # optional dev dep; the seeded sweep still runs
    HAVE_HYPOTHESIS = False

SPECS = [ConvSpec("c1", 8, 8, 3, 8), PoolSpec("p1", 8, 8, 8),
         FCSpec("fc", 4 * 4 * 8, 10, relu=False)]
BATCH = 2


def _build(backend="xla", dtype="fp32", opt_level=1):
    rng = np.random.default_rng(0)
    calib = (rng.standard_normal((8, 8, 8, 3)).astype(np.float32)
             if dtype == "int8" else None)
    return api.Accelerator.build(
        SPECS, target=pm.V5E, batch=BATCH, seed=0, backend=backend,
        opt_level=opt_level,
        dtype="float32" if dtype == "fp32" else dtype, calib=calib)


def _requests(n, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((8, 8, 3)).astype(np.float32)
            for _ in range(n)]


# --------------------------------------------------------------------------
# bitwise warm-load across the full matrix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("dtype", ["fp32", "int8"])
@pytest.mark.parametrize("opt_level", [0, 1])
def test_warm_load_bitwise_matrix(tmp_path, backend, dtype, opt_level):
    """Warm-loaded executors are BITWISE the fresh-compile path — same
    serialized XLA binary, not a float-tolerance lookalike — and the warm
    session compiles nothing (compile_ms == 0)."""
    acc = _build(backend, dtype, opt_level)
    reqs = _requests(2 * BATCH)
    with acc.serve(max_batch=BATCH, buckets=(1, BATCH), warmup=True) as s:
        fresh = [np.asarray(y) for y in s.run_many(reqs)]
        assert s.stats.compile_ms > 0          # this one DID compile
        assert s.stats.warm_load_ms == 0.0

    bundle = str(tmp_path / "bundle")
    acc.save_program(bundle, aot=True, buckets=(1, BATCH))
    warm_cache = ProgramCache()               # no in-process entries: every
    acc2 = api.Accelerator.from_program(       # lookup must hit the disk
        bundle, params=acc.params, cache=warm_cache,
        backend=backend, opt_level=opt_level)
    with acc2.serve(max_batch=BATCH, buckets=(1, BATCH), warmup=True) as s:
        warm = [np.asarray(y) for y in s.run_many(reqs)]
        st = s.stats
    assert warm_cache.stats.aot_loads >= 2     # both buckets deserialized
    assert st.compile_ms == 0.0                # NOTHING traced or compiled
    assert st.warm_load_ms > 0.0
    for a, b in zip(fresh, warm):
        np.testing.assert_array_equal(a, b)    # bitwise, not allclose

    # the direct acc(x) entry warm-loads too
    x = np.stack(_requests(BATCH, seed=9))
    np.testing.assert_array_equal(np.asarray(acc(x)), np.asarray(acc2(x)))


# --------------------------------------------------------------------------
# stale-key dimensions: fallback + logged reason, never a wrong answer
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bundle(tmp_path_factory):
    acc = _build()
    path = str(tmp_path_factory.mktemp("aot") / "bundle")
    acc.save_program(path, aot=True, buckets=(1, BATCH))
    return acc, path


def _key_for(acc, batch=BATCH, donate=False):
    rt = acc.runtime
    params = rt.dram_params()
    return cache_key(rt.program, batch=batch, dtype=acc.input_dtype,
                     param_dtypes=tuple(jnp.dtype(w.dtype).name
                                        for w, _ in params),
                     backend=rt.backend, interpret=rt.interpret,
                     opt_level=rt.opt_level, donate_input=donate,
                     quant=rt.quant)


def _load_expect_fallback(aot_dir, key, caplog, reason_substr, env=None):
    with caplog.at_level(logging.INFO, logger="repro.aot"):
        fn = aot.load_entry(aot_dir, key, env=env)
    assert fn is None
    text = caplog.text
    assert "falling back to fresh compile" in text
    assert reason_substr in text
    return text


def test_stale_device_kind_falls_back(bundle, caplog):
    acc, path = bundle
    env = dict(aot.environment_fingerprint(), device_kind="TPU v9000")
    _load_expect_fallback(os.path.join(path, "aot"), _key_for(acc),
                          caplog, "device_kind", env=env)


def test_stale_jax_version_falls_back(bundle, caplog, monkeypatch):
    """Version drift detected end-to-end: a bundle saved under another jax
    release recompiles fresh — and the recompiled answers stay bitwise
    right, because the fallback is the ordinary compile path."""
    acc, path = bundle
    env = dict(aot.environment_fingerprint(), jax_version="0.0.1",
               jaxlib_version="0.0.1")
    _load_expect_fallback(os.path.join(path, "aot"), _key_for(acc),
                          caplog, "jax_version", env=env)

    monkeypatch.setattr(aot, "environment_fingerprint", lambda: env)
    fresh_cache = ProgramCache()
    acc2 = api.Accelerator.from_program(path, params=acc.params,
                                        cache=fresh_cache)
    x = np.stack(_requests(BATCH, seed=3))
    np.testing.assert_array_equal(np.asarray(acc(x)), np.asarray(acc2(x)))
    assert fresh_cache.stats.aot_loads == 0    # every artifact was stale


def test_stale_schedule_falls_back(bundle, caplog):
    """A different instruction stream (schedule tamper/drift) never picks
    up the old binary."""
    acc, path = bundle
    other = compile_network(
        [ConvSpec("c1", 8, 8, 3, 8, relu=False)],
        [LayerPlan("spat", "ws", m=2, g_k=1, g_h=1)])
    key = list(_key_for(acc))
    key[0] = other.schedule_key()
    _load_expect_fallback(os.path.join(path, "aot"), tuple(key),
                          caplog, "schedule")


def test_stale_quant_digest_falls_back(bundle, caplog):
    """A tampered/re-calibrated quant sidecar changes the digest dimension
    of the key — the fp32-keyed (or differently-calibrated) binary must not
    serve it."""
    acc, path = bundle
    key = list(_key_for(acc))
    key[9] = "deadbeefdeadbeef"                # quant digest dimension
    _load_expect_fallback(os.path.join(path, "aot"), tuple(key),
                          caplog, "quant_digest")


def test_truncated_artifact_falls_back(bundle, caplog):
    acc, path = bundle
    aot_dir = os.path.join(path, "aot")
    key = _key_for(acc, batch=BATCH, donate=True)
    digest = aot.artifact_digest(aot.artifact_key(key))
    artifact = os.path.join(aot_dir, f"{digest}.aotx")
    blob = open(artifact, "rb").read()
    try:
        with open(artifact, "wb") as f:
            f.write(blob[: len(blob) // 2])
        _load_expect_fallback(aot_dir, key, caplog, "unreadable")
    finally:
        with open(artifact, "wb") as f:
            f.write(blob)


def test_tampered_manifest_falls_back(bundle, caplog):
    """A hand-edited manifest entry no longer matches its own digest — the
    artifact is refused even though the file exists."""
    acc, path = bundle
    aot_dir = os.path.join(path, "aot")
    mpath = os.path.join(aot_dir, aot.MANIFEST)
    saved = open(mpath).read()
    manifest = json.loads(saved)
    key = _key_for(acc, batch=BATCH, donate=True)
    digest = aot.artifact_digest(aot.artifact_key(key))
    try:
        manifest[digest]["opt_level"] = 99
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        _load_expect_fallback(aot_dir, key, caplog, "opt_level")
    finally:
        with open(mpath, "w") as f:
            f.write(saved)


# --------------------------------------------------------------------------
# save_program/from_program negative paths (named errors)
# --------------------------------------------------------------------------

def test_from_program_truncated_json(tmp_path):
    acc = _build()
    path = acc.save_program(str(tmp_path / "prog.json"))
    blob = open(path).read()
    with open(path, "w") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(api.ProgramLoadError, match="truncated or not JSON"):
        api.Accelerator.from_program(path, params=acc.params)


def test_from_program_unknown_format_version(tmp_path):
    acc = _build()
    path = acc.save_program(str(tmp_path / "prog.json"))
    doc = json.load(open(path))
    doc["format"] = "hybriddnn-program/v999"
    json.dump(doc, open(path, "w"))
    with pytest.raises(api.ProgramLoadError, match="v999"):
        api.Accelerator.from_program(path, params=acc.params)


def test_from_program_sidecar_from_other_schedule(tmp_path):
    """A quant sidecar spliced in from a DIFFERENT network's saved program
    is rejected: its digest is bound to the donor's schedule."""
    acc_a = _build(dtype="int8")
    other_specs = [ConvSpec("c1", 8, 8, 3, 16), PoolSpec("p1", 8, 8, 16),
                   FCSpec("fc", 4 * 4 * 16, 10, relu=False)]
    rng = np.random.default_rng(0)
    acc_b = api.Accelerator.build(
        other_specs, target=pm.V5E, batch=BATCH, seed=0, dtype="int8",
        calib=rng.standard_normal((8, 8, 8, 3)).astype(np.float32))
    path_a = acc_a.save_program(str(tmp_path / "a.json"))
    path_b = acc_b.save_program(str(tmp_path / "b.json"))
    doc_a, doc_b = json.load(open(path_a)), json.load(open(path_b))
    doc_b["quant"] = doc_a["quant"]            # the splice
    json.dump(doc_b, open(path_b, "w"))
    with pytest.raises(api.ProgramLoadError, match="sidecar"):
        api.Accelerator.from_program(path_b, params=acc_b.params)


def test_bundle_dir_without_program_json(tmp_path):
    d = tmp_path / "not_a_bundle"
    d.mkdir()
    with pytest.raises(api.ProgramLoadError, match="program.json"):
        api.Accelerator.from_program(str(d), params=[])


# --------------------------------------------------------------------------
# key stability: deterministic across processes, distinct per dimension
# --------------------------------------------------------------------------

def _random_program(seed: int):
    """A randomized (but seed-deterministic) single-conv Program."""
    rng = np.random.default_rng(seed)
    h = int(rng.choice([6, 8, 12]))
    c, k = int(rng.integers(1, 5)), int(rng.integers(2, 9))
    mode = "wino" if rng.integers(2) else "spat"
    flow = "ws" if rng.integers(2) else "is"
    specs = [ConvSpec("c1", h, h, c, k, relu=bool(rng.integers(2)))]
    plans = [LayerPlan(mode, flow, m=2, g_k=int(rng.integers(1, 3)),
                       g_h=int(rng.integers(1, 3)))]
    return compile_network(specs, plans)


def _digest_for_seed(seed: int) -> str:
    prog = _random_program(seed)
    key = cache_key(prog, batch=int(2 + seed % 3), dtype=jnp.float32,
                    param_dtypes=("float32",))
    return aot.artifact_digest(aot.artifact_key(key))


_STABILITY_SEEDS = (0, 1, 2, 7, 23, 1009)


def test_keys_deterministic_across_process_restart():
    """Same Program, fresh interpreter -> same cache key and artifact
    digest: nothing id()-, hash-randomization- or order-dependent leaks
    into the on-disk identity."""
    here = [_digest_for_seed(s) for s in _STABILITY_SEEDS]
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src"),
                    os.environ.get("PYTHONPATH", "")]))
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "digests",
         *map(str, _STABILITY_SEEDS)],
        capture_output=True, text=True, env=env, timeout=240)
    assert r.returncode == 0, r.stderr
    there = r.stdout.split()
    assert here == there


def _assert_single_dim_changes_distinct(seed: int):
    prog = _random_program(seed)
    base = cache_key(prog, batch=2, dtype=jnp.float32,
                     param_dtypes=("float32",))
    other = _random_program(seed + 1)
    if other.schedule_key() == prog.schedule_key():
        other = _random_program(seed + 2)
    variants = {
        "schedule": other.schedule_key(), "batch": 4, "dtype": "int8",
        "param_dtypes": ("int8",), "backend": "pallas", "interpret": True,
        "opt_level": 0, "donate_input": True,
        "mesh": ((2,), ("x",), (0, 1)), "quant_digest": "deadbeef",
    }
    dims = list(aot.artifact_key(base))[1:11]  # skip "format", pre-env dims
    digests = {aot.artifact_digest(aot.artifact_key(base))}
    for i, dim in enumerate(dims):
        t = list(base)
        t[i] = variants[dim]
        assert tuple(t) != base
        d = aot.artifact_digest(aot.artifact_key(tuple(t)))
        assert d not in digests, f"dimension {dim} did not change the key"
        digests.add(d)
    # the environment dimensions separate artifacts too
    for dim, v in (("device_kind", "TPU v9000"), ("platform", "neuromorph"),
                   ("jax_version", "0.0.1"), ("jaxlib_version", "0.0.1")):
        env = dict(aot.environment_fingerprint())
        env[dim] = v
        d = aot.artifact_digest(aot.artifact_key(base, env=env))
        assert d not in digests, f"env dimension {dim} did not change the key"
        digests.add(d)


def test_single_dimension_change_gives_distinct_key_seeded():
    for seed in _STABILITY_SEEDS:
        _assert_single_dim_changes_distinct(seed)


def test_cache_key_pure():
    """Recompiling the same specs/plans yields the identical key tuple."""
    a, b = _random_program(5), _random_program(5)
    assert a is not b
    assert (cache_key(a, batch=2, dtype=jnp.float32)
            == cache_key(b, batch=2, dtype=jnp.float32))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_single_dimension_change_gives_distinct_key(seed):
        _assert_single_dim_changes_distinct(seed)


if __name__ == "__main__":
    # child half of test_keys_deterministic_across_process_restart
    if len(sys.argv) > 1 and sys.argv[1] == "digests":
        print(" ".join(_digest_for_seed(int(s)) for s in sys.argv[2:]))
