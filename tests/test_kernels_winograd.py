"""Winograd transform kernels + end-to-end hybrid conv vs direct conv."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.winograd import (
    mult_reduction, transform_weights, winograd_conv2d_reference,
)
from repro.kernels.spatial_conv import spatial_conv2d
from repro.kernels.spatial_conv.ref import spatial_conv2d_ref
from repro.kernels.winograd import (
    input_transform, output_transform, winograd_conv2d,
)
from repro.kernels.winograd.ref import (
    conv2d_ref, input_transform_ref, output_transform_ref,
)


@pytest.mark.parametrize("m", [2, 4])
def test_input_transform(m):
    pt = m + 2
    tiles = jax.random.normal(jax.random.PRNGKey(0), (10, pt, pt, 7))
    np.testing.assert_allclose(np.asarray(input_transform(tiles, m)),
                               np.asarray(input_transform_ref(tiles, m)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m", [2, 4])
@pytest.mark.parametrize("relu", [False, True])
def test_output_transform(m, relu):
    pt = m + 2
    marr = jax.random.normal(jax.random.PRNGKey(1), (pt * pt, 10, 5))
    bias = jax.random.normal(jax.random.PRNGKey(2), (5,))
    np.testing.assert_allclose(
        np.asarray(output_transform(marr, bias, m, relu=relu)),
        np.asarray(output_transform_ref(marr, bias, m, relu=relu)),
        rtol=1e-5, atol=1e-5)


CONV_CASES = [
    (1, 8, 8, 3, 4, 3),
    (2, 14, 14, 8, 16, 3),
    (1, 12, 10, 4, 8, 5),    # kernel decomposition 5x5
    (1, 16, 16, 3, 4, 7),    # kernel decomposition 7x7
]


@pytest.mark.parametrize("n,h,w,c,k,r", CONV_CASES)
@pytest.mark.parametrize("m", [2, 4])
def test_winograd_conv_vs_direct(n, h, w, c, k, r, m):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (n, h, w, c), jnp.float32)
    g = jax.random.normal(kw, (r, r, c, k), jnp.float32) * 0.3
    b = jax.random.normal(kb, (k,), jnp.float32)
    y = winograd_conv2d(x, g, b, m=m, relu=True)
    yref = conv2d_ref(x, g, bias=b, relu=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("stride,pad", [(1, "SAME"), (2, "SAME"), (1, "VALID")])
def test_spatial_conv(stride, pad):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(kx, (2, 12, 12, 4), jnp.float32)
    g = jax.random.normal(kw, (3, 3, 4, 8), jnp.float32) * 0.3
    b = jax.random.normal(kb, (8,), jnp.float32)
    for df in ("is", "ws"):
        y = spatial_conv2d(x, g, b, stride=stride, padding=pad, relu=True,
                           dataflow=df)
        yref = spatial_conv2d_ref(x, g, b, stride=stride, padding=pad,
                                  relu=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)


def test_mult_reduction_paper_claim():
    """Paper Sec 4.2.1: F(4x4,3x3) needs 36 mults vs 144 -> exactly 4x."""
    assert mult_reduction(4) == 4.0
    assert mult_reduction(2) == 2.25


def test_weight_transform_shapes():
    g = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 5, 7))
    u = transform_weights(g, 4)
    assert u.shape == (6, 6, 5, 7)


def test_reference_matches_pallas():
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (1, 12, 12, 3), jnp.float32)
    g = jax.random.normal(kw, (3, 3, 3, 8), jnp.float32) * 0.3
    np.testing.assert_allclose(
        np.asarray(winograd_conv2d(x, g, m=4)),
        np.asarray(winograd_conv2d_reference(x, g, m=4)),
        rtol=2e-3, atol=2e-3)
