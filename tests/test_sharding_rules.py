"""Partition-rule unit tests (no multi-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import (
    make_rules, param_specs, shard, use_rules, zero1_specs,
)
from repro.train import steps as steps_lib


def _fake_rules(shape=(2, 4), names=("data", "model")):
    # abstract mesh over fake devices is not needed: host mesh works on CPU
    mesh = make_host_mesh()
    return make_rules(mesh)


def test_param_specs_cover_all_leaves():
    cfg = get_config("qwen3-32b").reduced()
    rules = _fake_rules()
    aparams = jax.eval_shape(
        lambda: steps_lib.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(aparams, rules)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    params_leaves = jax.tree.leaves(aparams)
    assert len(leaves) == len(params_leaves)
    assert all(isinstance(s, P) for s in leaves)


@pytest.mark.parametrize("arch", ["minitron-8b", "llama4-scout-17b-16e",
                                  "mamba2-130m", "zamba2-7b", "whisper-base"])
def test_specs_divisible_on_production_mesh(arch):
    """Every param spec divides its dim on the (16,16) mesh (jit contract)."""
    import dataclasses
    cfg = get_config(arch)
    rules = _fake_rules()
    # emulate production axis sizes by checking against 16 directly
    aparams = jax.eval_shape(
        lambda: steps_lib.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(aparams, rules)

    def check(leaf, spec):
        for dim, entry in enumerate(spec):
            if entry is not None:
                # host mesh model axis = n_local_devices; just sanity check
                assert leaf.shape[dim] >= 1
    jax.tree.map(check, aparams, specs,
                 is_leaf=lambda x: isinstance(x, P))


def test_shard_noop_without_rules():
    x = jnp.ones((4, 4))
    y = shard(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shard_drops_indivisible_dims():
    rules = _fake_rules()
    n_model = rules.mesh.devices.shape[-1]
    with use_rules(rules):
        x = jnp.ones((3, 5))      # 5 not divisible by any axis > 1
        y = shard(x, None, "mlp")  # must not raise
        assert y.shape == x.shape


def test_zero1_adds_dp_axis():
    cfg = get_config("minitron-8b").reduced()
    rules = _fake_rules()
    aparams = jax.eval_shape(
        lambda: steps_lib.init_params(jax.random.PRNGKey(0), cfg))
    z = zero1_specs(aparams, rules)
    # embed (V, D): dim0 None -> dp axes added when divisible
    emb_spec = z["embed"]
    assert emb_spec[0] in (("data",), "data", None)
