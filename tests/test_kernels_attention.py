"""Flash-attention Pallas kernel + the pjit scan-flash vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.layers import _flash_attention_scan

CASES = [(1, 2, 2, 64, 32), (2, 4, 2, 128, 64), (1, 8, 1, 100, 32)]


@pytest.mark.parametrize("b,h,hkv,s,d", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel(b, h, hkv, s, d, causal):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (b, h, s, d), jnp.float32)
    k = jax.random.normal(kk, (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(kv, (b, hkv, s, d), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    kk_ = jnp.repeat(k, h // hkv, axis=1).reshape(b * h, s, d)
    vv_ = jnp.repeat(v, h // hkv, axis=1).reshape(b * h, s, d)
    ref = attention_ref(q.reshape(b * h, s, d), kk_, vv_,
                        causal=causal).reshape(b, h, s, d)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16():
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (1, 2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(kk, (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(kv, (1, 2, 128, 64), jnp.bfloat16)
    o = np.asarray(flash_attention(q, k, v, bq=64, bk=64), np.float32)
    ref = np.asarray(attention_ref(
        q.reshape(2, 128, 64), k.reshape(2, 128, 64),
        v.reshape(2, 128, 64)).reshape(1, 2, 128, 64), np.float32)
    np.testing.assert_allclose(o, ref, rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("causal", [True, False])
def test_scan_flash_matches_direct(causal):
    """The pjit-internal scan-flash == direct softmax attention."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, g, r, d = 1, 64, 2, 2, 16
    q = jax.random.normal(kq, (b, s, g, r, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, g, d), jnp.float32)
    v = jax.random.normal(kv, (b, s, g, d), jnp.float32)
    o = _flash_attention_scan(q, k, v, causal=causal, block=16)
    sc = jnp.einsum("bqgrd,bkgd->bgrqk", q, k) * d ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    ref = jnp.einsum("bgrqk,bkgd->bqgrd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
