"""ELTWISE_ADD / DEPTHWISE_CONV end-to-end + the latent-bug regressions
this workload flushed out (integer pooling, silently-ignored PE knobs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid_conv import (
    ConvSpec,
    DepthwiseSpec,
    EltwiseSpec,
    FCSpec,
    dense,
    depthwise_conv2d,
    hybrid_conv2d,
    max_pool2d,
    same_pad,
)


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
def test_max_pool2d_integer_dtypes(dtype):
    """Regression: the reduce_window init value was a raw Python int, so
    integer inputs raised a dtype-inconsistency TypeError. Int pooling must
    work and agree with the float result."""
    rng = np.random.default_rng(0)
    lo, hi = (-128, 127) if dtype == jnp.int8 else (-10_000, 10_000)
    x = jnp.asarray(rng.integers(lo, hi + 1, (2, 8, 8, 3)), dtype)
    y = max_pool2d(x)
    assert y.dtype == dtype and y.shape == (2, 4, 4, 3)
    y_f = max_pool2d(x.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(y_f).astype(dtype))
    # the minimum representable value must survive (the init must not win)
    x_min = jnp.full((1, 2, 2, 1), jnp.iinfo(dtype).min, dtype)
    assert int(max_pool2d(x_min)[0, 0, 0, 0]) == jnp.iinfo(dtype).min


def test_hybrid_conv2d_rejects_ignored_knobs():
    """Regression: ``use_pallas=False`` silently ignored ``dataflow=`` and
    ``interpret=`` — callers believed WS dataflow / interpret mode was
    exercised when the XLA path ran instead. Both now raise."""
    x = jnp.zeros((1, 8, 8, 3), jnp.float32)
    g = jnp.zeros((3, 3, 3, 4), jnp.float32)
    with pytest.raises(ValueError, match="dataflow"):
        hybrid_conv2d(x, g, use_pallas=False, dataflow="ws")
    with pytest.raises(ValueError, match="interpret"):
        hybrid_conv2d(x, g, use_pallas=False, interpret=True)
    with pytest.raises(ValueError, match="interpret"):
        hybrid_conv2d(x, g, use_pallas=False, interpret=False)
    hybrid_conv2d(x, g, use_pallas=False)                    # defaults: fine
    hybrid_conv2d(x, g, use_pallas=False, dataflow="is")     # explicit ok


def test_dense_rejects_ignored_interpret():
    x = jnp.zeros((2, 8), jnp.float32)
    w = jnp.zeros((8, 4), jnp.float32)
    with pytest.raises(ValueError, match="interpret"):
        dense(x, w, use_pallas=False, interpret=True)
    dense(x, w, use_pallas=False)                            # default: fine


def test_same_pad_stride_aware():
    """Regression: the executor/compiler derived SAME halos with the
    stride-1 rule ``(k-1)//2``, shifting strided layers one pixel against
    the lax numerics. The shared helper must follow the XLA/TF rule."""
    assert same_pad(32, 3, 1) == (1, 1)      # the VGG case — unchanged
    assert same_pad(32, 3, 2) == (0, 1)      # strided even input: asymmetric
    assert same_pad(32, 1, 2) == (0, 0)      # 1x1 projection: no halo
    assert same_pad(33, 3, 2) == (1, 1)      # odd input: symmetric again
    assert same_pad(4, 5, 1) == (2, 2)


# ---------------------------------------------------------------------------
# Depthwise: op-level and compiled-chain parity
# ---------------------------------------------------------------------------

def _dw_reference(x, w, b, stride, padding):
    """Per-channel lax.conv — the independent oracle."""
    outs = []
    for c in range(x.shape[-1]):
        y = jax.lax.conv_general_dilated(
            x[..., c:c + 1].astype(jnp.float32),
            w[:, :, :, c:c + 1].astype(jnp.float32),
            (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        outs.append(y)
    return jnp.concatenate(outs, -1) + b.astype(jnp.float32)


@pytest.mark.parametrize("stride", [1, 2])
def test_depthwise_conv2d_matches_per_channel(stride):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 5)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, 5)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((5,)), jnp.float32)
    y = depthwise_conv2d(x, w, b, stride=stride)
    ref = _dw_reference(x, w, b, stride, "SAME")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="depthwise kernel"):
        depthwise_conv2d(x, jnp.zeros((3, 3, 5, 5), jnp.float32))


def test_depthwise_chain_compiles_and_matches():
    """conv -> depthwise -> depthwise(stride 2) -> fc as ONE Program; the
    cached executor matches the strict interpreter bitwise and the
    spec-chain oracle exactly (both all-XLA)."""
    from repro import api
    from repro.core import perf_model as pm
    from repro.models.resnet import reference_forward

    specs = [ConvSpec("c1", 8, 8, 3, 6, relu=True),
             DepthwiseSpec("d1", 8, 8, 6, relu=True),
             DepthwiseSpec("d2", 8, 8, 6, stride=2, relu=False),
             FCSpec("f1", 4 * 4 * 6, 5)]
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=2)
    assert acc.program is not None
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (2, 8, 8, 3)), jnp.float32)
    y = np.asarray(acc(x))
    assert y.shape == (2, 5)
    np.testing.assert_array_equal(y, np.asarray(acc.strict_request()(x)))
    np.testing.assert_array_equal(
        y, np.asarray(reference_forward(acc.params, x, specs)))


# ---------------------------------------------------------------------------
# Eltwise: compiled-chain skip-liveness coverage
# ---------------------------------------------------------------------------

def test_eltwise_skip_tensor_stays_live():
    """The skip operand's DRAM buffer must survive the intervening layers:
    conv0's output feeds BOTH conv1 (next layer) and the add two layers
    later, so the planner may not recycle it until the add retires."""
    from repro import api
    from repro.core import perf_model as pm
    from repro.core.compiler import LayerPlan, compile_network
    from repro.models.resnet import reference_forward

    specs = [ConvSpec("c0", 8, 8, 3, 4, relu=True),
             ConvSpec("c1", 8, 8, 4, 4, relu=True),
             ConvSpec("c2", 8, 8, 4, 4, relu=False),
             EltwiseSpec("add", 8, 8, 4, skip_from=0, relu=True)]
    prog = compile_network(specs, [LayerPlan("spat", "is")] * 3 + [None])
    cl_add = prog.layers[3]
    assert cl_add.skip_src == 0
    assert cl_add.skip_addr == prog.layers[0].out_addr
    # conv1/conv2 outputs must not alias the still-live skip buffer
    for lid in (1, 2):
        assert prog.layers[lid].out_addr != cl_add.skip_addr
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=2)
    x = jnp.asarray(np.random.default_rng(4).standard_normal(
        (2, 8, 8, 3)), jnp.float32)
    y = np.asarray(acc(x))
    np.testing.assert_array_equal(y, np.asarray(acc.strict_request()(x)))
    np.testing.assert_array_equal(
        y, np.asarray(reference_forward(acc.params, x, specs)))


def test_eltwise_skip_from_network_input():
    """skip_from=-1 adds the raw network input back in — the planner must
    keep the input buffer live to the end of the chain."""
    from repro import api
    from repro.core import perf_model as pm
    from repro.models.resnet import reference_forward

    specs = [ConvSpec("c0", 8, 8, 3, 3, relu=True),
             EltwiseSpec("add", 8, 8, 3, skip_from=-1, relu=False)]
    acc = api.Accelerator.build(specs, target=pm.V5E, batch=2)
    x = jnp.asarray(np.random.default_rng(5).standard_normal(
        (2, 8, 8, 3)), jnp.float32)
    y = np.asarray(acc(x))
    np.testing.assert_array_equal(y, np.asarray(acc.strict_request()(x)))
    np.testing.assert_array_equal(
        y, np.asarray(reference_forward(acc.params, x, specs)))


def test_eltwise_shape_mismatch_rejected():
    """An fmap whose shape disagrees with the add's operand shape is a
    compile-time error, not silent broadcasting."""
    from repro.core.compiler import LayerPlan, compile_network
    specs = [ConvSpec("c0", 8, 8, 3, 4, relu=True),
             ConvSpec("c1", 8, 8, 4, 8, relu=False),   # 8 channels != 4
             EltwiseSpec("add", 8, 8, 8, skip_from=0)]
    with pytest.raises(ValueError, match="add"):
        compile_network(specs, [LayerPlan("spat", "is")] * 2 + [None])


# ---------------------------------------------------------------------------
# ResNet-18 end-to-end (the ISSUE's acceptance criteria)
# ---------------------------------------------------------------------------

def _resnet_case(img=32, scale=16, batch=2, **kwargs):
    from repro.models import resnet
    specs = resnet.resnet18_specs(img, scale, n_classes=10)
    acc = resnet.accelerator(img=img, scale=scale, n_classes=10,
                             batch=batch, **kwargs)
    x = jnp.asarray(np.random.default_rng(6).standard_normal(
        (batch, img, img, 3)), jnp.float32)
    return specs, acc, x


def test_resnet18_compiles_to_one_program():
    specs, acc, x = _resnet_case()
    assert acc.program is not None and acc.segment_runtimes is None
    kinds = [cl.kind for cl in acc.program.layers]
    assert kinds.count("conv") == 20 and kinds.count("eltwise") == 8
    assert kinds.count("pool") == 1 and kinds.count("fc") == 1
    y = np.asarray(acc(x))
    assert y.shape == (2, 10)


def test_resnet18_executor_matches_strict_bitwise():
    """xla backend: cached executor == strict per-instruction interpreter
    BITWISE, and both equal the spec-chain oracle — including the
    residual adds and the strided 1x1-projection shortcut blocks."""
    from repro.models.resnet import reference_forward
    specs, acc, x = _resnet_case()
    y = np.asarray(acc(x))
    np.testing.assert_array_equal(y, np.asarray(acc.strict_request()(x)))
    np.testing.assert_array_equal(
        y, np.asarray(reference_forward(acc.params, x, specs)))


def test_resnet18_pallas_interpret_close():
    """pallas backend (interpret mode off-TPU) stays within 1e-4 of the
    oracle end-to-end."""
    from repro.models.resnet import reference_forward
    specs, acc, x = _resnet_case(backend="pallas", interpret=True)
    y = np.asarray(acc(x))
    ref = np.asarray(reference_forward(acc.params, x, specs))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


def test_resnet18_serve_cnn_smoke():
    """The serving driver accepts the resnet18 model name end-to-end."""
    from repro.launch.serve import serve_cnn
    y = serve_cnn("resnet18", reduced=True, batch=2, iters=1)
    assert y.shape == (2, 10)
    with pytest.raises(ValueError, match="segment"):
        serve_cnn("resnet18", reduced=True, batch=2, iters=1, segmented=True)
