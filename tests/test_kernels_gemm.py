"""Shape/dtype sweep of the shared GEMM PE vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gemm import batched_matmul, matmul
from repro.kernels.gemm.kernel import batched_matmul_kernel
from repro.kernels.gemm.ref import batched_matmul_ref, matmul_ref

SHAPES = [
    (1, 16, 32, 24),
    (4, 130, 257, 100),
    (36, 64, 64, 128),   # PT^2 = 36 Winograd batch
    (2, 8, 8, 8),
    (1, 300, 64, 513),
]


@pytest.mark.parametrize("g,m,k,n", SHAPES)
@pytest.mark.parametrize("dataflow", ["is", "ws"])
def test_batched_matmul_f32(g, m, k, n, dataflow):
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (g, m, k), jnp.float32)
    b = jax.random.normal(kb, (g, k, n), jnp.float32)
    out = batched_matmul(a, b, dataflow=dataflow)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(batched_matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_dtypes(dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(ka, (2, 64, 128), dtype)
    b = jax.random.normal(kb, (2, 128, 64), dtype)
    out = np.asarray(batched_matmul(a, b), np.float32)
    ref = np.asarray(batched_matmul_ref(a, b), np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


def test_2d_wrapper():
    ka, kb = jax.random.split(jax.random.PRNGKey(2))
    a = jax.random.normal(ka, (50, 70), jnp.float32)
    b = jax.random.normal(kb, (70, 30), jnp.float32)
    np.testing.assert_allclose(np.asarray(matmul(a, b)),
                               np.asarray(matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_fused_bias_relu_epilogue():
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.random.normal(ka, (1, 64, 64), jnp.float32)
    b = jax.random.normal(kb, (1, 64, 128), jnp.float32)
    bias = jax.random.normal(kc, (1, 128), jnp.float32)
    out = batched_matmul_kernel(a, b, bias, bm=64, bn=128, bk=64, relu=True)
    ref = jnp.maximum(batched_matmul_ref(a, b) + bias[:, None, :], 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_is_ws_equivalent():
    """The paper's two dataflows must be bit-compatible up to reassociation."""
    ka, kb = jax.random.split(jax.random.PRNGKey(4))
    a = jax.random.normal(ka, (3, 96, 160, ), jnp.float32)
    b = jax.random.normal(kb, (3, 160, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(batched_matmul(a, b, dataflow="is")),
        np.asarray(batched_matmul(a, b, dataflow="ws")),
        rtol=1e-5, atol=1e-5)
